"""Distributed PLAR on a simulated multi-device mesh (the paper's cluster).

    PYTHONPATH=src python examples/distributed_reduction.py

Runs the mesh-distributed MDP implementation (granules over 'data',
candidates over 'model') on 8 simulated devices and validates it against the
single-process PLAR and the brute-force oracle — then compares the three
collective schedules (paper-faithful all_reduce vs beyond-paper
reduce_scatter and fused; DESIGN.md §3.2).

NOTE: must run as its own process (device count is locked at jax init).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, "src")

import time

import jax
import numpy as np

from repro.core import plar_reduce
from repro.core.distributed import plar_reduce_distributed
from repro.data import scaled_paper_dataset


def main():
    from repro.distributed.api import make_mesh

    mesh = make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

    x, d = scaled_paper_dataset("shuttle", max_rows=20000, max_attrs=9).table()
    print(f"table: {x.shape}")

    for delta in ("PR", "SCE"):
        r_serial = plar_reduce(x, d, delta=delta)
        for coll in ("all_reduce", "reduce_scatter", "fused"):
            t0 = time.perf_counter()
            r = plar_reduce_distributed(x, d, mesh, delta=delta, collective=coll)
            dt = time.perf_counter() - t0
            match = "==" if r.reduct == r_serial.reduct else "!="
            print(f"Δ={delta:<4} {coll:<15} reduct={r.reduct} "
                  f"{match} serial ({dt:.2f}s)")
            assert r.reduct == r_serial.reduct


if __name__ == "__main__":
    main()
