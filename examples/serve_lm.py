"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma-2b] [--requests 8]

Loads the latest checkpoint from examples/train_lm.py if present (otherwise
random weights), then drives the ServingEngine with a batch of prompts of
varying lengths and budgets — the decode step is the same function the
multi-pod dry-run lowers for the decode_32k cells.
"""
import sys

sys.path.insert(0, "src")

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = ServingEngine(cfg, params, max_batch=args.max_batch, cache_len=96)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab, size=int(rng.integers(4, 24))),
                max_new_tokens=int(rng.integers(4, 12)))
        for i in range(args.requests)
    ]
    print(f"serving {len(reqs)} requests, max_batch={args.max_batch} "
          f"(continuous batching)")
    done = engine.serve(reqs)
    for r in done:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} → {len(r.output)} tokens "
              f"in {r.latency_s*1e3:.0f} ms: {r.output}")
    tput = sum(len(r.output) for r in done) / max(sum(r.latency_s for r in done), 1e-9)
    print(f"aggregate decode throughput ≈ {tput:.1f} tok/s (1-core CPU)")


if __name__ == "__main__":
    main()
