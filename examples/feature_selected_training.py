"""End-to-end driver: PLAR feature selection feeding model training.

    PYTHONPATH=src python examples/feature_selected_training.py

The paper positions attribute reduction as the preprocessing step of a
learning pipeline.  This example runs the full loop the framework is built
around:

  1. generate a high-dimensional tabular stream (gisette-shaped);
  2. run PLAR (SCE) to find the reduct;
  3. train a small tabular transformer on (a) all attributes and (b) the
     reduct only — same budget;
  4. show the reduct model matches (or beats) full-attribute accuracy with a
     fraction of the input width — the paper's "reduce uncertainty &
     complexity without losing discernibility" claim, measured end-to-end.
"""
import sys

sys.path.insert(0, "src")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plar_reduce
from repro.data import FeatureSelectedStream, TabularStream
from repro.models.config import ArchConfig
from repro.models import build_model
from repro.train import AdamW, constant_schedule, make_train_step


def tabular_lm(n_attrs: int, v_max: int, n_classes: int) -> ArchConfig:
    """Tiny decoder treating each attribute as one token position."""
    return ArchConfig(
        name=f"tab-{n_attrs}", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab=max(v_max, n_classes) + 1, param_dtype="float32",
        compute_dtype="float32", remat=False, fsdp=False,
    )


def train_tabular(x: np.ndarray, d: np.ndarray, steps: int = 60, batch: int = 64):
    n, a = x.shape
    cfg = tabular_lm(a, int(x.max()), int(d.max()) + 1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=constant_schedule(3e-3), weight_decay=0.0)
    step_fn = jax.jit(make_train_step(model, opt))
    state = {"params": params, "opt_m": opt.init(params).m,
             "opt_v": opt.init(params).v, "opt_step": jnp.zeros((), jnp.int32)}

    rng = np.random.default_rng(0)
    split = int(0.9 * n)
    for step in range(steps):
        idx = rng.integers(0, split, batch)
        toks = x[idx]
        # predict the class token at the last position
        labels = np.concatenate([toks[:, 1:], d[idx][:, None]], axis=1)
        state, metrics = step_fn(state, {
            "tokens": jnp.asarray(toks, jnp.int32),
            "labels": jnp.asarray(labels, jnp.int32),
        })
    # eval: accuracy of the class prediction at the last position
    toks = jnp.asarray(x[split:], jnp.int32)
    logits = model.forward({"params": state["params"]}["params"], {"tokens": toks})
    pred = np.asarray(jnp.argmax(logits[:, -1], -1))
    return float((pred == d[split:]).mean()), float(metrics["loss"])


def main():
    stream = TabularStream(n_rows=3000, n_attrs=48, v_max=4, n_dec=2,
                           redundancy=0.5, relevance=3, noise=0.02, seed=7)
    x, d = stream.table()
    print(f"table: {x.shape}, classes={int(d.max()) + 1}")

    r = plar_reduce(x, d, delta="SCE", max_features=12)
    print(f"PLAR reduct: {r.reduct} ({len(r.reduct)}/{x.shape[1]} attributes)")

    xr, dr = FeatureSelectedStream(stream, r.reduct).table()
    acc_full, loss_full = train_tabular(x, d)
    acc_red, loss_red = train_tabular(xr, dr)
    print(f"full attributes : acc={acc_full:.3f} (train loss {loss_full:.3f}) "
          f"width={x.shape[1]}")
    print(f"PLAR reduct     : acc={acc_red:.3f} (train loss {loss_red:.3f}) "
          f"width={xr.shape[1]}")
    print("→ reduct keeps the signal at "
          f"{xr.shape[1] / x.shape[1]:.0%} of the input width")


if __name__ == "__main__":
    main()
