"""Train a ~100M-param LM for a few hundred steps (the end-to-end driver).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch tinyllama-1.1b]

Uses the production Trainer: cosine schedule, grad clipping, checkpointing
(atomic + retention), preemption handler, straggler monitor, deterministic
restart-safe data.  The model is a ~100M config of the chosen architecture's
family (depth/width scaled, same block structure).
"""
import sys

sys.path.insert(0, "src")

import argparse
import dataclasses
import os

import jax.numpy as jnp

from repro.configs import get_config
from repro.data import TokenStream
from repro.train import TrainConfig, Trainer


def scale_to_100m(cfg):
    """Same family, ~100M params: d_model=512, 8 layers, vocab 32k."""
    changes = dict(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=min(cfg.n_kv_heads, 4) or 4,
        head_dim=64, d_ff=1536, vocab=32_000,
        param_dtype="float32", compute_dtype="float32",
        remat=False, fsdp=False,
    )
    if cfg.n_experts:
        changes.update(n_experts=8, top_k=2, moe_d_ff=512)
    if cfg.attn_every:
        changes.update(attn_every=4, n_layers=8)
    if cfg.family == "ssm":
        changes.update(rwkv_head_dim=64)
    return dataclasses.replace(cfg, **changes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="runs/train_lm_ckpt")
    args = ap.parse_args()

    cfg = scale_to_100m(get_config(args.arch))
    n_params = cfg.param_count()
    print(f"arch={cfg.name} (~{n_params/1e6:.0f}M params analytic)")

    tc = TrainConfig(
        peak_lr=3e-4, warmup_steps=20, total_steps=args.steps,
        ckpt_every=50, ckpt_dir=args.ckpt_dir, log_every=10,
    )
    trainer = Trainer(cfg, tc)
    trainer.install_preemption_handler()

    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    data_fn = lambda step: {k: jnp.asarray(v) for k, v in stream.batch(step).items()}

    state, history = trainer.fit(data_fn, steps=args.steps)
    print("\nstep  loss    grad_norm  s/step")
    for h in history:
        print(f"{h['step']:>4}  {h['loss']:<7.4f} {h['grad_norm']:<9.3f} "
              f"{h['sec_per_step']:.2f}")
    if trainer.straggler_steps:
        print(f"straggler steps flagged: {trainer.straggler_steps}")
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} → {last:.3f} over {args.steps} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    print(f"checkpoints in {args.ckpt_dir}: rerun this script to resume.")


if __name__ == "__main__":
    main()
