"""Quickstart: attribute reduction on a mushroom-shaped decision table.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end-to-end: build a decision table, run PLAR
with each of the paper's four significance measures, inspect the reduct, and
cross-check against the sequential baseline (paper Tables 6–9: identical
feature subsets).
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import har_reduce, plar_reduce
from repro.data import scaled_paper_dataset


def main():
    x, d = scaled_paper_dataset("mushroom", max_rows=5644).table()
    print(f"decision table: {x.shape[0]} samples × {x.shape[1]} attributes, "
          f"{int(d.max()) + 1} classes")

    for delta in ("PR", "SCE", "LCE", "CCE"):
        r = plar_reduce(x, d, delta=delta)
        print(f"\nΔ = {delta}")
        print(f"  reduct ({len(r.reduct)} attrs): {r.reduct}")
        print(f"  core:   {r.core}")
        print(f"  Θ(D|C) = {r.theta_full:.6f}; greedy Θ path: "
              f"{[round(t, 4) for t in r.theta_history]}")
        print(f"  evaluations: {r.n_evaluations}, elapsed: {r.elapsed_s:.2f}s")

        # the paper's consistency claim (Tables 6-9): HAR picks the same subset
        r_har = har_reduce(x, d, delta=delta)
        assert r_har.reduct == r.reduct, "HAR and PLAR must agree"
        print(f"  HAR agrees ({r_har.elapsed_s:.2f}s vs PLAR {r.elapsed_s:.2f}s)")


if __name__ == "__main__":
    main()
