"""Multi-tenant serving benchmark: batched dispatch vs single-flight.

The §3.9 scheduler's reason to exist, measured: N concurrent clients with
mixed measures over M datasets, under a streaming-update firehose (every
round lands one update batch per dataset, then all clients query at once).
The batched scheduler coalesces each dataset's window into ONE stacked
``reduce_many`` dispatch (warm repair included — per-config ``warm_start``
rides the §3.8 ensemble operands), while the PR 5 single-flight baseline
serves the identical workload one engine run per query.

Hard guarantees asserted in-bench, not just reported:

* **parity** — every batched result is byte-identical (reduct + Θ history +
  Θ_full) to its single-flight twin from the same round;
* **dedup** — C identical concurrent queries produce exactly 1 engine
  dispatch (engine-run counters);
* **admission** — submits above the bounded queue depth fail fast with
  ``ServerOverloaded``, and the server serves again after the drain.

Snapshot with ``python -m benchmarks.run --preset serve`` →
``benchmarks/BENCH_serve.json``.
"""
from __future__ import annotations

import asyncio
import time
from typing import Dict, List

import numpy as np

from .engine_bench import _latent_table

# 8-client mixed-measure workload over 2 datasets: each dataset's window
# carries all four measures → one stacked C=4 dispatch per dataset per round.
# Tables use the dispatch-bound tier shape (cf. autotune_bench): few latent
# factors → ~v_max^n_latent granules, so each engine run is mostly fixed
# dispatch overhead — the regime a multi-tenant tier of small resident
# datasets actually lives in, and where collapsing 4 dispatches into one
# stacked dispatch pays wall-clock, not just counter, dividends.
N_ROWS, N_ATTRS, N_LATENT, V_MAX = 20000, 32, 4, 3
CLIENTS = [(ds, m) for ds in ("A", "B") for m in ("PR", "SCE", "LCE", "CCE")]
ROUNDS = 3


def _run_workload(batching: bool, tables, chunks):
    """Drive the firehose workload; returns (per-round results, timed span,
    stats, metrics summary)."""
    from repro.service import ReductServer

    async def drive():
        async with ReductServer(batching=batching) as srv:
            for name, (x, d, base) in tables.items():
                await srv.submit(name, x[:base], d[:base],
                                 n_dec=2, v_max=V_MAX)
            # warm-up round: compile-warms cold + warm paths (not timed)
            await asyncio.gather(
                *[srv.query(ds, m) for ds, m in CLIENTS])
            for name in tables:
                await srv.update(name, *chunks[name][0])
            await asyncio.gather(
                *[srv.query(ds, m) for ds, m in CLIENTS])

            per_round = []
            t0 = time.perf_counter()
            for r in range(1, ROUNDS + 1):
                for name in tables:   # the firehose: one batch per dataset
                    await srv.update(name, *chunks[name][r])
                rs = await asyncio.gather(
                    *[srv.query(ds, m) for ds, m in CLIENTS])
                per_round.append(rs)
            span = time.perf_counter() - t0
            return per_round, span, dict(srv.stats), srv.metrics.summary()

    return asyncio.run(drive())


def serve_batched_vs_single_flight() -> List[Dict]:
    tables, chunks = {}, {}
    for i, name in enumerate(("A", "B")):
        x, d = _latent_table(N_ROWS, N_ATTRS, N_LATENT, V_MAX, seed=41 + i)
        base = N_ROWS // 2
        tables[name] = (x, d, base)
        # ROUNDS+1 update batches per dataset (one feeds the warm-up round)
        step = (N_ROWS - base) // (ROUNDS + 1)
        chunks[name] = [(x[base + r * step: base + (r + 1) * step],
                         d[base + r * step: base + (r + 1) * step])
                        for r in range(ROUNDS + 1)]

    b_rounds, b_span, b_stats, b_metrics = _run_workload(True, tables, chunks)
    s_rounds, s_span, s_stats, s_metrics = _run_workload(False, tables, chunks)

    # parity: every batched result byte-identical to its single-flight twin
    for r, (brs, srs) in enumerate(zip(b_rounds, s_rounds)):
        for (ds, m), rb, rs_ in zip(CLIENTS, brs, srs):
            assert rb.reduct == rs_.reduct, \
                f"round {r} {ds}/{m}: reduct diverged"
            assert np.array_equal(np.asarray(rb.theta_history),
                                  np.asarray(rs_.theta_history)), \
                f"round {r} {ds}/{m}: theta history diverged"
            assert rb.theta_full == rs_.theta_full

    n_queries = ROUNDS * len(CLIENTS)
    qps_b = n_queries / b_span
    qps_s = n_queries / s_span
    speedup = qps_b / max(qps_s, 1e-9)
    assert speedup >= 2.0, (
        f"batched dispatch only {speedup:.2f}x over single-flight "
        f"(need >=2x): {b_span:.3f}s vs {s_span:.3f}s")

    def _row(mode, span, stats, metrics):
        return {
            "mode": mode,
            "clients": len(CLIENTS), "datasets": len(tables),
            "rounds": ROUNDS, "queries": n_queries,
            "span_s": round(span, 3),
            "qps": round(n_queries / span, 2),
            "engine_runs": stats["engine_runs"],
            "mean_occupancy": metrics["mean_batch_occupancy"],
            "latency_p50_s": metrics["latency_p50_s"],
            "latency_p99_s": metrics["latency_p99_s"],
        }

    return [
        _row("batched", b_span, b_stats, b_metrics),
        _row("single_flight", s_span, s_stats, s_metrics),
        {"mode": "speedup", "clients": len(CLIENTS),
         "datasets": len(tables), "rounds": ROUNDS, "queries": n_queries,
         "span_s": round(speedup, 2), "qps": round(speedup, 2),
         "engine_runs": "-", "mean_occupancy": "-",
         "latency_p50_s": "-", "latency_p99_s": "parity=ok"},
    ]


def serve_dedup_and_admission() -> List[Dict]:
    """In-flight dedup and admission control, counted exactly."""
    from repro.service import ReductServer, ServerOverloaded

    x, d = _latent_table(8000, 24, 4, V_MAX, seed=7)

    async def drive():
        rows = []
        # C identical concurrent queries → exactly 1 engine dispatch
        async with ReductServer() as srv:
            await srv.submit("s", x, d, n_dec=2, v_max=V_MAX)
            c = 6
            rs = await asyncio.gather(
                *[srv.query("s", "SCE", tol=1e-6) for _ in range(c)])
            assert srv.stats["engine_runs"] == 1, srv.stats
            assert srv.stats["dedup_hits"] == c - 1
            assert all(r is rs[0] for r in rs)
            rows.append({"check": "inflight_dedup", "clients": c,
                         "engine_runs": srv.stats["engine_runs"],
                         "dedup_hits": srv.stats["dedup_hits"],
                         "rejected": 0, "recovered": "-"})
        # over-capacity submits fail fast, then the server recovers
        async with ReductServer(max_queue=3) as srv:
            await srv.submit("s", x, d, n_dec=2, v_max=V_MAX)
            tasks = [asyncio.create_task(
                srv.query("s", "PR", max_features=i + 1)) for i in range(6)]
            done = await asyncio.gather(*tasks, return_exceptions=True)
            rejected = sum(isinstance(r, ServerOverloaded) for r in done)
            served = sum(not isinstance(r, Exception) for r in done)
            assert rejected >= 1 and served >= 1
            assert served + rejected == len(tasks)
            r = await srv.query("s", "SCE")   # backlog drained: admits again
            rows.append({"check": "admission_control", "clients": len(tasks),
                         "engine_runs": srv.stats["engine_runs"],
                         "dedup_hits": srv.stats["dedup_hits"],
                         "rejected": rejected,
                         "recovered": bool(r.reduct is not None)})
        return rows

    return asyncio.run(drive())


ALL_SERVE_BENCHES = {
    "serve_batched_vs_single_flight": serve_batched_vs_single_flight,
    "serve_dedup_and_admission": serve_dedup_and_admission,
}
