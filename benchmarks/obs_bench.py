"""Observability benchmark: tracer overhead + the traced chaos run.

Two sections (DESIGN.md §3.11):

* ``obs_tracer_overhead`` — the zero-overhead-when-disabled contract,
  measured: per-span cost with the tracer disabled and enabled
  (spans/sec), then the §3.9 serve firehose driven both ways.  The
  disabled-mode overhead is asserted **deterministically**: measured
  spans-per-query × measured disabled-span cost must be < 2% of the
  firehose's mean per-query latency — a bound that does not depend on
  run-to-run wall-clock noise the way an enabled-vs-disabled diff does.
* ``obs_trace_chaos`` — PR 9's chaos machinery with the flight recorder
  on: a sharded build loses a shard and recovers through lineage, a
  fault plan fails a dispatch mid-serve, checkpoints land — and the
  exported Perfetto JSON must contain spans from all four subsystems
  (engine, scheduler, checkpoint, recovery) plus a flight-recorder dump
  next to the checkpoints.  Chaos runs become debuggable, not just
  survivable.

Snapshot with ``python -m benchmarks.run --preset obs`` →
``benchmarks/BENCH_obs.json``.
"""
from __future__ import annotations

import asyncio
import glob
import json
import os
import time
from typing import Dict, List

from .engine_bench import _latent_table
from .serve_bench import CLIENTS, N_ATTRS, N_LATENT, N_ROWS, ROUNDS, V_MAX

# Disabled spans are nanoseconds each; a large loop count keeps the
# per-span estimate stable against timer granularity.
SPAN_LOOP = 200_000

# The hard ceiling of the zero-overhead contract: tracing compiled out
# (disabled) must cost < 2% of the serve firehose's per-query latency.
OVERHEAD_CEILING = 0.02


def _span_cost_s(enabled: bool) -> float:
    """Per-span wall cost of ``with obs.span(...): pass`` (no attrs)."""
    from repro import obs

    tracer = obs.get_tracer()
    was = tracer.enabled
    (tracer.enable if enabled else tracer.disable)()
    try:
        t0 = time.perf_counter()
        for _ in range(SPAN_LOOP):
            with obs.span("bench.noop"):
                pass
        dt = time.perf_counter() - t0
    finally:
        tracer.enabled = was
    return dt / SPAN_LOOP


def _make_firehose():
    from .serve_bench import _run_workload

    tables, chunks = {}, {}
    for i, name in enumerate(("A", "B")):
        x, d = _latent_table(N_ROWS, N_ATTRS, N_LATENT, V_MAX, seed=41 + i)
        base = N_ROWS // 2
        tables[name] = (x, d, base)
        step = (N_ROWS - base) // (ROUNDS + 1)
        chunks[name] = [(x[base + r * step: base + (r + 1) * step],
                         d[base + r * step: base + (r + 1) * step])
                        for r in range(ROUNDS + 1)]
    return lambda: _run_workload(True, tables, chunks)


def obs_tracer_overhead() -> List[Dict]:
    from repro import obs

    tracer = obs.get_tracer()
    disabled_s = _span_cost_s(enabled=False)
    enabled_s = _span_cost_s(enabled=True)

    firehose = _make_firehose()
    n_queries = ROUNDS * len(CLIENTS)

    tracer.disable()
    _, span_off, _, _ = firehose()

    tracer.enable()
    tracer.clear()
    recorded_before = tracer.recorded
    _, span_on, _, _ = firehose()
    spans_recorded = tracer.recorded - recorded_before
    tracer.disable()

    # the deterministic bound: what the *disabled* tracer costs the firehose
    spans_per_query = spans_recorded / n_queries
    per_query_s = span_off / n_queries
    overhead_frac = spans_per_query * disabled_s / per_query_s
    assert overhead_frac < OVERHEAD_CEILING, (
        f"disabled-tracer overhead {overhead_frac:.4%} >= "
        f"{OVERHEAD_CEILING:.0%} of per-query latency "
        f"({spans_per_query:.0f} spans/query x {disabled_s * 1e9:.0f}ns "
        f"vs {per_query_s * 1e3:.2f}ms/query)")

    return [
        {"probe": "span_disabled", "ns_per_span": round(disabled_s * 1e9, 1),
         "spans_per_s": round(1.0 / disabled_s),
         "firehose_s": round(span_off, 3), "spans_per_query": "-",
         "overhead_pct": "-"},
        {"probe": "span_enabled", "ns_per_span": round(enabled_s * 1e9, 1),
         "spans_per_s": round(1.0 / enabled_s),
         "firehose_s": round(span_on, 3),
         "spans_per_query": round(spans_per_query, 1),
         "overhead_pct": "-"},
        {"probe": "disabled_overhead_bound", "ns_per_span": "-",
         "spans_per_s": "-", "firehose_s": "-",
         "spans_per_query": round(spans_per_query, 1),
         "overhead_pct": round(overhead_frac * 100, 4)},
    ]


def obs_trace_chaos() -> List[Dict]:
    """The PR 9 chaos run, flight-recorded end to end."""
    import tempfile

    from repro import obs
    from repro.core.recovery import build_sharded, recover
    from repro.data.pipeline import TabularStream
    from repro.service import FaultPlan, ReductServer, RetryPolicy

    stream = TabularStream(n_rows=6000, n_attrs=16, v_max=3, n_dec=2,
                           relevance=3, seed=5)
    tracer = obs.enable()
    tracer.clear()
    rows: List[Dict] = []
    try:
        with tempfile.TemporaryDirectory() as ckdir:
            # shard 1 dies after the build; lineage refold recovers it
            plan = FaultPlan.parse("shard_drop@0:1,dispatch@0")
            build = build_sharded(stream, 4, chunk_rows=2048,
                                  fault_plan=plan)
            assert build.lost == [1]
            recovered = recover(build, stream)

            async def drive():
                async with ReductServer(checkpoint_dir=ckdir,
                                        fault_plan=plan,
                                        retry=RetryPolicy(),
                                        serve_stale=True) as srv:
                    x, d = stream.table()
                    half = len(x) // 2
                    await srv.submit("live", x[:half], d[:half],
                                     n_dec=stream.n_dec, v_max=stream.v_max)
                    r1 = await srv.query("live", "SCE")   # dispatch@0 fires
                    await srv.update("live", x[half:], d[half:])
                    r2 = await srv.query("live", "SCE")   # merge + checkpoint
                    return r1, r2, dict(srv.stats)

            r1, r2, stats = asyncio.run(drive())
            assert stats["retries"] >= 1, stats  # the dispatch fault fired
            assert stats["checkpoints"] >= 1, stats

            # the fault firing must have dumped the flight recorder
            dumps = glob.glob(os.path.join(ckdir, "flightrec-*.json"))
            assert dumps, f"no flight-recorder dump in {ckdir}"
            with open(dumps[0]) as f:
                dump_doc = json.load(f)
            assert dump_doc["traceEvents"], "empty flight-recorder dump"

            trace_path = os.path.join(ckdir, "chaos_trace.json")
            tracer.export(trace_path)
            with open(trace_path) as f:
                doc = json.load(f)

        events = doc["traceEvents"]
        for ev in events:   # Chrome-trace schema validity, every event
            assert {"name", "cat", "ph", "ts", "pid", "tid"} <= set(ev)
            assert ev["ph"] in ("X", "i")
            assert (ev["ph"] != "X") or "dur" in ev
        cats = {ev["cat"] for ev in events}
        need = {"engine", "scheduler", "checkpoint", "recovery"}
        assert need <= cats, f"missing subsystems: {need - cats} (got {cats})"

        by_cat = {c: sum(ev["cat"] == c for ev in events) for c in sorted(cats)}
        rows.append({"check": "chaos_trace", "events": len(events),
                     "subsystems": len(cats),
                     "by_cat": json.dumps(by_cat),
                     "recovered_shards": len(recovered),
                     "dumps": len(dumps), "ok": True})
    finally:
        obs.disable()
        obs.set_dump_dir(None)
    return rows


ALL_OBS_BENCHES = {
    "obs_tracer_overhead": obs_tracer_overhead,
    "obs_trace_chaos": obs_trace_chaos,
}
