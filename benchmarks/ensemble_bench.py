"""Ensemble benchmark: stacked multi-config engine vs a sequential loop.

The stacked engine (DESIGN.md §3.8) runs a whole (measure × shrink × ...)
grid as ONE ``lax.while_loop`` dispatch over one shared granularity: one XLA
compile for the grid and one read of each granule/candidate tile per
iteration, where the sequential loop pays a separate compile and a separate
pass per config.  This section measures that directly, at two grains:

* **cold** — end-to-end wall-clock in a fresh-config process state,
  compiles included: the cost a first-time grid query actually pays (the
  serving-layer number — ``ReductServer.query_ensemble`` is exactly this).
  The stacked grid compiles once; the sequential loop compiles per config
  (each (delta, shrink) pair is its own static ``_Cfg``), which is where
  the bulk of the aggregate configs/sec win comes from.
* **warm** — best-of-3 with every compile cached: the pure loop-execution
  comparison.  On XLA:CPU ``while_loop`` bodies run mostly single-threaded,
  so the stacked body (all configs per iteration) and the sequential loops
  (one config at a time) do similar total compute and the warm ratio mainly
  reflects saved dispatch/driver overhead; on TPU/GPU the shared tile reads
  translate into saved HBM traffic.

Per-config reducts and Θ histories are asserted byte-identical between the
two paths on every shape (the §3.8 correctness contract; exhaustively
covered in tests/test_ensemble.py).

Snapshot with ``python -m benchmarks.run --preset ensemble`` →
``benchmarks/BENCH_ensemble.json``.
"""
from __future__ import annotations

import time
from typing import Dict, List

from .engine_bench import _latent_table

_MEASURES = ("PR", "SCE", "LCE", "CCE")


def ensemble_stacked_vs_sequential() -> List[Dict]:
    """Aggregate configs/sec: one stacked dispatch vs N sequential engines."""
    from repro.core import plar_reduce
    from repro.core.reduction import plar_reduce_ensemble

    # throwaway warmup on an unrelated shape: absorbs process-wide one-time
    # costs (jax init, thread pools) so neither timed path is charged for
    # them; its compiles share no cache entry with the benchmark shapes
    xw, dw = _latent_table(1000, 8, 3, 3, seed=1)
    plar_reduce(xw, dw, delta="PR", engine="device", compute_core=False)
    plar_reduce_ensemble(xw, dw, configs=["PR"], backend="segment")

    shapes = [
        # (rows, attrs, latent, vmax, grid) — ≥32 attrs / ≥4 configs are the
        # acceptance shapes; the 8-config grid crosses measures with shrink
        (20000, 32, 5, 3,
         [{"delta": dd, "shrink": s, "compute_core": False}
          for dd in _MEASURES for s in (False, True)]),
        (40000, 48, 5, 3,
         [{"delta": dd, "compute_core": False} for dd in _MEASURES]),
    ]
    rows = []
    for n, a, nl, vmax, grid in shapes:
        x, d = _latent_table(n, a, nl, vmax, seed=n + a)

        def run_stacked():
            return plar_reduce_ensemble(x, d, configs=grid, backend="segment",
                                        mp_chunk=64)

        def run_sequential():
            return [plar_reduce(x, d, delta=g["delta"],
                                shrink=g.get("shrink", False),
                                compute_core=False, engine="device",
                                backend="segment", mp_chunk=64)
                    for g in grid]

        # cold: stacked first, so it (not the sequential loop) pays the
        # shared host-side compiles (Θ(D|C) ids/contingency) — conservative
        # for the stacked side's reported win
        t0 = time.perf_counter()
        ens = run_stacked()
        cold_stacked = time.perf_counter() - t0
        t0 = time.perf_counter()
        seq = run_sequential()
        cold_seq = time.perf_counter() - t0

        for r_e, r_s in zip(ens, seq):
            assert r_e.reduct == r_s.reduct, "stacked/sequential disagree"
            assert r_e.theta_history == r_s.theta_history, \
                "stacked/sequential Θ histories disagree"

        warm_stacked = min(
            _timed(run_stacked) for _ in range(3))
        warm_seq = min(
            _timed(run_sequential) for _ in range(3))

        c = len(grid)
        rows.append({
            "table": f"grc n{n} A{a} latent{nl}",
            "configs": c,
            "selected": [len(r.reduct) for r in ens][0],
            "cold_stacked_s": round(cold_stacked, 3),
            "cold_sequential_s": round(cold_seq, 3),
            "cold_cfg_per_s_stacked": round(c / cold_stacked, 3),
            "cold_cfg_per_s_sequential": round(c / cold_seq, 3),
            "cold_speedup": round(cold_seq / max(cold_stacked, 1e-9), 2),
            "warm_stacked_s": round(warm_stacked, 3),
            "warm_sequential_s": round(warm_seq, 3),
            "warm_speedup": round(warm_seq / max(warm_stacked, 1e-9), 2),
        })
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


ALL_ENSEMBLE_BENCHES = {
    "ensemble_stacked_vs_sequential": ensemble_stacked_vs_sequential,
}
