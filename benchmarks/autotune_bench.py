"""Roofline-driven tile selection + dispatch-bound tier (DESIGN.md §5.2).

Two sections, snapshotted by ``python -m benchmarks.run --preset autotune`` →
``benchmarks/BENCH_autotune.json``:

* ``autotune_tile_selection`` — what each selector mode picks per kernel per
  shape regime, the analytic pick's modeled time, and (on the single-grid-step
  validation shapes) the modeled-vs-``cost_analysis()`` byte ratio — the same
  agreement tests/test_kernel_cost_model.py asserts, kept visible in the perf
  trajectory.
* ``autotune_dispatch_bound`` — the benchmark tier the cost model's
  ``GRID_STEP_OVERHEAD_S`` term exists for: a tiny-granule table (a few dozen
  granules after GrC init) where per-iteration wall clock is dominated by
  engine/dispatch overhead rather than kernel compute, against a
  granule-heavy compute-bound contrast.  Reported columns separate the two:
  ``modeled_kernel_ms`` is the roofline bound of the per-iteration candidate
  sweep, ``engine_overhead_ms`` the measured remainder.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from .engine_bench import _dense_table, _latent_table

# (label, nc, g, n_bins, m, v_max) — the tile-selection shape regimes
_SHAPES = [
    ("tiny", 2, 300, 40, 3, 2),
    ("mid", 8, 3000, 1024, 8, 2),
    ("wide", 64, 8192, 4096, 16, 4),
]

# single-grid-step validation shapes (XLA counts a while body once, so only
# one-step grids compare exactly — the tests/test_kernel_cost_model.py matrix)
_VALIDATION = [
    ("contingency", 1, 1024, 8, 128, (8, 1024), 1, None),
    ("fused", 1, 1024, 8, 128, (8, 1024), 1, "SCE"),
    ("sweep", 1, 1024, 8, 128, (1, 8, 1024), 2, "SCE"),
]


def _measured_cost(kernel, nc, g, nb, m, tiles, v_max, delta):
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    wd = jnp.zeros((g, m), jnp.float32).at[
        jnp.arange(g), jnp.asarray(rng.integers(0, m, (g,)))].set(1.0)
    if kernel == "contingency":
        from repro.kernels.contingency.kernel import contingency_pallas

        packed = jnp.asarray(rng.integers(0, nb, (nc, g)), jnp.int32)
        low = contingency_pallas.lower(packed, wd, n_bins=nb, bk=tiles[0],
                                       bg=tiles[1], interpret=True)
    elif kernel == "fused":
        from repro.kernels.contingency.fused import fused_theta_pallas

        packed = jnp.asarray(rng.integers(0, nb, (nc, g)), jnp.int32)
        low = fused_theta_pallas.lower(packed, wd, n_bins=nb, delta=delta,
                                       bk=tiles[0], bg=tiles[1],
                                       interpret=True)
    else:
        from repro.kernels.contingency.sweep import sweep_theta_pallas

        x_t = jnp.asarray(rng.integers(0, v_max, (nc, g)), jnp.int32)
        r_ids = jnp.asarray(
            rng.integers(0, max(nb // v_max, 1), (g,)), jnp.int32)
        low = sweep_theta_pallas.lower(x_t, r_ids, wd, v_max=v_max, n_bins=nb,
                                       delta=delta, bc=tiles[0], bk=tiles[1],
                                       bg=tiles[2], interpret=True)
    ca = low.compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def autotune_tile_selection() -> List[Dict]:
    """Per-selector tile picks + analytic model agreement with XLA."""
    from repro.kernels.contingency.autotune import resolve_tiles
    from repro.kernels.contingency.model import kernel_cost, modeled_time_s

    rows = []
    for label, nc, g, nb, m, v_max in _SHAPES:
        m_pad = -(-m // 128) * 128
        for kernel in ("contingency", "fused", "sweep"):
            picks = {
                sel: resolve_tiles(kernel, nc=nc, g=g, n_bins=nb, m=m_pad,
                                   v_max=v_max, selector=sel)
                for sel in ("heuristic", "analytic", "pinned")
            }
            cost = kernel_cost(kernel, nc, g, nb, m_pad, picks["analytic"],
                               v_max=v_max)
            rows.append({
                "shape": label, "kernel": kernel,
                "heuristic": "x".join(map(str, picks["heuristic"])),
                "analytic": "x".join(map(str, picks["analytic"])),
                "pinned": "x".join(map(str, picks["pinned"])),
                "modeled_ms": round(modeled_time_s(cost) * 1e3, 4),
                "modeled_MB": round(cost.hbm_bytes / 1e6, 2),
                "grid_steps": cost.grid_steps,
            })

    # model-vs-XLA agreement on the single-step validation shapes
    from repro.kernels.contingency.model import kernel_cost as kc

    for kernel, nc, g, nb, m, tiles, v_max, delta in _VALIDATION:
        cost = kc(kernel, nc, g, nb, m, tiles, v_max=v_max,
                  delta=delta or "SCE")
        flops_x, bytes_x = _measured_cost(kernel, nc, g, nb, m, tiles,
                                          v_max, delta or "SCE")
        rows.append({
            "shape": "validate", "kernel": kernel,
            "heuristic": "-", "analytic": "x".join(map(str, tiles)),
            "pinned": "-",
            "modeled_ms": round(modeled_time_s(cost) * 1e3, 4),
            "modeled_MB": round(cost.hbm_bytes / 1e6, 2),
            "grid_steps": cost.grid_steps,
            "flops_ratio": round(cost.flops / flops_x, 3) if flops_x else None,
            "bytes_ratio": round(cost.hbm_bytes / bytes_x, 3) if bytes_x else None,
        })
    return rows


def autotune_dispatch_bound() -> List[Dict]:
    """Per-iteration wall clock vs modeled kernel compute, two regimes.

    ``tiny_granule`` is the dispatch-bound tier: 20k rows collapse to a few
    dozen granules, so one greedy iteration moves kilobytes — the while_loop
    body's fixed costs (dispatch, argmin, state carry) dominate and
    ``engine_overhead_ms`` ≈ the whole iteration.  ``dense_granule`` is the
    compute-bound contrast (every row its own granule).
    """
    from repro.core import plar_reduce
    from repro.core.granularity import build_granularity, next_pow2
    from repro.kernels.contingency.model import (
        kernel_cost,
        modeled_time_s,
        select_tiles,
    )

    shapes = [
        ("tiny_granule", *_latent_table(20000, 32, 4, 3, seed=11), 3),
        ("dense_granule", *_dense_table(4000, 16, 3, seed=13), 3),
    ]
    rows = []
    for label, x, d, vmax in shapes:
        n, a = x.shape
        gran = build_granularity(x, d, n_dec=2, v_max=vmax)
        cap = next_pow2(max(int(gran.num), 16))
        m_pad = 128  # lane-padded decision axis
        nb = cap * vmax

        def run():
            return plar_reduce(x, d, delta="SCE", backend="sweep_xla",
                               engine="device", ladder=True,
                               selector="analytic")

        r = run()                       # warm the compile
        best = None
        for _ in range(3):
            t0 = time.perf_counter()
            r = run()
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        per_iter_ms = best / max(len(r.reduct), 1) * 1e3

        # modeled per-iteration candidate sweep at the analytic tiles — the
        # kernel-compute share of one iteration
        tiles = select_tiles("sweep", a, cap, nb, m_pad, v_max=vmax)
        cost = kernel_cost("sweep", a, cap, nb, m_pad, tiles, v_max=vmax)
        modeled_ms = modeled_time_s(cost) * 1e3
        rows.append({
            "table": label, "rows": n, "attrs": a,
            "granules": int(gran.num), "cap": cap,
            "iterations": len(r.reduct),
            "per_iter_ms": round(per_iter_ms, 3),
            "modeled_kernel_ms": round(modeled_ms, 4),
            "engine_overhead_ms": round(max(per_iter_ms - modeled_ms, 0.0), 3),
            "overhead_frac": round(
                max(per_iter_ms - modeled_ms, 0.0) / per_iter_ms, 3)
            if per_iter_ms else None,
        })
    return rows


ALL_AUTOTUNE_BENCHES = {
    "autotune_tile_selection": autotune_tile_selection,
    "autotune_dispatch_bound": autotune_dispatch_bound,
}
