"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure (paper_tables.py) + kernel micro-benches.
Pass table names to run a subset: ``python -m benchmarks.run table_12 fig_9``;
``--list`` prints every selectable section and preset.
Results are printed as aligned text and mirrored to benchmarks/results.json;
``--tag NAME`` additionally snapshots them to ``benchmarks/BENCH_NAME.json``
(timestamped), building the per-PR perf trajectory — see benchmarks/README.md.
"""
from __future__ import annotations

import json
import re
import sys
import time


def _print_rows(name: str, rows) -> None:
    print(f"\n=== {name} ===")
    if not rows:
        print("(empty)")
        return
    keys = list(rows[0].keys())
    widths = {k: max(len(str(k)), *(len(str(r.get(k, ''))) for r in rows)) for k in keys}
    print("  ".join(str(k).ljust(widths[k]) for k in keys))
    for r in rows:
        print("  ".join(str(r.get(k, "")).ljust(widths[k]) for k in keys))


# Named section bundles: ``--preset NAME`` runs the bundle and snapshots it
# as benchmarks/BENCH_NAME.json (an implicit --tag NAME).
def _environment() -> dict:
    """The machine stamp on every BENCH_*.json snapshot: enough to tell
    whether two snapshots in the perf trajectory are comparable."""
    import datetime
    import os

    import jax

    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }


PRESETS = {
    "engine": ["engine_host_vs_device"],
    "ensemble": ["ensemble_stacked_vs_sequential"],
    "kernels": ["contingency_backends", "fused_theta_vs_unfused"],
    "ingest": ["ingest_stream_vs_monolithic"],
    "sweep": ["sweep_ladder_speedup"],
    "service": ["service_incremental_vs_recompute"],
    "serve": ["serve_batched_vs_single_flight", "serve_dedup_and_admission"],
    "autotune": ["autotune_tile_selection", "autotune_dispatch_bound"],
    "chaos": ["chaos_refold_vs_rebuild", "chaos_restart_warm_vs_cold",
              "chaos_fault_storm_absorbed"],
    "obs": ["obs_tracer_overhead", "obs_trace_chaos"],
}


def main() -> None:
    from .autotune_bench import ALL_AUTOTUNE_BENCHES
    from .chaos_bench import ALL_CHAOS_BENCHES
    from .engine_bench import ALL_ENGINE_BENCHES
    from .ensemble_bench import ALL_ENSEMBLE_BENCHES
    from .ingest_bench import ALL_INGEST_BENCHES, EXPLICIT_BENCHES
    from .kernel_bench import ALL_BENCHES
    from .obs_bench import ALL_OBS_BENCHES
    from .paper_tables import ALL_TABLES
    from .serve_bench import ALL_SERVE_BENCHES
    from .service_bench import ALL_SERVICE_BENCHES

    # accept both "--flag VALUE" and "--flag=VALUE"
    argv = []
    for a in sys.argv[1:]:
        if a.startswith("--preset=") or a.startswith("--tag="):
            argv.extend(a.split("=", 1))
        else:
            argv.append(a)
    tag = None
    if "--tag" in argv:
        i = argv.index("--tag")
        if i + 1 >= len(argv):
            sys.exit("usage: python -m benchmarks.run [SECTION ...] "
                     "[--preset NAME] [--tag NAME]")
        tag = argv[i + 1]
        if not re.fullmatch(r"[A-Za-z0-9._-]+", tag):
            sys.exit(f"invalid --tag {tag!r}: use letters, digits, '.', '_', '-'")
        argv = argv[:i] + argv[i + 2:]
    if "--preset" in argv:
        i = argv.index("--preset")
        if i + 1 >= len(argv) or argv[i + 1] not in PRESETS:
            sys.exit(f"--preset expects one of: {', '.join(sorted(PRESETS))}")
        preset = argv[i + 1]
        argv = argv[:i] + [s for s in PRESETS[preset] if s not in argv] + argv[i + 2:]
        tag = tag or preset
    wanted = argv or None
    jobs = {**ALL_TABLES, **ALL_BENCHES, **ALL_ENGINE_BENCHES,
            **ALL_ENSEMBLE_BENCHES, **ALL_INGEST_BENCHES,
            **ALL_SERVICE_BENCHES, **ALL_SERVE_BENCHES,
            **ALL_AUTOTUNE_BENCHES, **ALL_CHAOS_BENCHES,
            **ALL_OBS_BENCHES}
    # long-running sections run only when named, never via the no-arg path
    selectable = {**jobs, **EXPLICIT_BENCHES}
    if "--list" in argv:
        print("sections:")
        for name in sorted(selectable):
            note = "  (explicit-only)" if name in EXPLICIT_BENCHES else ""
            print(f"  {name}{note}")
        print("presets (--preset NAME, implies --tag NAME):")
        for name in sorted(PRESETS):
            print(f"  {name}: {', '.join(PRESETS[name])}")
        return
    if wanted:
        unknown = [s for s in wanted if s not in selectable]
        if unknown:
            sys.exit(f"unknown section(s): {', '.join(unknown)}\n"
                     f"available: {', '.join(sorted(selectable))}")
        jobs = {k: v for k, v in selectable.items() if k in wanted}

    results = {}
    for name, fn in jobs.items():
        t0 = time.perf_counter()
        rows = fn()
        results[name] = rows
        _print_rows(name, rows)
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")

    with open("benchmarks/results.json", "w") as f:
        json.dump(results, f, indent=2)
    print("\nwritten: benchmarks/results.json")

    if tag is not None:
        snap = f"benchmarks/BENCH_{tag}.json"
        # merge by section: partial runs refresh what they ran without
        # destroying a snapshot's other sections (e.g. BENCH_ingest.json
        # holds the CI-smoke section AND the paper-scale evidence); each
        # section keeps its own timestamp so carried-over evidence is
        # distinguishable from freshly regenerated rows
        sections, section_times = {}, {}
        try:
            with open(snap) as f:
                prev = json.load(f)
            sections = prev.get("sections", {})
            section_times = prev.get("section_times", {})
        except (OSError, json.JSONDecodeError):
            pass
        now = int(time.time())
        sections.update(results)
        section_times.update({name: now for name in results})
        with open(snap, "w") as f:
            json.dump({"tag": tag, "unix_time": now,
                       "environment": _environment(),
                       "section_times": section_times,
                       "sections": sections}, f, indent=2)
        print(f"written: {snap}")


if __name__ == "__main__":
    main()
