"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure (paper_tables.py) + kernel micro-benches.
Pass table names to run a subset: ``python -m benchmarks.run table_12 fig_9``.
Results are printed as aligned text and mirrored to benchmarks/results.json.
"""
from __future__ import annotations

import json
import sys
import time


def _print_rows(name: str, rows) -> None:
    print(f"\n=== {name} ===")
    if not rows:
        print("(empty)")
        return
    keys = list(rows[0].keys())
    widths = {k: max(len(str(k)), *(len(str(r.get(k, ''))) for r in rows)) for k in keys}
    print("  ".join(str(k).ljust(widths[k]) for k in keys))
    for r in rows:
        print("  ".join(str(r.get(k, "")).ljust(widths[k]) for k in keys))


def main() -> None:
    from .kernel_bench import ALL_BENCHES
    from .paper_tables import ALL_TABLES

    wanted = sys.argv[1:] or None
    jobs = {**ALL_TABLES, **ALL_BENCHES}
    if wanted:
        jobs = {k: v for k, v in jobs.items() if k in wanted}

    results = {}
    for name, fn in jobs.items():
        t0 = time.perf_counter()
        rows = fn()
        results[name] = rows
        _print_rows(name, rows)
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")

    with open("benchmarks/results.json", "w") as f:
        json.dump(results, f, indent=2)
    print("\nwritten: benchmarks/results.json")


if __name__ == "__main__":
    main()
