"""Microbenchmarks for the compute hot-spots (CPU wall-clock, interpret-mode
kernels excluded — Pallas interpret is a correctness vehicle, not a timing
one; kernel *tiling* quality is assessed via the roofline, not wall time).

Compares the XLA backends that execute in production on this host:
  contingency:  segment-sum vs one-hot-matmul (the MXU strategy in XLA form)
  fused Θ:      materialize-[nc,K,M]-then-evaluate vs the fused schedule
                (θ folded per bin tile — the Pallas kernel's schedule in XLA
                form, DESIGN.md §5.2), across the four measures and shapes
  attention:    chunked-flash XLA vs naive S² (small shapes)
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import candidate_contingency, candidate_theta
from repro.models.attention import _flash_xla
from repro.kernels.flash_attention.ref import attention_ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def contingency_backends(nc=32, g=65536, n_bins=256, m=8) -> List[Dict]:
    rng = np.random.default_rng(0)
    packed = jnp.asarray(rng.integers(0, n_bins, (nc, g)), jnp.int32)
    d = jnp.asarray(rng.integers(0, m, (g,)), jnp.int32)
    w = jnp.asarray(rng.random(g), jnp.float32)
    valid = jnp.ones((g,), bool)
    rows = []
    for backend in ("segment", "onehot"):
        fn = jax.jit(lambda p, dd, ww, vv, b=backend: candidate_contingency(
            p, dd, ww, vv, n_bins=n_bins, m=m, backend=b))
        dt = _time(fn, packed, d, w, valid)
        rows.append({"backend": backend, "us_per_call": round(dt * 1e6, 1),
                     "candidates": nc, "granules": g})
    return rows


def fused_theta_vs_unfused() -> List[Dict]:
    """Fused contingency→Θ vs unfused across measures and (G, nc, K, M) shapes.

    ``unfused`` materializes the [nc, K, M] contingency (one-hot backend, the
    MXU strategy) and reduces it with ``measures.evaluate``; ``fused`` runs
    the same accumulation with the θ epilogue folded per bin tile
    (``backend="fused_xla"``) so the tensor never round-trips through memory.
    ``hbm_mib_saved`` is the write+read traffic of that tensor — the bytes the
    fused Pallas kernel removes from the TPU hot path.
    """
    shapes = [
        # (g, nc, n_bins, m)
        (16384, 16, 256, 2),
        (16384, 64, 1024, 4),
        (65536, 32, 512, 8),
    ]
    rows = []
    for g, nc, n_bins, m in shapes:
        rng = np.random.default_rng(g + nc)
        packed = jnp.asarray(rng.integers(0, n_bins, (nc, g)), jnp.int32)
        d = jnp.asarray(rng.integers(0, m, (g,)), jnp.int32)
        w = jnp.asarray(rng.random(g), jnp.float32)
        valid = jnp.ones((g,), bool)
        n = float(np.asarray(w).sum())
        for delta in ("PR", "SCE", "LCE", "CCE"):
            def theta(backend):
                return jax.jit(lambda p, dd, ww, vv, b=backend: candidate_theta(
                    delta, p, dd, ww, vv, n, n_bins=n_bins, m=m, backend=b))

            t_unfused = _time(theta("onehot"), packed, d, w, valid, reps=3)
            t_fused = _time(theta("fused_xla"), packed, d, w, valid, reps=3)
            rows.append({
                "delta": delta,
                "shape": f"g{g} nc{nc} K{n_bins} m{m}",
                "unfused_ms": round(t_unfused * 1e3, 2),
                "fused_ms": round(t_fused * 1e3, 2),
                "speedup": round(t_unfused / t_fused, 2),
                "hbm_mib_saved": round(2 * 4 * nc * n_bins * m / 2**20, 1),
            })
    return rows


def attention_impls(b=1, h=8, s=1024, dh=64) -> List[Dict]:
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    rows = []
    flash = jax.jit(lambda q_, k_, v_: _flash_xla(
        q_, k_, v_, causal=True, window=None, scale=dh ** -0.5,
        q_chunk=256, kv_chunk=256))
    naive = jax.jit(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=True))
    for name, fn in (("flash_xla_chunked", flash), ("naive_s2", naive)):
        dt = _time(fn, q, k, v, reps=3)
        rows.append({"impl": name, "ms_per_call": round(dt * 1e3, 2),
                     "shape": f"b{b} h{h} s{s} d{dh}"})
    return rows


ALL_BENCHES = {
    "contingency_backends": contingency_backends,
    "fused_theta_vs_unfused": fused_theta_vs_unfused,
    "attention_impls": attention_impls,
}
