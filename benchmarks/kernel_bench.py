"""Microbenchmarks for the compute hot-spots (CPU wall-clock, interpret-mode
kernels excluded — Pallas interpret is a correctness vehicle, not a timing
one; kernel *tiling* quality is assessed via the roofline, not wall time).

Compares the XLA backends that execute in production on this host:
  contingency:  segment-sum vs one-hot-matmul (the MXU strategy in XLA form)
  attention:    chunked-flash XLA vs naive S² (small shapes)
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import candidate_contingency
from repro.models.attention import _flash_xla
from repro.kernels.flash_attention.ref import attention_ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def contingency_backends(nc=32, g=65536, n_bins=256, m=8) -> List[Dict]:
    rng = np.random.default_rng(0)
    packed = jnp.asarray(rng.integers(0, n_bins, (nc, g)), jnp.int32)
    d = jnp.asarray(rng.integers(0, m, (g,)), jnp.int32)
    w = jnp.asarray(rng.random(g), jnp.float32)
    valid = jnp.ones((g,), bool)
    rows = []
    for backend in ("segment", "onehot"):
        fn = jax.jit(lambda p, dd, ww, vv, b=backend: candidate_contingency(
            p, dd, ww, vv, n_bins=n_bins, m=m, backend=b))
        dt = _time(fn, packed, d, w, valid)
        rows.append({"backend": backend, "us_per_call": round(dt * 1e6, 1),
                     "candidates": nc, "granules": g})
    return rows


def attention_impls(b=1, h=8, s=1024, dh=64) -> List[Dict]:
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    rows = []
    flash = jax.jit(lambda q_, k_, v_: _flash_xla(
        q_, k_, v_, causal=True, window=None, scale=dh ** -0.5,
        q_chunk=256, kv_chunk=256))
    naive = jax.jit(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=True))
    for name, fn in (("flash_xla_chunked", flash), ("naive_s2", naive)):
        dt = _time(fn, q, k, v, reps=3)
        rows.append({"impl": name, "ms_per_call": round(dt * 1e3, 2),
                     "shape": f"b{b} h{h} s{s} d{dh}"})
    return rows


ALL_BENCHES = {
    "contingency_backends": contingency_backends,
    "attention_impls": attention_impls,
}
