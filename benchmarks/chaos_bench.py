"""Chaos benchmark: what failures actually cost with the §3.10 layer on.

Two recovery costs, measured (and their cheap alternative asserted):

* **refold vs rebuild** — losing 1 of S data shards costs re-folding that
  shard's lineage (``O(rows/S)``) plus one S-way re-merge; the naive
  answer is a full from-scratch rebuild (``O(rows)``).  The bench times
  both on the same sharded build path (identical chunk shapes → identical
  compiles) and asserts the recovered granularity is bitwise identical to
  the unfailed one — the §3.10 parity contract, not just a speedup claim.
* **restart warm vs cold** — restart-to-first-answer with a durable
  checkpoint (restore handles + warm ``repair_reduce``) vs a cold process
  (rebuild granularity + cold greedy reduction).  Parity of the answer is
  asserted; the two spans are the availability gap a checkpoint buys.

A third section drives the hardened server through an injected fault storm
(transient dispatch faults + a checkpoint-write crash) and asserts the
retry/stale layer absorbed every one of them — queries all answered, no
client-visible error.

Snapshot with ``python -m benchmarks.run --preset chaos`` →
``benchmarks/BENCH_chaos.json`` (the CI smoke tier).
"""
from __future__ import annotations

import asyncio
import os
import tempfile
import time
from typing import Dict, List

import numpy as np

# Big enough that per-shard fold work dominates the fixed merge cost, small
# enough for the CI smoke tier.
N_ROWS, N_ATTRS, N_SHARDS, CHUNK_ROWS = 120_000, 24, 8, 4096


def _stream():
    from repro.data import TabularStream
    return TabularStream(n_rows=N_ROWS, n_attrs=N_ATTRS, v_max=3, n_dec=2,
                         distinct_fraction=0.05, seed=13)


def chaos_refold_vs_rebuild() -> List[Dict]:
    from repro.core.recovery import build_sharded, recover
    from repro.service import granularity_fingerprint

    src = _stream()
    # warm-up build: compiles the fold/merge for these shapes (not timed)
    unfailed = build_sharded(src, N_SHARDS, chunk_rows=CHUNK_ROWS)
    fp = granularity_fingerprint(unfailed.merged)

    t0 = time.perf_counter()
    rebuilt = build_sharded(src, N_SHARDS, chunk_rows=CHUNK_ROWS)
    rebuild_s = time.perf_counter() - t0

    failed = build_sharded(src, N_SHARDS, chunk_rows=CHUNK_ROWS)
    failed.drop(N_SHARDS // 2)
    t0 = time.perf_counter()
    recovered = recover(failed, src)
    refold_s = time.perf_counter() - t0

    # parity first, speed second: recovery must be bitwise exact
    assert recovered == [N_SHARDS // 2]
    assert granularity_fingerprint(failed.merged) == fp
    assert granularity_fingerprint(rebuilt.merged) == fp
    ratio = rebuild_s / max(refold_s, 1e-9)
    assert ratio >= 1.5, (
        f"re-folding one of {N_SHARDS} shards only {ratio:.2f}x cheaper "
        f"than a full rebuild ({refold_s:.3f}s vs {rebuild_s:.3f}s)")
    return [{
        "rows": N_ROWS, "shards": N_SHARDS, "chunk_rows": CHUNK_ROWS,
        "refold_one_shard_s": round(refold_s, 3),
        "full_rebuild_s": round(rebuild_s, 3),
        "rebuild_over_refold": round(ratio, 2),
        "parity": "bitwise",
    }]


def chaos_restart_warm_vs_cold() -> List[Dict]:
    from repro.service import ReductServer

    src = _stream()
    x, d = src.chunk(0, 40_000)

    async def first_life(ckdir):
        async with ReductServer(checkpoint_dir=ckdir) as srv:
            await srv.submit("ds", x, d, n_dec=src.n_dec, v_max=src.v_max)
            r = await srv.query("ds", delta="SCE")
            # persist the warm fixed point (what the restart repairs from)
            r = await asyncio.to_thread(srv.handle("ds").reduce, "SCE")
            return r

    async def restart(ckdir):
        t0 = time.perf_counter()
        async with ReductServer(checkpoint_dir=ckdir) as srv:
            r = await srv.query("ds", delta="SCE")
            span = time.perf_counter() - t0
            return r, span, dict(srv.stats)

    async def cold_process():
        t0 = time.perf_counter()
        async with ReductServer() as srv:
            await srv.submit("ds", x, d, n_dec=src.n_dec, v_max=src.v_max)
            r = await srv.query("ds", delta="SCE")
            span = time.perf_counter() - t0
            return r, span

    with tempfile.TemporaryDirectory() as ckdir:
        # compile-warm everything once (not timed), then measure
        r0 = asyncio.run(first_life(ckdir))
        warm_r, warm_s, stats = asyncio.run(restart(ckdir))
        cold_r, cold_s = asyncio.run(cold_process())

    assert stats["restored_datasets"] == 1
    assert stats["warm"] == 1, "first post-restart query must repair, not rebuild"
    assert warm_r.reduct == r0.reduct, "restart changed the answer"
    assert sorted(warm_r.reduct) == sorted(cold_r.reduct)
    return [{
        "rows": len(x), "measure": "SCE",
        "restart_warm_first_answer_s": round(warm_s, 3),
        "cold_first_answer_s": round(cold_s, 3),
        "cold_over_warm": round(cold_s / max(warm_s, 1e-9), 2),
        "restored": stats["restored_datasets"],
        "parity": "reduct",
    }]


def chaos_fault_storm_absorbed() -> List[Dict]:
    """Transient dispatch faults + a checkpoint crash, all absorbed: every
    query answered, zero client-visible errors."""
    from repro.service import FaultPlan, ReductServer, RetryPolicy

    rng = np.random.default_rng(17)
    x = rng.integers(0, 3, (20_000, 16)).astype(np.int32)
    d = rng.integers(0, 2, (20_000,)).astype(np.int32)
    plan = FaultPlan.parse("dispatch@1,dispatch@3,merge@1,checkpoint@1")

    async def drive(ckdir):
        async with ReductServer(
                checkpoint_dir=ckdir, fault_plan=plan,
                retry=RetryPolicy(base_delay_s=0.001),
                serve_stale=True) as srv:
            await srv.submit("ds", x[:10_000], d[:10_000], n_dec=2, v_max=3)
            answered = 0
            for i in range(4):
                lo = 10_000 + i * 2500
                await srv.update("ds", x[lo:lo + 2500], d[lo:lo + 2500])
                r = await srv.query("ds", delta="PR")
                answered += bool(r.reduct is not None)
            return answered, dict(srv.stats), srv.checkpointer.failed_saves

    with tempfile.TemporaryDirectory() as ckdir:
        answered, stats, failed_saves = asyncio.run(drive(ckdir))

    faults_fired = len(plan.fired)
    assert answered == 4, "a fault leaked to a client"
    assert faults_fired >= 3, f"plan under-fired: {plan.fired}"
    assert stats["retries"] >= 2
    return [{
        "queries": 4, "answered": answered,
        "faults_fired": faults_fired,
        "retries": stats["retries"],
        "stale_served": stats["stale_served"],
        "checkpoint_write_failures": failed_saves,
        "client_errors": 0,
    }]


ALL_CHAOS_BENCHES = {
    "chaos_refold_vs_rebuild": chaos_refold_vs_rebuild,
    "chaos_restart_warm_vs_cold": chaos_restart_warm_vs_cold,
    "chaos_fault_storm_absorbed": chaos_fault_storm_absorbed,
}
