"""End-to-end greedy-loop benchmark: host loop vs device-resident engine.

The kernel micro-benches measure per-candidate evaluation; this section
measures the thing the paper actually fights — per-*iteration* driver
overhead.  ``engine="host"`` pays, every iteration: one jit dispatch per
candidate chunk, an ``int(k_new)`` sync, a numpy gather for the argmin, and
Python list mutation.  ``engine="device"`` runs the whole greedy loop as one
``lax.while_loop`` (core/engine.py), so an iteration costs only its compute.

Table shapes follow the paper's GrC premise (|U/A| ≪ |U|): attribute columns
derive from a few latent factors, so tens of thousands of rows compress to a
few hundred granules and the per-iteration cost is dispatch-dominated — the
regime the engine exists for.  A dense-granule row (every row its own
granule) is kept as the compute-bound reference: there the loop body
dominates and the two engines are within noise of each other on CPU (XLA:CPU
parallelizes top-level ops but runs while_loop bodies mostly single-threaded;
on TPU/GPU this asymmetry disappears).

Snapshot with ``python -m benchmarks.run --preset engine`` →
``benchmarks/BENCH_engine.json`` — the end-to-end datapoint of the perf
trajectory (benchmarks/README.md).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def _latent_table(n: int, a: int, n_latent: int, vmax: int, seed: int):
    """Columns are relabelings of a few latent factors → small |U/A| after
    GrC init, non-trivial reducts (≈ one attribute per informative factor)."""
    rng = np.random.default_rng(seed)
    z = rng.integers(0, vmax, size=(n, n_latent)).astype(np.int32)
    cols = []
    for _ in range(a):
        src = rng.integers(0, n_latent)
        perm = rng.permutation(vmax).astype(np.int32)
        cols.append(perm[z[:, src]])
    x = np.stack(cols, axis=1)
    d = (z.sum(1) % 2).astype(np.int32)
    return x, d


def _dense_table(n: int, a: int, vmax: int, seed: int):
    """No latent structure: nearly every row is its own granule."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    for j in range(1, a):
        if rng.random() < 0.4:
            x[:, j] = x[:, rng.integers(0, j)]
    d = rng.integers(0, 2, size=(n,)).astype(np.int32)
    return x, d


def engine_host_vs_device() -> List[Dict]:
    """Per-iteration wall-clock, host loop vs device engine, same tables.

    Each engine runs once to warm its compiles, then best-of-3 timed runs
    (the host is a shared CPU; min damps contention noise).  Reducts are
    asserted identical between engines on every shape.
    """
    from repro.core import plar_reduce

    shapes = [
        # (kind, rows, attrs, latent, vmax) — ≥32 attrs are the acceptance shapes
        ("grc", 20000, 32, 5, 3),
        ("grc", 50000, 48, 5, 3),
        ("dense", 4000, 16, None, 3),
    ]
    rows = []
    for kind, n, a, nl, vmax in shapes:
        if kind == "grc":
            x, d = _latent_table(n, a, nl, vmax, seed=n + a)
        else:
            x, d = _dense_table(n, a, vmax, seed=n + a)
        out = {}
        for engine in ("host", "device"):
            def run():
                return plar_reduce(x, d, delta="SCE", engine=engine,
                                   compute_core=False, mp_chunk=64)

            run()                       # warm: compiles for this shape
            best, r = None, None
            for _ in range(3):
                r = run()
                per = sum(r.per_iteration_s) / max(r.iterations, 1)
                best = per if best is None else min(best, per)
            out[engine] = (best, r)
        t_host, r_host = out["host"]
        t_dev, r_dev = out["device"]
        assert r_host.reduct == r_dev.reduct, "engines disagree"
        rows.append({
            "table": f"{kind} n{n} A{a}" + (f" latent{nl}" if nl else ""),
            "selected": len(r_dev.reduct),
            "iterations": r_dev.iterations,
            "host_per_iter_ms": round(t_host * 1e3, 2),
            "device_per_iter_ms": round(t_dev * 1e3, 2),
            "speedup": round(t_host / max(t_dev, 1e-9), 2),
            "host_total_s": round(r_host.elapsed_s, 3),
            "device_total_s": round(r_dev.elapsed_s, 3),
        })
    return rows


def sweep_ladder_speedup() -> List[Dict]:
    """Per-iteration wall-clock of the §5.3 eval-sweep configs vs the PR-2
    device engine (``backend="segment"``, ladder off), same tables.

    Grid: ladder on/off × sweep_xla/segment, on GrC-compressed tables
    (incl. the ≥32-attribute acceptance shapes) and a dense-granule one.
    The speedup column is vs the PR-2 baseline; reducts are asserted
    identical across all four configs on every shape.

    XLA:CPU caveat: ``lax.while_loop`` bodies run mostly single-threaded
    (only top-level jit calls parallelize across cores), so the engine-
    resident sweep is benchmarked on dispatch-bound GrC shapes — the regime
    the §3.5 engine exists for.  The dense-granule row is the compute-bound
    reference where the ladder has little to cut (K ≈ G from the first
    iteration, so every iteration runs near the top rung).  On TPU/GPU the
    single-threaded-body asymmetry disappears and the saved bins translate
    directly into saved HBM traffic.

    Snapshot with ``python -m benchmarks.run --preset sweep`` →
    ``benchmarks/BENCH_sweep.json``.
    """
    from repro.core import plar_reduce

    shapes = [
        # (kind, rows, attrs, latent, vmax) — ≥32 attrs are the acceptance
        # shapes; vmax=4 gives cap·V = 4096 bins, a 5-rung ladder
        ("grc", 20000, 32, 5, 4),
        ("grc", 50000, 48, 5, 4),
        ("dense", 4000, 16, None, 3),
    ]
    configs = [
        ("segment", False),   # the PR-2 device engine (baseline)
        ("segment", True),
        ("sweep_xla", False),
        ("sweep_xla", True),
    ]
    rows = []
    for kind, n, a, nl, vmax in shapes:
        if kind == "grc":
            x, d = _latent_table(n, a, nl, vmax, seed=n + a)
        else:
            x, d = _dense_table(n, a, vmax, seed=n + a)
        per = {}
        reducts = {}
        for backend, ladder in configs:
            def run():
                return plar_reduce(x, d, delta="SCE", engine="device",
                                   backend=backend, ladder=ladder,
                                   compute_core=False, mp_chunk=64)

            run()                       # warm: compiles for this config
            best, r = None, None
            for _ in range(3):
                r = run()
                t = sum(r.per_iteration_s) / max(r.iterations, 1)
                best = t if best is None else min(best, t)
            per[(backend, ladder)] = best
            reducts[(backend, ladder)] = r.reduct
        assert len(set(map(tuple, reducts.values()))) == 1, \
            "sweep/ladder configs disagree on the reduct"
        base = per[("segment", False)]
        row = {
            "table": f"{kind} n{n} A{a}" + (f" latent{nl}" if nl else ""),
            "iterations": len(reducts[("segment", False)]),
            "baseline_ms": round(base * 1e3, 2),
        }
        for backend, ladder in configs[1:]:
            key = f"{backend}_ladder_{'on' if ladder else 'off'}"
            row[f"{key}_ms"] = round(per[(backend, ladder)] * 1e3, 2)
            row[f"{key}_speedup"] = round(base / max(per[(backend, ladder)], 1e-9), 2)
        rows.append(row)
    return rows


ALL_ENGINE_BENCHES = {
    "engine_host_vs_device": engine_host_vs_device,
    "sweep_ladder_speedup": sweep_ladder_speedup,
}
