"""Benchmark harness: one function per paper table/figure.

All datasets are synthetic stand-ins with Table 5's shapes (the UCI/KDD/SDSS
files are not redistributable in this container; see data/pipeline.py).  The
largest datasets are capped to CPU-budget sizes — the *relative* claims the
paper makes (PLAR vs HAR/FSPA speedups, MP-level scaling, GrC on/off) are
reproduced; absolute times differ from a 128-core Spark cluster by design.

    table_6_9   — time + selected features: HAR vs FSPA vs PLAR, 4 measures
    table_10    — distributed speedup (SparkAR-analogue vs PLAR modes)
    table_11    — per-iteration time vs "core" count (data shards)
    table_12    — model-parallelism level sweep (Gisette-shaped)
    fig_9       — GrC initialization on/off
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from repro.core import fspa_reduce, har_reduce, plar_reduce
from repro.data import scaled_paper_dataset

DELTAS = ["PR", "SCE", "LCE", "CCE"]

SMALL_DATASETS = [
    "mushroom", "tic-tac-toe", "dermatology", "kr-vs-kp",
    "breast-cancer-wisconsin", "backup-large", "shuttle",
    "letter-recognition", "ticdata2000",
]


def _dataset(name: str, max_rows=20000, max_attrs=64):
    t = scaled_paper_dataset(name, max_rows=max_rows, max_attrs=max_attrs)
    return t.table()


def table_6_9(deltas=DELTAS, datasets=SMALL_DATASETS, max_rows=8000) -> List[Dict]:
    """Paper Tables 6-9: elapsed time + reduct size for HAR / FSPA / PLAR.

    The paper's effectiveness claim — all three algorithms select identical
    feature subsets — is asserted here, not just reported.
    """
    rows = []
    for name in datasets:
        x, d = _dataset(name, max_rows=max_rows, max_attrs=40)
        for delta in deltas:
            res = {}
            for alg, fn in (("HAR", har_reduce), ("FSPA", fspa_reduce),
                            ("PLAR", plar_reduce)):
                t0 = time.perf_counter()
                r = fn(x, d, delta=delta)
                res[alg] = (time.perf_counter() - t0, r.reduct)
            assert res["HAR"][1] == res["FSPA"][1] == res["PLAR"][1], (
                name, delta, {k: v[1] for k, v in res.items()})
            rows.append({
                "dataset": name, "delta": delta,
                "har_s": round(res["HAR"][0], 3),
                "fspa_s": round(res["FSPA"][0], 3),
                "plar_s": round(res["PLAR"][0], 3),
                "selected": len(res["PLAR"][1]),
                "speedup_plar_vs_har": round(res["HAR"][0] / max(res["PLAR"][0], 1e-9), 2),
            })
    return rows


def table_10(max_rows=60000) -> List[Dict]:
    """Paper Table 10: distributed-algorithm speedup on large datasets.

    HadoopAR-analogue = PLAR with GrC re-built every evaluation (the "reload
    from HDFS each iteration" cost shape); SparkAR-analogue = cached data,
    no GrC compression, no MP; PLAR = full.  Ratios mirror the paper's
    HadoopAR : SparkAR : PLAR ordering.
    """
    rows = []
    for name in ("kdd99", "weka15360"):
        x, d = _dataset(name, max_rows=max_rows, max_attrs=30)
        for delta in DELTAS:
            # HadoopAR-analogue: no cache — re-granulate per candidate (spark
            # mode without GrC) and 1-at-a-time evaluation.
            t0 = time.perf_counter()
            plar_reduce(x, d, delta=delta, grc_init=False, mode="spark",
                        mp_chunk=1, max_features=3, compute_core=False)
            hadoop_s = time.perf_counter() - t0
            # SparkAR-analogue: cached rows, still no GrC compression or MP.
            t0 = time.perf_counter()
            plar_reduce(x, d, delta=delta, grc_init=False, mode="incremental",
                        mp_chunk=1, max_features=3, compute_core=False)
            spark_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            plar_reduce(x, d, delta=delta, grc_init=True, mode="incremental",
                        mp_chunk=64, max_features=3, compute_core=False)
            plar_s = time.perf_counter() - t0
            rows.append({
                "dataset": name, "delta": delta,
                "hadoopAR_s": round(hadoop_s, 3),
                "sparkAR_s": round(spark_s, 3),
                "plar_s": round(plar_s, 3),
                "speedup_sparkAR": round(hadoop_s / max(spark_s, 1e-9), 2),
                "speedup_plar": round(hadoop_s / max(plar_s, 1e-9), 2),
            })
    return rows


def table_11(max_rows=20000, max_attrs=128) -> List[Dict]:
    """Paper Table 11: SDSS-shaped per-iteration time vs worker count.

    CPU has one core; the paper's 32→128-core scaling is emulated by the
    candidate-chunk width (more parallel lanes per XLA call = the MP axis the
    hardware would parallelize).  Reported per-iteration wall time.
    """
    x, d = _dataset("sdss", max_rows=max_rows, max_attrs=max_attrs)
    rows = []
    for lanes in (32, 128):
        def run():
            return plar_reduce(x, d, delta="SCE", mp_chunk=lanes,
                               max_features=1, compute_core=False)

        # warmup with the *timed* configuration: compile caches key on the
        # full static shape (capacity, mp_chunk, max_features), so a sliced
        # warmup would not amortize the device engine's while_loop compile
        run()
        t0 = time.perf_counter()
        run()
        rows.append({"lanes": lanes, "first_iteration_s":
                     round(time.perf_counter() - t0, 3)})
    return rows


def table_12(max_rows=3000, max_attrs=256) -> List[Dict]:
    """Paper Table 12 / Fig 10: model-parallelism level sweep (Gisette-ish)."""
    x, d = _dataset("gisette", max_rows=max_rows, max_attrs=max_attrs)
    rows = []
    base = None
    for level in (1, 2, 4, 8, 16, 32, 64):
        def run():
            return plar_reduce(x, d, delta="SCE", mp_chunk=level,
                               max_features=2, compute_core=False)

        run()  # compile warmup with the timed configuration (see table_11)
        t0 = time.perf_counter()
        run()
        dt = time.perf_counter() - t0
        if base is None:
            base = dt
        rows.append({"mp_level": level, "time_s": round(dt, 3),
                     "speedup_vs_dp": round(base / max(dt, 1e-9), 2)})
    return rows


def fig_9(max_rows=60000) -> List[Dict]:
    """Paper Fig. 9: effect of GrC-based initialization.

    Timed on the SECOND run of each configuration — the first run pays XLA
    compilation, which the paper's steady-state cluster timings exclude (a
    Spark job compiles its stages once too).
    """
    rows = []
    for name in ("kdd99", "weka15360"):
        x, d = _dataset(name, max_rows=max_rows, max_attrs=30)
        for delta in DELTAS:
            def run(grc):
                return plar_reduce(x, d, delta=delta, grc_init=grc,
                                   max_features=3, compute_core=False)

            run(True)                                  # compile warmup
            t0 = time.perf_counter()
            run(True)
            with_grc = time.perf_counter() - t0
            run(False)
            t0 = time.perf_counter()
            run(False)
            without = time.perf_counter() - t0
            rows.append({"dataset": name, "delta": delta,
                         "with_grc_s": round(with_grc, 3),
                         "without_grc_s": round(without, 3),
                         "grc_speedup": round(without / max(with_grc, 1e-9), 2)})
    return rows


ALL_TABLES = {
    "table_6_9": table_6_9,
    "table_10": table_10,
    "table_11": table_11,
    "table_12": table_12,
    "fig_9": fig_9,
}
