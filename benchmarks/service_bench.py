"""Online reduct service benchmark: incremental update vs from-scratch.

The §3.7 subsystem's reason to exist, measured: once a dataset is resident
(its granularity cached on device, its reduct known), absorbing a row batch
costs one monoid merge (O(batch + live granules)) plus a warm-started
repair (prefix folds — no candidate sweeps — and greedy only for what
actually changed), while the batch alternative re-granulates every row seen
so far, recomputes the core, and re-runs greedy from an empty reduct.

Tables are the GrC-compressed latent-factor shapes of engine_bench
(|U/A| ≪ |U|, ≥32 attributes — the acceptance shapes), streamed as a 50%
base + one update batch per measured size.  Both paths are compile-warmed
and best-of-2 timed.  The incremental path's reduct is asserted to reach
the stopping target on the updated table (the repair hard guarantee), and
``same_attrs`` records set-and-length equality with the recompute's reduct:
on these tables the *attribute set* is always identical, while the order
may permute — the recompute force-folds its recomputed core in index order,
the warm path preserves its previous greedy order, and several columns here
relabel the same latent factors so their Θ values tie (see DESIGN.md §3.7
repair semantics; exact list equality on separable paper datasets is
asserted in tests/test_service.py).

Snapshot with ``python -m benchmarks.run --preset service`` →
``benchmarks/BENCH_service.json``.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from .engine_bench import _latent_table


def service_incremental_vs_recompute() -> List[Dict]:
    from repro.core import plar_reduce
    from repro.core.measures import f32_threshold
    from repro.service import DatasetHandle

    delta = "SCE"
    shapes = [
        # (rows, attrs, latent, vmax) — ≥32 attrs are the acceptance shapes
        (40000, 32, 5, 3),
        (40000, 48, 5, 3),
    ]
    update_fracs = [0.01, 0.05, 0.25]
    rows: List[Dict] = []
    for n, a, nl, vmax in shapes:
        x, d = _latent_table(n, a, nl, vmax, seed=n + a)
        base = n // 2

        def fresh_handle():
            h = DatasetHandle.create(x[:base], d[:base], n_dec=2, v_max=vmax)
            h.reduce(delta)          # resident reduct (compile-warms too)
            return h

        fresh_handle()               # warm every compile on the base shape
        for frac in update_fracs:
            un = max(int(n * frac), 1)
            hi = base + un
            xu, du = x[base:hi], d[base:hi]

            best_inc, r_inc, kept = None, None, 0
            for _ in range(2):       # fresh handle per run: same start state
                h = fresh_handle()
                t0 = time.perf_counter()
                h.update(xu, du)
                r_inc = h.reduce(delta)
                dt = time.perf_counter() - t0
                kept = h.last_prefix_kept
                best_inc = dt if best_inc is None else min(best_inc, dt)

            def recompute():
                return plar_reduce(x[:hi], d[:hi], delta=delta, n_dec=2,
                                   v_max=vmax)

            recompute()              # warm the full-table compiles
            best_re, r_re = None, None
            for _ in range(2):
                t0 = time.perf_counter()
                r_re = recompute()
                dt = time.perf_counter() - t0
                best_re = dt if best_re is None else min(best_re, dt)

            # hard guarantee: the repaired reduct reaches the stopping
            # target on the updated table (it is a valid super-reduct)
            assert r_inc.theta_history[-1] <= f32_threshold(
                r_inc.theta_full, 1e-6) + 1e-6, "repair missed the target"
            rows.append({
                "table": f"grc n{hi} A{a} latent{nl}",
                "update_rows": un,
                "prefix_kept": f"{kept}/{len(r_inc.reduct)}",
                "incremental_s": round(best_inc, 3),
                "recompute_s": round(best_re, 3),
                "speedup": round(best_re / max(best_inc, 1e-9), 2),
                "same_attrs": sorted(r_inc.reduct) == sorted(r_re.reduct),
            })
    return rows


ALL_SERVICE_BENCHES = {
    "service_incremental_vs_recompute": service_incremental_vs_recompute,
}
