"""Streaming GrC ingestion benchmark: build throughput + peak RSS.

The paper's premise (PLAR §3.3, Fig. 9) is that the granularity
representation is small enough to cache — but *getting there* used to
require the uncompressed ``(n_rows, n_attrs)`` table resident on the host.
This section measures what the streaming build (DESIGN.md §3.6) buys:

* ``ingest_stream_vs_monolithic`` — same table, both ingestion paths, in
  *separate subprocesses* so each run's ``ru_maxrss`` is a clean per-path
  peak (RSS high-water marks are monotone within a process, so in-process
  before/after deltas would be meaningless).  Streaming peak memory is
  O(chunk + granularity capacity); monolithic is O(n_rows · n_attrs) plus
  the sort's key copies.
* ``ingest_paper_scale`` — the Table-5 flagship kdd99 at its full 5M×41
  shape, streaming only (the whole point: the monolithic path at this shape
  is exactly what we no longer need).  Granule counts are asserted equal
  between paths where both run.

Snapshot with ``python -m benchmarks.run --preset ingest`` →
``benchmarks/BENCH_ingest.json`` (CI runs the preset as a smoke step; the
paper-scale section is included via ``python -m benchmarks.run
ingest_paper_scale --tag ingest`` when refreshing the acceptance evidence).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

_CHILD = r"""
import dataclasses, json, resource, sys, time
mode, name, n_rows, chunk_rows = sys.argv[1], sys.argv[2], int(sys.argv[3]), int(sys.argv[4])
import jax.numpy as jnp
from repro.core import build_granularity, build_granularity_streaming
from repro.data import paper_dataset

t = paper_dataset(name)
if n_rows:
    t = dataclasses.replace(t, n_rows=n_rows)
t0 = time.perf_counter()
if mode == "monolithic":
    x, d = t.table()
    g = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=t.n_dec, v_max=t.v_max)
else:
    g = build_granularity_streaming(t.chunks(chunk_rows), n_dec=t.n_dec, v_max=t.v_max)
out = {
    "granules": int(g.num),
    "elapsed_s": round(time.perf_counter() - t0, 2),
    # linux ru_maxrss is KiB
    "peak_rss_mb": round(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024, 1),
}
print(json.dumps(out))
"""


def _ingest(mode: str, name: str, n_rows: int, chunk_rows: int) -> Dict:
    """Run one ingestion in a fresh python; return its self-reported stats."""
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env = {**os.environ, "PYTHONPATH": src}
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, mode, name, str(n_rows), str(chunk_rows)],
        env=env, capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"ingest child failed:\n{r.stderr}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _row(name: str, mode: str, n_rows: int, chunk_rows: int, stats: Dict) -> Dict:
    return {
        "dataset": name,
        "rows": n_rows,
        "mode": mode,
        "chunk_rows": chunk_rows if mode == "streaming" else "-",
        "granules": stats["granules"],
        "elapsed_s": stats["elapsed_s"],
        "peak_rss_mb": stats["peak_rss_mb"],
        "krows_per_s": round(n_rows / max(stats["elapsed_s"], 1e-9) / 1e3, 1),
    }


def ingest_stream_vs_monolithic() -> List[Dict]:
    """Both paths on a kdd99-shaped table capped to a CI-friendly row count."""
    rows: List[Dict] = []
    shapes = [("kdd99", 1_000_000, 65536), ("shuttle", 58_000, 8192)]
    for name, n_rows, chunk_rows in shapes:
        mono = _ingest("monolithic", name, n_rows, chunk_rows)
        stream = _ingest("streaming", name, n_rows, chunk_rows)
        assert mono["granules"] == stream["granules"], (name, mono, stream)
        rows.append(_row(name, "monolithic", n_rows, chunk_rows, mono))
        rows.append(_row(name, "streaming", n_rows, chunk_rows, stream))
        rows.append({
            "dataset": name, "rows": n_rows, "mode": "rss_ratio",
            "chunk_rows": "-", "granules": "-", "elapsed_s": "-",
            "peak_rss_mb": round(mono["peak_rss_mb"] / stream["peak_rss_mb"], 2),
            "krows_per_s": "-",
        })
    return rows


def ingest_paper_scale() -> List[Dict]:
    """kdd99 at the full Table-5 shape (5M×41), streaming only."""
    name, chunk_rows = "kdd99", 65536
    from repro.data import paper_dataset

    n_rows = paper_dataset(name).n_rows
    stream = _ingest("streaming", name, 0, chunk_rows)
    return [_row(name, "streaming", n_rows, chunk_rows, stream)]


ALL_INGEST_BENCHES = {
    "ingest_stream_vs_monolithic": ingest_stream_vs_monolithic,
}

# Addressable by explicit name only — a ~5-8 min 5M-row build does not
# belong in the no-arg run-everything path (run.py merges these into the
# job table only when a wanted section names them).
EXPLICIT_BENCHES = {
    "ingest_paper_scale": ingest_paper_scale,
}
