from .api import (
    BATCH_AXES, FSDP_AXIS, TP_AXIS,
    active_mesh, axis_size, batch_spec, constrain, make_mesh,
    mesh_batch_shards, resolve_spec, shard_map, sharding_for, use_mesh,
)
from .compression import (
    compressed_grad_mean, compressed_psum_mean, dequantize_int8,
    init_error_state, quantize_int8,
)
from .pipeline_parallel import pipeline_apply, pipeline_loss

__all__ = [
    "BATCH_AXES", "FSDP_AXIS", "TP_AXIS",
    "active_mesh", "axis_size", "batch_spec", "constrain", "make_mesh",
    "mesh_batch_shards", "resolve_spec", "shard_map", "sharding_for", "use_mesh",
    "compressed_grad_mean", "compressed_psum_mean", "dequantize_int8",
    "init_error_state", "quantize_int8",
    "pipeline_apply", "pipeline_loss",
]
