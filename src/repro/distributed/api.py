"""Mesh context + sharding-constraint helpers shared by all model code.

Model code never names a concrete mesh: it calls :func:`constrain` with a
*logical* PartitionSpec.  The active mesh is carried in a context variable set
by the launcher (`use_mesh`); axes absent from the active mesh are silently
dropped, so the same model lowers on the single-pod ``(data, model)`` mesh,
the multi-pod ``(pod, data, model)`` mesh, and bare CPU (no mesh → no-op).
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: ContextVar[Optional[Mesh]] = ContextVar("repro_active_mesh", default=None)


# --- jax version compatibility (DESIGN.md §2) --------------------------------
# `jax.shard_map` / `jax.sharding.AxisType` graduated from experimental after
# 0.4.x; these two shims are the single place the repo adapts, so every call
# site reads identically on old and new jax.


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` where available, else experimental (check_rep API)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma)


def make_mesh(shape, axis_names, *, devices=None):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    kw = {} if devices is None else {"devices": devices}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(shape, axis_names, **kw)

# Canonical logical axes (DESIGN.md §3.3):
#   batch  → ('pod', 'data')   data parallelism (pods are pure DP)
#   fsdp   → 'data'            ZeRO parameter/optimizer sharding
#   tp     → 'model'           tensor parallelism (heads / d_ff / vocab / experts)
BATCH_AXES: Tuple[str, ...] = ("pod", "data")
FSDP_AXIS = "data"
TP_AXIS = "model"


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH.get()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    token = _ACTIVE_MESH.set(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE_MESH.reset(token)


def _filter_spec(spec: Sequence, mesh: Mesh) -> P:
    """Drop mesh axes the active mesh doesn't have (e.g. 'pod' on one pod)."""
    names = set(mesh.axis_names)
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, str):
            out.append(entry if entry in names else None)
        else:  # tuple of axis names
            kept = tuple(a for a in entry if a in names)
            out.append(kept if kept else None)
    return P(*out)


def resolve_spec(spec: Union[P, Sequence], mesh: Optional[Mesh] = None) -> Optional[P]:
    mesh = mesh or active_mesh()
    if mesh is None:
        return None
    return _filter_spec(tuple(spec), mesh)


def sharding_for(spec: Union[P, Sequence], mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or active_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, _filter_spec(tuple(spec), mesh))


def _divisible(entry, dim: int, mesh: Mesh):
    """Drop spec entries whose mesh-axis product does not divide the dim."""
    if entry is None:
        return None
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    kept = []
    prod = 1
    for a in axes:
        size = mesh.shape[a]
        if dim % (prod * size) == 0:
            kept.append(a)
            prod *= size
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


def shard_by_shape(spec: Union[P, Sequence], shape: Tuple[int, ...],
                   mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    """Divisibility-aware NamedSharding: axes that don't divide are dropped
    (pjit in_shardings reject uneven shards; replication is always legal)."""
    mesh = mesh or active_mesh()
    if mesh is None:
        return None
    filtered = tuple(_filter_spec(tuple(spec), mesh))
    entries = [_divisible(e, d, mesh) for e, d in zip(filtered, shape)]
    return NamedSharding(mesh, P(*entries))


def constrain(x: jax.Array, *spec) -> jax.Array:
    """`with_sharding_constraint` against the active mesh (no-op without one).

    Divisibility-aware: axes that don't divide the dimension are dropped
    (e.g. 40 rwkv heads on a 16-way model axis → replicated)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    s = shard_by_shape(P(*spec), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, s)


def batch_spec(*trailing) -> P:
    """P(('pod','data'), *trailing) — the activation batch sharding."""
    return P(BATCH_AXES, *trailing)


def axis_size(name: str, mesh: Optional[Mesh] = None) -> int:
    mesh = mesh or active_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def mesh_batch_shards(mesh: Optional[Mesh] = None) -> int:
    return axis_size("pod", mesh) * axis_size("data", mesh)
