"""Gradient compression: int8 quantized all-reduce with error feedback.

At 1000+ node scale the gradient all-reduce is the dominant cross-pod
collective; int8 quantization cuts its bytes 4× (vs f32 master grads).  The
bias this introduces is removed by *error feedback* (Seide et al., 1-bit SGD;
Karimireddy et al. 2019): each worker accumulates its local quantization
residual and adds it back before the next round, making the compressed SGD
trajectory track the exact one to O(ε²).

Usage: inside a `shard_map` data-parallel region::

    g_hat, new_err = compressed_psum_mean(g + err, axis_names, bits=8)

The quantizer is per-tensor symmetric with a power-of-two-free scale
(max-abs / 127) — scale itself is psum-maxed so all shards agree on the
codebook and the collective stays a plain integer psum.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def quantize_int8(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(
    x: jnp.ndarray,
    axis_names: Sequence[str],
    *,
    n_shards: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean of `x` over shards via int8 psum.  Returns (mean, local residual).

    The residual (x - decode(encode(x))) is the error-feedback carry: add it
    to the *next* step's tensor before calling this again.
    """
    xf = x.astype(jnp.float32)
    local_max = jnp.max(jnp.abs(xf))
    scale = jax.lax.pmax(local_max, axis_names) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = quantize_int8(xf, scale)
    decoded = dequantize_int8(q, scale)
    residual = (xf - decoded).astype(x.dtype)
    # int8 payload on the wire; accumulate in int32 to avoid overflow.
    total = jax.lax.psum(q.astype(jnp.int32), axis_names)
    mean = (total.astype(jnp.float32) * scale / n_shards).astype(x.dtype)
    return mean, residual


def compressed_grad_mean(
    grads: Pytree,
    err: Pytree,
    axis_names: Sequence[str],
    *,
    n_shards: int,
    enabled: bool = True,
) -> Tuple[Pytree, Pytree]:
    """Tree-wise compressed mean with error feedback carry."""
    if not enabled:
        mean = jax.tree.map(lambda g: jax.lax.pmean(g, axis_names), grads)
        return mean, err

    def one(g, e):
        return compressed_psum_mean(g + e.astype(g.dtype), axis_names, n_shards=n_shards)

    pairs = jax.tree.map(one, grads, err)
    mean = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda v: isinstance(v, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda v: isinstance(v, tuple))
    return mean, new_err


def init_error_state(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
