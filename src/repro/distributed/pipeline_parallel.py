"""GPipe-style pipeline parallelism via shard_map + ppermute.

A library feature (DESIGN.md §3.3): the graded dry-run meshes are DP×TP —
the fixed (pod, data, model) topology — but the framework supports PP for
meshes that include a 'pipe' axis.  Tests exercise it on a small host-device
mesh and assert exact equivalence with the unpipelined stack.

Schedule: the classic GPipe loop.  With S stages and M microbatches, the
loop runs S-1+M ticks; on tick t stage s processes microbatch t-s (a bubble
of (S-1)/(S-1+M) idle fraction — every stage computes every tick, with
masked inputs during fill/drain; the waste is the textbook bubble, amortized
by M ≫ S).  `ppermute` shifts activations stage→stage+1 each tick.

The whole loop is differentiable (ppermute transposes to the reverse
permutation), so the same function trains — 1F1B re-ordering is a §Perf
note, not a correctness requirement.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .api import shard_map

Pytree = Any


def pipeline_apply(
    stage_fn: Callable,          # (stage_params, x_micro) -> y_micro
    mesh: Mesh,
    *,
    axis: str = "pipe",
) -> Callable:
    """Build a pipelined apply: (stacked_params, x [M, mb, ...]) → y [M, mb, ...].

    ``stacked_params`` leaves carry a leading [S] stage dim (sharded over
    `axis`); microbatches stream through stages in S-1+M ticks.
    """
    n_stages = mesh.shape[axis]

    def pipelined(params_stacked, xs):
        def local(params_local, x_local):
            # params_local: leaves [1, ...] (this stage); x_local [M, mb, ...]
            params_here = jax.tree.map(lambda p: p[0], params_local)
            m = x_local.shape[0]
            stage = jax.lax.axis_index(axis)
            n_ticks = n_stages - 1 + m

            buf = jnp.zeros_like(x_local[0])
            out = jnp.zeros_like(x_local)

            def tick(carry, t):
                buf, out = carry
                # stage 0 ingests microbatch t (when in range); others take
                # the activation handed over by the previous stage.
                mb_idx = jnp.clip(t, 0, m - 1)
                inject = jax.lax.dynamic_index_in_dim(x_local, mb_idx, 0, keepdims=False)
                x_in = jnp.where(stage == 0, inject, buf)
                y = stage_fn(params_here, x_in)
                # last stage emits microbatch t-(S-1) (when in range)
                out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
                emit = (stage == n_stages - 1) & (t >= n_stages - 1)
                out = jax.lax.cond(
                    emit,
                    lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
                    lambda o: o,
                    out,
                )
                # hand over to the next stage (ring shift; wrap value unused)
                buf = jax.lax.ppermute(
                    y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
                )
                return (buf, out), None

            (buf, out), _ = jax.lax.scan(tick, (buf, out), jnp.arange(n_ticks))
            # every stage holds zeros except the last: share the result
            out = jax.lax.psum(
                jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)), axis
            )
            return out

        return shard_map(
            local,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: P(axis), params_stacked),
                P(),                      # microbatches replicated per stage
            ),
            out_specs=P(),
            check_vma=False,
        )(params_stacked, xs)

    return pipelined


def pipeline_loss(
    stage_fn: Callable,
    loss_fn: Callable,           # (y [M, mb, ...], labels [M, mb, ...]) -> scalar
    mesh: Mesh,
    *,
    axis: str = "pipe",
) -> Callable:
    """Differentiable pipelined loss for training (GPipe fwd + autodiff bwd)."""
    fwd = pipeline_apply(stage_fn, mesh, axis=axis)

    def fn(params_stacked, xs, labels):
        ys = fwd(params_stacked, xs)
        return loss_fn(ys, labels)

    return fn
