"""Online reduct service demo (DESIGN.md §3.7/§3.9):

    python -m repro.launch.reduce_server --dataset kdd99 --delta SCE
    python -m repro.launch.reduce_server --dataset shuttle --updates 8 --json
    python -m repro.launch.reduce_server --clients 8 --serial   # PR 5 baseline

Drives a paper dataset through :class:`repro.service.ReductServer` as a live
stream: the first half of the table creates the dataset, the second half
arrives in ``--updates`` row batches, and the reduct is re-queried after
every batch.  Each query coalesces the pending batch, folds it into the
device-resident granularity (one monoid merge), and *repairs* the previous
reduct (warm-started selection) instead of recomputing it — the per-update
latency column against the from-scratch recompute at the end is the point
of the subsystem.  The final reduct is checked against a batch
``plar_reduce`` over the full table.

``--clients K`` adds K concurrent mixed-measure clients per round: their
queries land in one scheduler window and are served by stacked batched
dispatch (§3.9); the closing metrics block shows batch occupancy, dedup
hits, and sustained qps.  ``--serial`` runs the single-flight baseline
instead; ``--max-queue`` bounds admission.

Resilience (§3.10): ``--checkpoint-dir DIR`` makes the handle state
durable — run once, kill it, run again with the same DIR and the dataset
restores from the checkpoint (the first query is a warm repair, not a cold
rebuild).  ``--fault-plan SPEC`` injects deterministic failures (e.g.
``dispatch@1x2,merge@0``) which the retry/quarantine/stale layer absorbs;
the closing resilience line counts what fired.

Observability (§3.11): ``--metrics-port PORT`` serves the Prometheus text
exposition (process + server registries) on ``/metrics``;
``--stats-interval SECS`` prints a one-line registry snapshot to stderr
every interval; ``--trace-out PATH`` enables the flight recorder and
exports the Perfetto/Chrome-trace JSON on shutdown (open at
https://ui.perfetto.dev).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import threading
import time


def _serve_metrics(port: int, registries):
    """The Prometheus exposition on a daemon thread; returns the server."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    from repro import obs

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            body = obs.render_prometheus(extra=registries).encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):  # stderr belongs to --stats-interval
            pass

    httpd = ThreadingHTTPServer(("", port), Handler)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    return httpd


def _stats_reporter(interval: float, registries, stop: threading.Event):
    """One-line merged registry snapshot to stderr every ``interval`` s."""
    from repro import obs

    def run():
        while not stop.wait(interval):
            snap = obs.get_registry().snapshot()
            for reg in registries:
                snap.update(reg.snapshot())
            print(f"[stats] {json.dumps(snap, sort_keys=True)}",
                  file=sys.stderr, flush=True)

    t = threading.Thread(target=run, daemon=True)
    t.start()
    return t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="kdd99")
    ap.add_argument("--delta", default="SCE", choices=["PR", "SCE", "LCE", "CCE"])
    ap.add_argument("--rows", type=int, default=20000,
                    help="row cap for the scaled dataset")
    ap.add_argument("--attrs", type=int, default=64, help="attribute cap")
    ap.add_argument("--updates", type=int, default=4,
                    help="update batches streaming in the second half")
    ap.add_argument("--clients", type=int, default=0,
                    help="extra concurrent mixed-measure clients per round "
                         "(exercises §3.9 batched dispatch)")
    ap.add_argument("--serial", action="store_true",
                    help="single-flight worker (the PR 5 baseline)")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="admission-control queue depth")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="durable DatasetHandle checkpoints (§3.10): restore "
                         "on start, background save after merges, final "
                         "blocking save at stop")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault schedule, e.g. "
                         "'dispatch@1x2,merge@0,checkpoint@0' "
                         "(see repro.service.faults)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the Prometheus text exposition (process + "
                         "server registries) on this port at /metrics")
    ap.add_argument("--stats-interval", type=float, default=None,
                    metavar="SECS",
                    help="print a one-line registry snapshot to stderr "
                         "every SECS seconds")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable flight-recorder tracing and export the "
                         "Perfetto/Chrome-trace JSON here on shutdown")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    from repro import obs
    from repro.core import plar_reduce
    from repro.data import scaled_paper_dataset
    from repro.service import FaultPlan, ReductServer, RetryPolicy

    if args.trace_out:
        obs.enable()

    stream = scaled_paper_dataset(args.dataset, max_rows=args.rows,
                                  max_attrs=args.attrs)
    x, d = stream.table()
    half = len(x) // 2
    rest = len(x) - half

    # K extra clients fan across the other measures (round-robin): a window
    # of mixed-measure queries per round, served by ONE stacked dispatch
    others = [m for m in ("PR", "SCE", "LCE", "CCE") if m != args.delta]
    client_measures = [others[i % len(others)] for i in range(args.clients)]

    fault_plan = FaultPlan.parse(args.fault_plan) if args.fault_plan else None

    server = ReductServer(batching=not args.serial,
                          max_queue=args.max_queue,
                          checkpoint_dir=args.checkpoint_dir,
                          fault_plan=fault_plan,
                          retry=RetryPolicy(),
                          serve_stale=fault_plan is not None)

    httpd = None
    if args.metrics_port is not None:
        httpd = _serve_metrics(args.metrics_port, [server.registry])
        print(f"[metrics] http://localhost:{args.metrics_port}/metrics",
              file=sys.stderr, flush=True)
    stats_stop = threading.Event()
    if args.stats_interval:
        _stats_reporter(args.stats_interval, [server.registry], stats_stop)

    async def drive():
        async with server as srv:
            if "live" not in srv._handles:  # absent unless restored (§3.10)
                await srv.submit("live", x[:half], d[:half],
                                 n_dec=stream.n_dec, v_max=stream.v_max)
            events = []

            async def round_query(tag, rows):
                t0 = time.perf_counter()
                coros = [srv.query("live", delta=args.delta)]
                coros += [srv.query("live", delta=m) for m in client_measures]
                rs = await asyncio.gather(*coros)
                req = srv.requests[-1]
                events.append({
                    "event": tag, "rows": rows,
                    "granules": srv.handle("live").n_granules,
                    "reduct": rs[0].reduct,
                    "prefix_kept": req.prefix_kept,
                    "clients": 1 + len(client_measures),
                    "latency_s": round(time.perf_counter() - t0, 3)})
                return rs[0]

            r = await round_query("cold", half)
            for i in range(args.updates):
                lo = half + i * rest // args.updates
                hi = half + (i + 1) * rest // args.updates
                await srv.update("live", x[lo:hi], d[lo:hi])
                r = await round_query(f"update_{i + 1}", hi - lo)
            return r, events, dict(srv.stats), srv.metrics.summary()

    try:
        final, events, stats, metrics = asyncio.run(drive())
    finally:
        stats_stop.set()
        if httpd is not None:
            httpd.shutdown()
        if args.trace_out:
            tracer = obs.get_tracer()
            tracer.export(args.trace_out)
            print(f"[trace] {len(tracer.records())} spans -> "
                  f"{args.trace_out} (open at https://ui.perfetto.dev)",
                  file=sys.stderr, flush=True)

    # the from-scratch baseline the incremental path replaces
    t0 = time.perf_counter()
    batch = plar_reduce(x, d, delta=args.delta, n_dec=stream.n_dec,
                        v_max=stream.v_max)
    recompute_s = time.perf_counter() - t0
    warm_lat = [e["latency_s"] for e in events if e["event"] != "cold"]

    out = {
        "dataset": args.dataset, "delta": args.delta,
        "table_shape": [len(x), x.shape[1]],
        "scheduler": "single-flight" if args.serial else "batched",
        "clients": 1 + len(client_measures),
        "events": events, "stats": stats, "metrics": metrics,
        "final_reduct": final.reduct,
        "batch_reduct": batch.reduct,
        "reduct_matches_batch": final.reduct == batch.reduct,
        "full_recompute_s": round(recompute_s, 3),
        "mean_update_latency_s": round(sum(warm_lat) / max(len(warm_lat), 1), 3),
    }
    if args.json:
        print(json.dumps(out, indent=2))
    else:
        for e in events:
            extra = (f"  prefix_kept={e['prefix_kept']}"
                     if "prefix_kept" in e else "")
            print(f"{e['event']:>10}: rows+{e['rows']:<7} "
                  f"granules={e['granules']:<6} {e['latency_s']:6.3f}s  "
                  f"reduct={e['reduct']}{extra}")
        print(f"\nfull recompute: {out['full_recompute_s']}s   "
              f"mean update latency: {out['mean_update_latency_s']}s")
        print(f"scheduler={out['scheduler']} clients={out['clients']}  "
              f"engine_runs={stats['engine_runs']} "
              f"dedup_hits={stats['dedup_hits']} "
              f"occupancy={metrics['mean_batch_occupancy']} "
              f"qps={metrics['qps_sustained']} "
              f"latency_p99={metrics['latency_p99_s']}s")
        if args.checkpoint_dir or args.fault_plan:
            print(f"resilience: restored={stats['restored_datasets']} "
                  f"checkpoints={stats['checkpoints']} "
                  f"retries={stats['retries']} "
                  f"quarantined={stats['quarantined']} "
                  f"stale_served={stats['stale_served']} "
                  f"flushed={stats['flushed_batches']}")
        print(f"final reduct matches batch plar_reduce: "
              f"{out['reduct_matches_batch']}")


if __name__ == "__main__":
    main()
