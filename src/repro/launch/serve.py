"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--smoke]``."""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8),
                    max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]
    for r in engine.serve(reqs):
        print(f"req {r.rid}: {r.output}  ({r.latency_s*1e3:.0f} ms)")


if __name__ == "__main__":
    main()
