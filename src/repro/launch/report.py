"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from runs/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report --dir runs/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

GB = 1024 ** 3


def load(dir_: str) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_s(v):
    if v is None:
        return "—"
    if v >= 1:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v*1e3:.1f}ms"
    return f"{v*1e6:.0f}µs"


def dryrun_table(records: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | compile | HBM/dev (args+out+temp) | flops/dev | wire B/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped¹ | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"**ERROR** | — | — | — | — |")
            continue
        m = r.get("memory_per_device", {})
        hbm = (m.get("argument_bytes", 0) + m.get("output_bytes", 0)
               + m.get("temp_bytes", 0) - m.get("alias_bytes", 0))
        wire = (r.get("collectives", {}) or {}).get("total_wire_bytes_per_device")
        if wire is None:
            wire = (r.get("collectives", {}) or {}).get("total_wire_bytes")
        flops = r.get("flops_per_device")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('compile_s', '—')}s | {hbm/GB:.1f} GiB | "
            f"{flops/1e12:.2f}T | {(wire or 0)/1e9:.2f} GB |"
        )
    lines.append("")
    lines.append("¹ long_500k on full-attention archs — skipped per DESIGN.md §4.")
    return "\n".join(lines)


def roofline_table(records: List[Dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | model TF | useful % | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        rf = r.get("roofline")
        if not rf:
            continue
        ratio = r.get("useful_flops_ratio")
        hint = dominant_hint(r)
        mf = r.get("model_flops_total", 0) / 1e12
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"**{rf['dominant']}** | {mf:.0f} | "
            f"{'' if ratio is None else f'{ratio*100:.0f}%'} | {hint} |"
        )
    return "\n".join(lines)


def dominant_hint(r: Dict) -> str:
    rf = r.get("roofline", {})
    dom = rf.get("dominant")
    shape = r.get("shape", "")
    if dom == "collective":
        return ("smaller FSDP all-gathers (widen DP-only for small models) / "
                "overlap collectives with compute")
    if dom == "memory":
        if "decode" in shape or "long" in shape:
            return "KV-cache quantization / larger decode batch amortizes weight reads"
        return "flash-attention bwd recompute (kill scan-carry saves) / fused remat"
    return "causal block-skip in prefill / MXU-aligned tiles"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--what", default="all", choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    records = load(args.dir)
    print(f"<!-- {len(records)} records from {args.dir} -->\n")
    if args.what in ("all", "dryrun"):
        print("## §Dry-run (both meshes)\n")
        print(dryrun_table(records))
        print()
    if args.what in ("all", "roofline"):
        print("## §Roofline (single-pod, 256 chips)\n")
        print(roofline_table(records, "single"))
        print("\n### multi-pod (512 chips)\n")
        print(roofline_table(records, "multi"))


if __name__ == "__main__":
    main()
