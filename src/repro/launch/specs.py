"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every cell.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these.  One builder per step kind; each returns

    (fn, arg_shapes: tuple, arg_shardings: tuple, donate: tuple[int])
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.api import BATCH_AXES, sharding_for, use_mesh
from repro.models import build_model
from repro.models.config import ArchConfig, SHAPES, ShapeConfig
from repro.train import AdamW, constant_schedule, make_train_step

BIG_PARAM_THRESHOLD = 50e9      # ≥: bf16 optimizer moments (HBM budget)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _batch_sharding(mesh: Mesh, batch_size: int, extra_dims: int):
    """Batch sharded over (pod, data) when divisible, else replicated."""
    n = 1
    for a in BATCH_AXES:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    spec = P(BATCH_AXES, *([None] * extra_dims)) if batch_size % n == 0 \
        else P(*([None] * (extra_dims + 1)))
    return sharding_for(spec, mesh)


def _train_batch(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    b, s = shape.global_batch, shape.seq_len
    shapes: Dict[str, Any] = {}
    shard: Dict[str, Any] = {}
    if cfg.is_encdec:
        dec = s // 4
        shapes["frames"] = _sds((b, s, cfg.frontend_dim), "float32")
        shapes["tokens"] = _sds((b, dec), "int32")
        shapes["labels"] = _sds((b, dec), "int32")
        shard["frames"] = _batch_sharding(mesh, b, 2)
        shard["tokens"] = shard["labels"] = _batch_sharding(mesh, b, 1)
        return shapes, shard
    shapes["tokens"] = _sds((b, s), "int32")
    shapes["labels"] = _sds((b, s), "int32")
    shard["tokens"] = shard["labels"] = _batch_sharding(mesh, b, 1)
    if cfg.frontend == "vision":
        shapes["frontend_feats"] = _sds((b, cfg.frontend_tokens, cfg.frontend_dim), "float32")
        shard["frontend_feats"] = _batch_sharding(mesh, b, 2)
    return shapes, shard


def moment_dtype_for(cfg: ArchConfig) -> str:
    return "bfloat16" if cfg.param_count() >= BIG_PARAM_THRESHOLD else "float32"


def make_train_setup(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                     *, microbatches: int = 1):
    model = build_model(cfg)
    opt = AdamW(lr=constant_schedule(3e-4), moment_dtype=moment_dtype_for(cfg))
    fn = make_train_step(model, opt, microbatches=microbatches)

    with use_mesh(mesh):
        pshapes = model.param_shapes()
        psh = model.param_shardings(mesh)
        mdt = jnp.dtype(moment_dtype_for(cfg))
        mshapes = jax.tree.map(lambda sd: _sds(sd.shape, mdt), pshapes)
        state_shapes = {"params": pshapes, "opt_m": mshapes, "opt_v": mshapes,
                        "opt_step": _sds((), "int32")}
        state_sh = {"params": psh, "opt_m": psh, "opt_v": psh,
                    "opt_step": sharding_for(P(), mesh)}
        batch_shapes, batch_sh = _train_batch(cfg, shape, mesh)
    return fn, (state_shapes, batch_shapes), (state_sh, batch_sh), (0,)


def make_prefill_setup(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    model = build_model(cfg)
    b, s = shape.global_batch, shape.seq_len
    with use_mesh(mesh):
        pshapes = model.param_shapes()
        psh = model.param_shardings(mesh)
        if cfg.is_encdec:
            batch_shapes = {"frames": _sds((b, s, cfg.frontend_dim), "float32")}
            batch_sh = {"frames": _batch_sharding(mesh, b, 2)}
            fn = lambda params, batch: model.prefill(params, batch, cache_len=1024)
        else:
            batch_shapes = {"tokens": _sds((b, s), "int32")}
            batch_sh = {"tokens": _batch_sharding(mesh, b, 1)}
            if cfg.frontend == "vision":
                batch_shapes["frontend_feats"] = _sds(
                    (b, cfg.frontend_tokens, cfg.frontend_dim), "float32")
                batch_sh["frontend_feats"] = _batch_sharding(mesh, b, 2)
            fn = lambda params, batch: model.prefill(params, batch, cache_len=s)
    return fn, (pshapes, batch_shapes), (psh, batch_sh), ()


def _attn_cache_len(cfg: ArchConfig, shape: ShapeConfig) -> int:
    s = shape.seq_len
    if cfg.window and s > cfg.window:
        return cfg.window            # rolling-window cache (jamba long_500k)
    return s


def make_decode_setup(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    model = build_model(cfg)
    b = shape.global_batch
    with use_mesh(mesh):
        pshapes = model.param_shapes()
        psh = model.param_shardings(mesh)
        if cfg.is_encdec:
            cache_shapes = model.cache_shapes(b, 1024, shape.seq_len)
            cache_sh = model.cache_shardings(b, 1024, shape.seq_len, mesh)
        else:
            clen = _attn_cache_len(cfg, shape)
            cache_shapes = model.cache_shapes(b, clen)
            cache_sh = model.cache_shardings(b, clen, mesh)
        tok_shapes = _sds((b, 1), "int32")
        len_shapes = _sds((b,), "int32")
        tok_sh = _batch_sharding(mesh, b, 1)
        len_sh = _batch_sharding(mesh, b, 0)
        fn = lambda params, cache, tokens, lengths: model.decode(
            params, cache, tokens, lengths)
    return (fn, (pshapes, cache_shapes, tok_shapes, len_shapes),
            (psh, cache_sh, tok_sh, len_sh), (1,))


def make_setup(cfg: ArchConfig, shape_name: str, mesh: Mesh, **kw):
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return make_train_setup(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return make_prefill_setup(cfg, shape, mesh)
    return make_decode_setup(cfg, shape, mesh)


def probe_config(cfg: ArchConfig, n_periods: int) -> ArchConfig:
    """Unrolled shallow clone for the cost-extrapolation probes."""
    from repro.models.blocks import layer_pattern
    if cfg.is_encdec:
        return dataclasses.replace(
            cfg, enc_layers=n_periods, dec_layers=n_periods,
            n_layers=n_periods, scan_unroll=True, remat=False, attn_naive=True,
        )
    period = len(layer_pattern(cfg)[0])
    return dataclasses.replace(
        cfg, n_layers=period * n_periods, scan_unroll=True, remat=False,
        attn_naive=True,
    )


def n_periods_of(cfg: ArchConfig) -> int:
    from repro.models.blocks import layer_pattern
    if cfg.is_encdec:
        return cfg.enc_layers  # enc+dec scale together in probe_config
    return layer_pattern(cfg)[1]
