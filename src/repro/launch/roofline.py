"""Roofline terms from compiled dry-run artifacts (no hardware required).

Hardware constants: TPU v5e-class — 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI (per the brief).  This module is the one home for those
constants: the analytic kernel-cost selector
(:mod:`repro.kernels.contingency.model`) ranks candidate tilings on the
same :func:`roofline_terms` bound.

Three terms per (arch × shape × mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_device / 197e12
    memory     = HLO_bytes_per_device / 819e9
    collective = wire_bytes_per_device / 50e9

FLOPs/bytes come from ``compiled.cost_analysis()``.  CAVEAT (measured, see
EXPERIMENTS.md §Dry-run): XLA's cost analysis counts a ``while`` body ONCE,
so the scanned-layer production artifact under-reports by ~n_layers×.  The
driver therefore lowers two *unrolled probe* configs (1 and 2 periods) and
extrapolates linearly:

    total(P) = cost(p1) + (P - 1) · (cost(p2) - cost(p1))

which is exact for a layer-homogeneous stack (embed/logits cancel in the
difference).  Collectives are parsed from the probes' post-SPMD HLO text the
same way and extrapolated with the same rule.

Wire bytes use the ring model per op kind (n = collective group size):
    all-reduce       2·(n-1)/n · bytes
    all-gather         (n-1)/n · bytes(result)
    reduce-scatter     (n-1)   · bytes(result)     (input = n · result)
    all-to-all         (n-1)/n · bytes
    collective-permute          bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]     # Σ result sizes per kind
    wire_bytes: Dict[str, float]     # ring-model per-device wire bytes per kind

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def as_dict(self):
        return {"counts": self.counts, "result_bytes": self.result_bytes,
                "wire_bytes": self.wire_bytes,
                "total_wire_bytes": self.total_wire_bytes}


_CONVERT_RE = re.compile(
    r"= ([a-z0-9]+)\[([0-9,]*)\][^ ]* (convert|bitcast-convert|copy)\("
)


def parse_convert_bytes(hlo_text: str) -> int:
    """Result bytes of dtype-convert/copy ops (CPU bf16-emulation artifacts).

    XLA:CPU emulates bf16 arithmetic by converting to f32 and back; those
    converts are absent on TPU (native bf16 MXU/VPU).  The §Roofline memory
    term is reported both raw and convert-corrected (raw − 2×convert bytes):
    the corrected value is the TPU expectation.
    """
    total = 0
    for m in _CONVERT_RE.finditer(hlo_text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    rbytes: Dict[str, int] = {}
    wbytes: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group("kind")
        result = m.group("result")
        size = _shape_bytes(result)
        # group size: look ahead in the same line
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start(): line_end if line_end > 0 else len(hlo_text)]
        n = None
        g = _GROUPS_BRACE_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g = _GROUPS_IOTA_RE.search(line)
            if g:
                n = int(g.group(2))
        if n is None or n <= 1:
            n = 2  # conservative default if groups elided
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * size
        elif kind == "all-gather":
            wire = (n - 1) / n * size
        elif kind == "reduce-scatter":
            wire = float(n - 1) * size
        elif kind == "all-to-all":
            wire = (n - 1) / n * size
        else:  # collective-permute
            wire = float(size)
        counts[kind] = counts.get(kind, 0) + 1
        rbytes[kind] = rbytes.get(kind, 0) + size
        wbytes[kind] = wbytes.get(kind, 0.0) + wire
    return CollectiveStats(counts, rbytes, wbytes)


def extrapolate(p1: float, p2: float, n_periods: int) -> float:
    """total(P) = p1 + (P-1)·(p2-p1); clamps tiny negative diffs to 0."""
    delta = max(p2 - p1, 0.0)
    return p1 + (n_periods - 1) * delta


def extrapolate_collectives(s1: CollectiveStats, s2: CollectiveStats,
                            n_periods: int) -> Dict[str, float]:
    kinds = set(s1.wire_bytes) | set(s2.wire_bytes)
    out = {}
    for k in kinds:
        out[k] = extrapolate(s1.wire_bytes.get(k, 0.0), s2.wire_bytes.get(k, 0.0),
                             n_periods)
    return out


# ---------------------------------------------------------------------------
# analytic model FLOPs (the "useful work" yardstick)
# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> float:
    """6·N_active·D for training; 2·N_active·D + attention reads for serving.

    Attention scores/values add 4·B·Hq·Dh·S_q·S_kv per attention layer
    (halved for causal).  This is the textbook MFU numerator — compiled
    FLOPs above this are remat/padding/capacity waste.
    """
    n_active = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len
    kinds = cfg.block_kinds()
    n_attn = sum(1 for k in kinds if k == "attn")
    if cfg.is_encdec:
        n_attn = cfg.enc_layers + 2 * cfg.dec_layers

    if shape.kind == "train":
        if cfg.is_encdec:
            dec = s // 4
            tokens = b * (s + dec)
            attn = 4 * b * cfg.n_heads * cfg.hd * (
                cfg.enc_layers * s * s
                + cfg.dec_layers * dec * dec * 0.5
                + cfg.dec_layers * dec * s
            )
        else:
            tokens = b * s
            attn = 2 * b * cfg.n_heads * cfg.hd * n_attn * (
                min(s, cfg.window or s) * s
            )  # causal ⇒ ×1/2 of 4·S² (window caps the span)
        return 6.0 * n_active * tokens + 3.0 * attn

    if shape.kind == "prefill":
        if cfg.is_encdec:
            tokens = b * s
            attn = 4 * b * cfg.n_heads * cfg.hd * cfg.enc_layers * s * s
        else:
            tokens = b * s
            attn = 2 * b * cfg.n_heads * cfg.hd * n_attn * min(s, cfg.window or s) * s
        return 2.0 * n_active * tokens + attn

    # decode: one token per sequence
    span = min(s, cfg.window or s)
    if cfg.is_encdec:
        attn = 4 * b * cfg.n_heads * cfg.hd * cfg.dec_layers * (s + 1)
        return 2.0 * n_active * b + attn
    attn = 4 * b * cfg.n_heads * cfg.hd * n_attn * span
    return 2.0 * n_active * b + attn


def roofline_terms(flops_dev: float, bytes_dev: float, wire_bytes_dev: float):
    compute = flops_dev / PEAK_FLOPS
    memory = bytes_dev / HBM_BW
    collective = wire_bytes_dev / ICI_BW
    dominant = max(
        [("compute", compute), ("memory", memory), ("collective", collective)],
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "bound_s": max(compute, memory, collective),
    }
