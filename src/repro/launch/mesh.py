"""Production mesh construction (the brief's fixed topology).

A FUNCTION, not a module constant: importing this module never touches jax
device state (device count is locked at first backend init, so the dry-run
must set XLA_FLAGS before any jax call — see dryrun.py lines 1–2).
"""
from __future__ import annotations

import jax

from repro.distributed.api import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=None):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return make_mesh(shape, axes)
