import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count at backend
init, and the production meshes need 512 placeholder devices.

Per cell this driver produces:
  * the compile proof — ``jax.jit(step).lower(**input_specs).compile()``
    succeeds on the scanned production config;
  * ``memory_analysis()`` — per-device bytes (argument/output/temp);
  * roofline inputs — FLOPs / bytes / collective wire bytes, via the
    1-period/2-period unrolled probe extrapolation (see roofline.py for why
    the scanned artifact alone cannot give loop-correct costs).

Usage::

    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out runs/dryrun
    python -m repro.launch.dryrun --plar --mesh multi       # the paper's own workload

``--all`` spawns one subprocess per cell (compiler arenas do not shrink;
isolation keeps the 80-compile sweep bounded) and skips cells whose output
JSON already exists.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback


def _build_mesh(kind: str):
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(kind == "multi"))


def _parse_overrides(pairs):
    out = {}
    for pair in pairs or []:
        k, v = pair.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, skip_probes: bool = False,
             setup_kw=None, overrides=None) -> dict:
    import jax
    from repro.configs import get_config, shape_applies
    from repro.distributed.api import use_mesh
    from repro.launch import roofline as rl
    from repro.launch.specs import make_setup, n_periods_of, probe_config
    from repro.models.config import SHAPES

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not shape_applies(cfg, shape):
        record["status"] = "skipped"
        record["reason"] = "long_500k requires sub-quadratic attention (DESIGN.md §4)"
        return record

    mesh = _build_mesh(mesh_kind)
    n_chips = mesh.devices.size
    record["chips"] = int(n_chips)
    setup_kw = setup_kw or {}

    def lower_compile(config, collect_text: bool):
        fn, shapes, shardings, donate = make_setup(config, shape_name, mesh, **setup_kw)
        with use_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
            lowered = jitted.lower(*shapes)
            compiled = lowered.compile()
        out = {
            "cost": compiled.cost_analysis(),
            "memory": compiled.memory_analysis(),
            "text": compiled.as_text() if collect_text else None,
        }
        return out

    # 1) compile proof on the full scanned config
    t0 = time.time()
    full = lower_compile(cfg, collect_text=False)
    record["compile_s"] = round(time.time() - t0, 1)
    ma = full["memory"]
    record["memory_per_device"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "total_hbm_bytes": ma.argument_size_in_bytes + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
    }
    record["scanned_cost"] = {
        "flops_per_device": full["cost"].get("flops", 0.0),
        "bytes_per_device": full["cost"].get("bytes accessed", 0.0),
    }

    if skip_probes:
        record["status"] = "ok"
        return record

    # 2) probe configs for loop-correct costs.
    #    FLOPs: naive-attention probes (every flop visible, no inner loops).
    #    Bytes + collectives: production-path probes (the chunked/flash
    #    implementation whose HBM traffic we actually ship).
    n_periods = n_periods_of(cfg)
    t0 = time.time()
    p1n = lower_compile(probe_config(cfg, 1), collect_text=False)
    p2n = lower_compile(probe_config(cfg, 2), collect_text=False)
    p1f = lower_compile(
        dataclasses.replace(probe_config(cfg, 1), attn_naive=False), collect_text=True)
    p2f = lower_compile(
        dataclasses.replace(probe_config(cfg, 2), attn_naive=False), collect_text=True)
    record["probe_s"] = round(time.time() - t0, 1)

    flops = rl.extrapolate(p1n["cost"].get("flops", 0.0),
                           p2n["cost"].get("flops", 0.0), n_periods)
    bytes_ = rl.extrapolate(p1f["cost"].get("bytes accessed", 0.0),
                            p2f["cost"].get("bytes accessed", 0.0), n_periods)
    conv = rl.extrapolate(rl.parse_convert_bytes(p1f["text"]),
                          rl.parse_convert_bytes(p2f["text"]), n_periods)
    bytes_corrected = max(bytes_ - 2.0 * conv, bytes_ * 0.1)
    c1 = rl.parse_collectives(p1f["text"])
    c2 = rl.parse_collectives(p2f["text"])
    wire = rl.extrapolate_collectives(c1, c2, n_periods)
    wire_total = sum(wire.values())

    record["flops_per_device"] = flops
    record["bytes_per_device"] = bytes_
    record["convert_bytes_per_device"] = conv
    record["bytes_per_device_tpu_corrected"] = bytes_corrected
    record["collectives"] = {
        "p1": c1.as_dict(), "p2": c2.as_dict(),
        "extrapolated_wire_bytes": wire,
        "total_wire_bytes_per_device": wire_total,
    }
    record["roofline"] = rl.roofline_terms(flops, bytes_, wire_total)
    record["roofline_tpu_corrected"] = rl.roofline_terms(
        flops, bytes_corrected, wire_total)
    mf = rl.model_flops(cfg, shape)
    record["model_flops_total"] = mf
    record["model_flops_per_device"] = mf / n_chips
    record["useful_flops_ratio"] = (mf / n_chips) / flops if flops else None
    record["status"] = "ok"
    return record


def run_plar_cell(mesh_kind: str, *, collective: str = "all_reduce",
                  table_dtype: str = "int32", fused_pack: bool = False) -> dict:
    """The paper's own workload: one PLAR greedy-loop iteration at SDSS scale
    (320k granules × 5201 candidate attributes), lowered on the production
    mesh: candidates over 'model', granules over ('pod','data')."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.distributed import _eval_step, _advance_step
    from repro.launch import roofline as rl

    mesh = _build_mesh(mesh_kind)
    n_chips = mesh.devices.size
    record = {"arch": "plar-sdss", "shape": "eval_iteration", "mesh": mesh_kind,
              "chips": int(n_chips), "collective": collective,
              "table_dtype": table_dtype, "fused_pack": fused_pack}

    G, A, V, M = 327_680, 5_216, 8, 17    # SDSS-shaped, padded to shard multiples
    K = 64                                 # reduct classes mid-loop
    n_bins = K * V
    ev = _eval_step(mesh, "SCE", n_bins, M, V, collective,
                    table_dtype=table_dtype, fused_pack=fused_pack)
    adv = _advance_step(mesh, "SCE", n_bins, M, V)

    tdt = jnp.dtype(table_dtype)
    shapes = (
        jax.ShapeDtypeStruct((A,), jnp.int32),        # cand_cols
        jax.ShapeDtypeStruct((G,), jnp.int32),        # r_ids
        jax.ShapeDtypeStruct((G, A), tdt),            # x
        jax.ShapeDtypeStruct((G,), tdt),              # d
        jax.ShapeDtypeStruct((G,), jnp.int32),        # w
        jax.ShapeDtypeStruct((G,), jnp.bool_),        # valid
        jax.ShapeDtypeStruct((), jnp.float32),        # n
    )
    t0 = time.time()
    lowered = ev.lower(*shapes)
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    record["memory_per_device"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
    }
    cost = compiled.cost_analysis()
    flops = cost.get("flops", 0.0)
    bytes_ = cost.get("bytes accessed", 0.0)
    colls = rl.parse_collectives(compiled.as_text())
    record["flops_per_device"] = flops
    record["bytes_per_device"] = bytes_
    record["collectives"] = colls.as_dict()
    record["roofline"] = rl.roofline_terms(flops, bytes_, colls.total_wire_bytes)
    # "useful work": the contingency scatter-adds — A·G adds of m-sized rows
    record["model_flops_total"] = float(A) * G * 2
    record["status"] = "ok"

    # advance step must lower too (proves the full loop is mesh-coherent)
    adv_shapes = (
        jax.ShapeDtypeStruct((G,), jnp.int32),
        jax.ShapeDtypeStruct((G,), jnp.int32),
        jax.ShapeDtypeStruct((G,), jnp.int32),
        jax.ShapeDtypeStruct((G,), jnp.int32),
        jax.ShapeDtypeStruct((G,), jnp.bool_),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    adv.lower(*adv_shapes).compile()
    record["advance_step"] = "ok"
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--plar", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default=None, help="suffix for perf-variant records")
    ap.add_argument("--override", action="append", default=[],
                    help="ArchConfig overrides, e.g. --override flash_bwd=True")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--collective", default="all_reduce",
                    choices=["all_reduce", "reduce_scatter"])
    ap.add_argument("--table-dtype", default="int32", choices=["int32", "int8"])
    ap.add_argument("--fused-pack", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        from repro.configs import cells
        jobs = [(a, s, m) for (a, s) in cells() for m in meshes]
        jobs += [("plar-sdss", "eval_iteration", m) for m in meshes]
        for arch, shape, mesh_kind in jobs:
            path = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}.json")
            if os.path.exists(path) and not args.force:
                print(f"[skip] {path} exists")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--mesh", mesh_kind, "--out", args.out]
            cmd += ["--plar"] if arch == "plar-sdss" else ["--arch", arch, "--shape", shape]
            print(f"[run ] {arch} × {shape} × {mesh_kind}", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                err = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "status": "error", "stderr": r.stderr[-4000:]}
                with open(path, "w") as f:
                    json.dump(err, f, indent=2)
                print(f"[FAIL] {arch} × {shape} × {mesh_kind}", flush=True)
            else:
                print(r.stdout.strip().splitlines()[-1] if r.stdout.strip() else "", flush=True)
        return

    suffix = f"__{args.tag}" if args.tag else ""
    if args.plar:
        try:
            record = run_plar_cell(meshes[0], collective=args.collective,
                                   table_dtype=args.table_dtype,
                                   fused_pack=args.fused_pack)
        except Exception:
            record = {"arch": "plar-sdss", "shape": "eval_iteration",
                      "mesh": meshes[0], "status": "error",
                      "traceback": traceback.format_exc()[-4000:]}
        path = os.path.join(
            args.out, f"plar-sdss__eval_iteration__{meshes[0]}{suffix}.json")
    else:
        try:
            setup_kw = ({"microbatches": args.microbatches}
                        if args.microbatches > 1 and args.shape == "train_4k" else {})
            record = run_cell(args.arch, args.shape, meshes[0],
                              overrides=_parse_overrides(args.override),
                              setup_kw=setup_kw)
        except Exception:
            record = {"arch": args.arch, "shape": args.shape, "mesh": meshes[0],
                      "status": "error", "traceback": traceback.format_exc()[-4000:]}
        path = os.path.join(
            args.out, f"{args.arch}__{args.shape}__{meshes[0]}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2, default=float)
    ok = record.get("status")
    rf = record.get("roofline", {})
    print(f"{record['arch']} × {record['shape']} × {record['mesh']}: {ok} "
          f"compile={record.get('compile_s')}s dominant={rf.get('dominant')}")
    if record.get("status") == "error":
        print(record.get("traceback", record.get("reason", ""))[-2000:], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
