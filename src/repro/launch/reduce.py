"""Attribute-reduction launcher (the paper's CLI):

    python -m repro.launch.reduce --dataset mushroom --delta SCE
    python -m repro.launch.reduce --dataset sdss --delta PR --distributed --mesh 4,2
    python -m repro.launch.reduce --dataset kdd99 --stream

``--distributed`` runs the mesh MDP implementation (requires the process to
have been started with enough devices, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

``--stream`` runs the dataset at its *full* Table-5 shape through streaming
GrC ingestion (DESIGN.md §3.6): the table is generated and granulated in
``--chunk-rows`` chunks, so peak host memory is O(chunk + granularity
capacity) — never the 5M×41 array.  ``--max-rows``/``--max-attrs`` apply
only to the non-streaming path.
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", required=True)
    ap.add_argument("--delta", default="SCE", choices=["PR", "SCE", "LCE", "CCE"])
    ap.add_argument("--stream", action="store_true",
                    help="full paper-scale shape via streaming GrC ingestion")
    ap.add_argument("--chunk-rows", type=int, default=65536,
                    help="rows granulated per streaming chunk")
    ap.add_argument("--max-rows", type=int, default=None,
                    help="row cap, non-streaming path only (default 20000)")
    ap.add_argument("--max-attrs", type=int, default=None,
                    help="attribute cap, non-streaming path only (default 64)")
    ap.add_argument("--max-features", type=int, default=None)
    ap.add_argument("--mode", default="incremental", choices=["incremental", "spark"])
    ap.add_argument("--backend", default="segment",
                    choices=["segment", "onehot", "pallas", "fused",
                             "fused_xla", "sweep", "sweep_xla"],
                    help="Θ evaluation backend (fused = PR-1 Pallas kernel; "
                         "sweep/sweep_xla = PR-4 read-once candidate sweep)")
    ap.add_argument("--bin-ladder", default="off", choices=["on", "off"],
                    help="K-adaptive bin ladder for the candidate sweep "
                         "(DESIGN.md §5.3): early iterations pay "
                         "K-proportional work, zero recompiles on the "
                         "device engine")
    ap.add_argument("--engine", default="auto", choices=["auto", "host", "device"],
                    help="greedy loop: device-resident while_loop or legacy host loop")
    ap.add_argument("--selector", default="analytic",
                    choices=["heuristic", "analytic", "pinned"],
                    help="kernel tile / ladder-rung selection (DESIGN.md "
                         "§5.2): analytic = roofline cost model (default), "
                         "heuristic = legacy VMEM-occupancy rule, pinned = "
                         "kernel-module defaults")
    ap.add_argument("--shrink", action="store_true",
                    help="FSPA universe shrinking (drop pure classes)")
    ap.add_argument("--mp-chunk", type=int, default=64)
    ap.add_argument("--ensemble", default=None, metavar="MEASURES",
                    help="comma-separated measure grid (or 'all' = "
                         "PR,SCE,LCE,CCE) run as ONE stacked engine "
                         "dispatch (DESIGN.md §3.8); --shrink/"
                         "--max-features apply to every member")
    ap.add_argument("--bags", type=int, default=None, metavar="N",
                    help="with --ensemble: N bagged (bootstrap-reweighted) "
                         "replicas per measure, seeds 0..N-1")
    ap.add_argument("--no-grc", action="store_true")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--mesh", default="4,2", help="data,model (distributed)")
    ap.add_argument("--collective", default="all_reduce",
                    choices=["all_reduce", "reduce_scatter", "fused"])
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    from repro.data import paper_dataset, scaled_paper_dataset

    if args.stream:
        # refuse inapplicable knobs rather than silently ignoring them
        # (same policy as the --distributed block below)
        dropped = [name for name, off_default in [
            ("--max-rows", args.max_rows is not None),
            ("--max-attrs", args.max_attrs is not None),
            # --no-grc would materialize the full table (HAR has no
            # compressed representation to stream into), silently voiding
            # the O(chunk + capacity) memory bound --stream promises
            ("--no-grc", args.no_grc),
        ] if off_default]
        if dropped:
            ap.error(f"{', '.join(dropped)} not supported with --stream "
                     "(streaming runs the full Table-5 shape under GrC init)")
        source = paper_dataset(args.dataset)
        table_shape = [source.n_rows, source.n_attrs]
        x = d = None
    else:
        source = None
        x, d = scaled_paper_dataset(
            args.dataset,
            max_rows=args.max_rows if args.max_rows is not None else 20000,
            max_attrs=args.max_attrs if args.max_attrs is not None else 64,
        ).table()
        table_shape = list(x.shape)

    ladder = args.bin_ladder == "on"
    if args.ensemble is not None:
        from repro.core.engine import ENSEMBLE_BACKENDS
        from repro.core.reduction import plar_reduce_ensemble

        # refuse inapplicable knobs rather than silently ignoring them
        dropped = [name for name, off_default in [
            ("--distributed", args.distributed),
            ("--engine", args.engine == "host"),
            ("--backend", args.backend not in ENSEMBLE_BACKENDS),
            ("--delta", args.delta != "SCE"),  # the grid IS the measure knob
        ] if off_default]
        if dropped:
            ap.error(f"{', '.join(dropped)} not supported with --ensemble "
                     f"(stacked engine backends: "
                     f"{', '.join(ENSEMBLE_BACKENDS)}; measures go in the "
                     f"--ensemble list)")
        measures_ = (["PR", "SCE", "LCE", "CCE"] if args.ensemble == "all"
                     else [s.strip() for s in args.ensemble.split(",")])
        configs = [{"delta": dd, "shrink": args.shrink,
                    "max_features": args.max_features} for dd in measures_]
        seeds = None if args.bags is None else list(range(args.bags))
        rs = plar_reduce_ensemble(
            x, d, source=source, chunk_rows=args.chunk_rows, configs=configs,
            seeds=seeds, mode=args.mode, backend=args.backend, ladder=ladder,
            selector=args.selector, mp_chunk=args.mp_chunk,
            grc_init=not args.no_grc)
        grid = [{"delta": dd} if seeds is None else {"delta": dd, "seed": s}
                for dd in measures_ for s in (seeds or [None])]
        out = {
            "dataset": args.dataset, "table_shape": table_shape,
            "ensemble": [
                {**g, "reduct": r.reduct, "core": r.core,
                 "theta_full": r.theta_full, "iterations": r.iterations,
                 "elapsed_s": round(r.elapsed_s, 3)}
                for g, r in zip(grid, rs)],
        }
        if args.json:
            print(json.dumps(out, indent=2))
        else:
            print(f"{'dataset':>14}: {out['dataset']}")
            print(f"{'table_shape':>14}: {out['table_shape']}")
            for e in out["ensemble"]:
                tag = e["delta"] + (f"/bag{e['seed']}" if "seed" in e else "")
                print(f"{tag:>14}: reduct={e['reduct']} "
                      f"theta_full={e['theta_full']:.6f}")
        return

    if args.distributed:
        # the mesh driver has no mode/shrink knobs and only the mesh-capable
        # Θ backends — refuse rather than silently ignoring them
        dropped = [name for name, off_default in [
            ("--mode", args.mode != "incremental"),
            ("--backend", args.backend not in ("segment", "sweep_xla")),
            ("--shrink", args.shrink),
            ("--mp-chunk", args.mp_chunk != 64),
        ] if off_default]
        if dropped:
            ap.error(f"{', '.join(dropped)} not supported with --distributed")

        from repro.core.distributed import plar_reduce_distributed
        from repro.distributed.api import make_mesh

        shape = tuple(int(v) for v in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model"))
        r = plar_reduce_distributed(x, d, mesh, source=source,
                                    chunk_rows=args.chunk_rows,
                                    delta=args.delta,
                                    max_features=args.max_features,
                                    collective=args.collective,
                                    backend=args.backend, ladder=ladder,
                                    selector=args.selector,
                                    engine=args.engine)
    else:
        from repro.core import plar_reduce

        r = plar_reduce(x, d, source=source, chunk_rows=args.chunk_rows,
                        delta=args.delta, mode=args.mode,
                        backend=args.backend, ladder=ladder,
                        selector=args.selector,
                        engine=args.engine, shrink=args.shrink,
                        mp_chunk=args.mp_chunk, grc_init=not args.no_grc,
                        max_features=args.max_features)

    out = {
        "dataset": args.dataset, "delta": args.delta,
        "table_shape": table_shape,
        "reduct": r.reduct, "core": r.core,
        "theta_full": r.theta_full, "iterations": r.iterations,
        "n_evaluations": r.n_evaluations, "elapsed_s": round(r.elapsed_s, 3),
    }
    if args.json:
        print(json.dumps(out, indent=2))
    else:
        for k, v in out.items():
            print(f"{k:>14}: {v}")


if __name__ == "__main__":
    main()
