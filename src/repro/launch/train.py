"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this process runs per host under the cluster scheduler
(jax.distributed.initialize picks up the pod topology); on this container it
drives the same Trainer on local devices.  ``--smoke`` trains the reduced
config — the path CI exercises.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--mesh", default=None,
                    help="mesh shape as 'data,model' (requires enough devices)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import TokenStream
    from repro.train import TrainConfig, Trainer

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()

    mesh = None
    if args.mesh:
        shape = tuple(int(v) for v in args.mesh.split(","))
        names = ("data", "model")[: len(shape)]
        from repro.distributed.api import make_mesh

        mesh = make_mesh(shape, names)

    tc = TrainConfig(
        peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps, microbatches=args.microbatches,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
    )
    trainer = Trainer(cfg, tc, mesh=mesh)
    trainer.install_preemption_handler()

    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq_len,
                         global_batch=args.global_batch)
    data_fn = lambda step: {k: jnp.asarray(v) for k, v in stream.batch(step).items()}
    state, history = trainer.fit(data_fn, steps=args.steps)
    for h in history:
        print(f"step {h['step']:>5}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  {h['sec_per_step']:.2f}s/step")


if __name__ == "__main__":
    main()
