"""Deterministic fault injection for the resilience layer (DESIGN.md §3.10).

A :class:`FaultPlan` is a *seeded, step-indexed* schedule of failures: each
fault names an injection **site** (``shard_drop``, ``merge``, ``dispatch``,
``checkpoint``) and the 0-based occurrence of that site at which it fires.
The plan is consulted at exact code points — the scheduler's merge and
dispatch attempts, the checkpointer's write, the sharded build/recovery
loop — so a test or benchmark can say "the 2nd engine dispatch fails, the
1st checkpoint write crashes, shard 1 dies after the build" and replay it
bit-for-bit.  Two plans built from the same spec (or the same seed) fire
identically; nothing here consults wall clock or global RNG state.

Spec grammar (the ``--fault-plan`` CLI flag)::

    SPEC    := FAULT ("," FAULT)*
    FAULT   := KIND "@" STEP [":" ARG] ["x" COUNT]
    KIND    := shard_drop | merge | dispatch | checkpoint

``shard_drop@0:1`` — drop shard 1 at the first shard-drop site;
``dispatch@2x3`` — fail dispatch occurrences 2, 3 and 4;
``merge@0,checkpoint@0`` — first merge and first checkpoint write fail.

Injected failures raise :class:`FaultInjected` (``transient=True`` by
default — the retry layer's recoverable class; ``!`` after the kind makes
it fatal, e.g. ``dispatch!@1``).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs

__all__ = ["FAULT_KINDS", "FaultInjected", "FaultSpec", "FaultPlan"]

FAULT_KINDS = ("shard_drop", "merge", "dispatch", "checkpoint")


class FaultInjected(RuntimeError):
    """The raw injected failure — what a real infrastructure fault would
    look like to the caller (NOT a ServiceError: the resilience layer is
    supposed to classify and absorb it, not hand it to clients)."""

    def __init__(self, kind: str, step: int, *, transient: bool = True):
        super().__init__(f"injected {kind} fault at site step {step}")
        self.kind = kind
        self.step = step
        self.transient = transient


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire at site occurrences [step, step+count)."""

    kind: str
    step: int
    arg: Optional[int] = None   # kind-specific (shard_drop: which shard)
    count: int = 1
    transient: bool = True

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} "
                f"(one of: {', '.join(FAULT_KINDS)})")
        if self.step < 0 or self.count < 1:
            raise ValueError(
                f"fault step must be ≥ 0 and count ≥ 1, got "
                f"step={self.step} count={self.count}")

    def covers(self, step: int) -> bool:
        return self.step <= step < self.step + self.count


class FaultPlan:
    """Step-indexed fault schedule with per-site occurrence counters.

    Thread-safe: sites are consulted from scheduler worker threads and the
    event loop alike; the counter advance is atomic so a plan fires each
    scheduled fault exactly once regardless of interleaving.
    """

    def __init__(self, specs: Tuple[FaultSpec, ...] = ()):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self._counters: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        self._lock = threading.Lock()
        self.fired: List[Tuple[str, int]] = []   # (kind, step) audit log

    # -- construction --------------------------------------------------------

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Build a plan from the CLI grammar (module docstring)."""
        specs: List[FaultSpec] = []
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if "@" not in part:
                raise ValueError(
                    f"bad fault {part!r}: expected KIND@STEP[:ARG][xCOUNT]")
            kind, _, rest = part.partition("@")
            kind = kind.strip()
            transient = not kind.endswith("!")
            kind = kind.rstrip("!")
            count = 1
            if "x" in rest:
                rest, _, cnt = rest.rpartition("x")
                count = int(cnt)
            arg: Optional[int] = None
            if ":" in rest:
                rest, _, a = rest.partition(":")
                arg = int(a)
            specs.append(FaultSpec(kind=kind, step=int(rest), arg=arg,
                                   count=count, transient=transient))
        return cls(tuple(specs))

    @classmethod
    def seeded(cls, seed: int, *, horizon: int = 8,
               kinds: Tuple[str, ...] = ("dispatch", "merge"),
               n_faults: int = 1) -> "FaultPlan":
        """A deterministic random plan: ``n_faults`` distinct occurrence
        indices per kind drawn from ``[0, horizon)`` by a seeded PRNG.
        Same seed → same plan, so chaos benchmarks are replayable."""
        rng = np.random.default_rng(seed)
        specs: List[FaultSpec] = []
        for kind in kinds:
            steps = rng.choice(horizon, size=min(n_faults, horizon),
                               replace=False)
            specs.extend(FaultSpec(kind=kind, step=int(s))
                         for s in sorted(steps))
        return cls(tuple(specs))

    # -- consultation (the injection sites call these) -----------------------

    def fire(self, kind: str) -> Optional[FaultSpec]:
        """Advance the site counter for ``kind``; return the matching spec
        if one is scheduled for this occurrence, else None."""
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault site {kind!r}")
        hit: Optional[FaultSpec] = None
        with self._lock:
            step = self._counters[kind]
            self._counters[kind] += 1
            for spec in self.specs:
                if spec.kind == kind and spec.covers(step):
                    self.fired.append((kind, step))
                    hit = spec
                    break
        if hit is not None:  # flight-recorder postmortem, outside the lock
            obs.event("faults.fired", kind=kind, step=step,
                      transient=hit.transient)
            obs.counter("plar_faults_fired_total",
                        "fault-plan injections that fired").inc()
            obs.request_dump(f"fault-{kind}",
                             meta={"kind": kind, "step": step,
                                   "transient": hit.transient})
        return hit

    def inject(self, kind: str) -> None:
        """Raise :class:`FaultInjected` when a fault is scheduled here."""
        spec = self.fire(kind)
        if spec is not None:
            raise FaultInjected(kind, self.fired[-1][1],
                                transient=spec.transient)

    def reset(self) -> None:
        """Rewind every site counter (replay the same plan again)."""
        with self._lock:
            self._counters = {k: 0 for k in FAULT_KINDS}
            self.fired.clear()

    def __repr__(self) -> str:
        parts = [f"{s.kind}{'' if s.transient else '!'}@{s.step}"
                 + (f":{s.arg}" if s.arg is not None else "")
                 + (f"x{s.count}" if s.count != 1 else "")
                 for s in self.specs]
        return f"FaultPlan({','.join(parts)})"
