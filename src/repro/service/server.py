"""Async multi-tenant reduct server: batched dispatch, dedup, admission.

The serving layer of DESIGN.md §3.7/§3.9, shaped like ``serving/engine.py``'s
Request pattern: requests enter a *bounded* asyncio queue, one scheduler
task (:class:`~repro.service.scheduler.Scheduler`) drains it in windows,
and the expensive JAX work runs in threads so the event loop stays
responsive for admission, dedup, and rejection.

Operations:

* ``submit(name, ...)``  — create a :class:`DatasetHandle` (initial rows,
  a GranuleSource, or a prebuilt Granularity);
* ``update(name, x, d)`` — enqueue a row batch.  Updates are *lazy*: they
  buffer per dataset and are **coalesced into one monoid merge** when the
  next query for that dataset is served — k buffered batches cost one
  concat + one ``merge_granularity``, not k (the §3.6 merge is a monoid, so
  coalescing is exact);
* ``query(name, delta, **params)`` — reduct for the dataset's *current*
  content (pending updates drain first).  Served through two cache tiers:

  - **in-flight dedup** — an identical query (same dataset *content
    epoch*, measure, normalized params) that arrives while one is already
    queued or running awaits the same future instead of re-running;
  - **result cache** — keyed ``(dataset, content fingerprint, measure,
    normalized params)``; a repeat query on unchanged content is a
    dictionary hit, a changed fingerprint falls through to the handle's
    warm validate-and-repair path (state.py), and a merge evicts the
    dataset's superseded-fingerprint entries through a per-dataset
    fingerprint index (O(evicted), not O(total cache));

* ``query_ensemble(name, configs, seeds=..., **shared)`` — a whole config
  grid in one stacked engine dispatch (DESIGN.md §3.8), cached per config
  under the same key shape: only the grid's cache *misses* are re-run (as
  a smaller stacked grid).

Cross-query batching: compatible single-config cache misses that share a
scheduler window are answered by ONE stacked ``reduce_many`` dispatch
(§3.9) — byte-identical to serving each alone.  ``batching=False``
restores the PR 5 single-flight worker (the benchmark baseline).

Admission control: the queue depth is bounded (``max_queue``); when it is
full, ``query``/``query_ensemble`` fail fast with
:class:`~repro.service.scheduler.ServerOverloaded` instead of queueing
unboundedly.  ``stop()`` fails queued-but-unstarted requests with
``RuntimeError("server stopped")`` — futures never hang across shutdown.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import threading
from typing import Any, Deque, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.reduction import ReductionResult, expand_ensemble_grid

from .metrics import RequestTiming, ServiceMetrics
from .scheduler import Scheduler, ServerOverloaded
from .state import DatasetHandle

__all__ = ["ReductServer", "ReduceRequest", "ServerOverloaded"]

_STOP = object()

# Completed-request log depth (introspection/stats only — not correctness).
_REQUEST_LOG = 1024

# Key params consumed at f32 precision by the engine (measures.f32_threshold):
# f32-rounding them in cache/dedup keys conflates only queries whose
# thresholds the engine cannot tell apart.
_F32_KEY_PARAMS = ("tol", "tie_tol")


def _norm_key_value(key: str, value: Any) -> Any:
    """Normalize one cache/dedup key value (the PR 6 engine-factory idiom):
    numpy scalars become python scalars, f32-consumed thresholds round to
    f32 — so ``np.float32(0.01)`` and ``0.01`` hash to ONE key."""
    if isinstance(value, np.generic):
        value = value.item()
    if key in _F32_KEY_PARAMS and isinstance(value, float):
        value = float(np.float32(value))
    return value


def _norm_items(params: Dict[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted((k, _norm_key_value(k, v)) for k, v in params.items()))


@dataclasses.dataclass
class ReduceRequest:
    """One query through the queue (the serving/engine.py Request shape)."""

    rid: int
    dataset: str
    delta: str
    params: Tuple[Tuple[str, Any], ...]
    future: asyncio.Future
    # ensemble queries: the expanded config grid (sorted-items tuples);
    # None marks a single-config query
    configs: Optional[Tuple[Tuple[Tuple[str, Any], ...], ...]] = None
    # latency accounting (shared shape with serving/engine.py):
    timing: RequestTiming = dataclasses.field(default_factory=RequestTiming)
    # filled by the scheduler:
    cached: bool = False
    warm: bool = False
    prefix_kept: int = 0
    merged_batches: int = 0
    batch_size: int = 0   # queries served by this request's engine dispatch
    latency_s: float = 0.0


class ReductServer:
    """Stateful attribute-reduction service over evolving decision tables.

    ``max_queue`` bounds the request queue (admission control);
    ``batching=False`` restores the PR 5 single-flight worker with dedup
    disabled — the serve-benchmark baseline.
    """

    def __init__(self, *, max_queue: int = 1024,
                 batching: bool = True) -> None:
        self._max_queue = int(max_queue)
        self._batching = bool(batching)
        # None marks a name reserved by an in-flight submit()
        self._handles: Dict[str, Optional[DatasetHandle]] = {}
        self._pending: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
        # content epoch per dataset: bumped on every update(); dedup keys
        # carry it so only queries over the same eventual content share a
        # future (the fingerprint is not known until the merge lands)
        self._epoch: Dict[str, int] = {}
        # result cache, keyed (dataset, fingerprint, measure, params), plus
        # a dataset → fingerprint → keys index so stale eviction touches
        # only the evicted entries
        self._cache: Dict[tuple, ReductionResult] = {}
        self._cache_index: Dict[str, Dict[int, Set[tuple]]] = {}
        self._lock = threading.Lock()
        # in-flight dedup tier: dedup key → the future already serving it
        self._inflight: Dict[tuple, asyncio.Future] = {}
        self._queue: Optional[asyncio.Queue] = None
        self._worker: Optional[asyncio.Task] = None
        self._stopping = False
        self._rid = 0
        self.requests: Deque[ReduceRequest] = collections.deque(
            maxlen=_REQUEST_LOG)
        self.metrics = ServiceMetrics()
        self.stats = {"queries": 0, "cache_hits": 0, "warm": 0, "cold": 0,
                      "merges": 0, "updates": 0, "coalesced_batches": 0,
                      "ensemble_queries": 0, "ensemble_configs": 0,
                      "dedup_hits": 0, "rejected": 0, "engine_runs": 0}

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "ReductServer":
        if self._worker is not None:
            raise RuntimeError("server already started")
        self._queue = asyncio.Queue(maxsize=self._max_queue)
        self._scheduler = Scheduler(self, batching=self._batching)
        self._worker = asyncio.create_task(self._scheduler.run(_STOP))
        return self

    async def stop(self) -> None:
        """Stop the scheduler.  The window being dispatched completes; every
        queued-but-unstarted request fails fast with
        ``RuntimeError("server stopped")`` (futures never hang)."""
        if self._worker is None:
            return
        self._stopping = True
        try:
            await self._queue.put(_STOP)
            await self._worker
        finally:
            self._worker = None
            self._queue = None
            self._inflight.clear()
            self._stopping = False

    async def __aenter__(self) -> "ReductServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- operations ---------------------------------------------------------

    async def submit(self, name: str, x=None, d=None, *, source=None,
                     n_dec: Optional[int] = None, v_max: Optional[int] = None,
                     exact: bool = True, chunk_rows: int = 65536) -> int:
        """Create a dataset; returns its content fingerprint."""
        if name in self._handles:
            raise ValueError(f"dataset {name!r} already exists")
        # reserve before awaiting: the to_thread suspension would otherwise
        # let a concurrent same-name submit pass the existence check too,
        # and the last writer would silently swallow the other's rows
        self._handles[name] = None
        try:
            handle = await asyncio.to_thread(
                DatasetHandle.create, x, d, source=source, n_dec=n_dec,
                v_max=v_max, exact=exact, chunk_rows=chunk_rows)
        except BaseException:
            del self._handles[name]
            raise
        self._handles[name] = handle
        return handle.fingerprint

    async def update(self, name: str, x, d) -> None:
        """Buffer a row batch; applied (coalesced) before the next query.

        Validated against the dataset's declared schema *now*: a bad batch
        is rejected to its sender instead of poisoning the coalesced merge
        (which would silently drop the valid batches buffered beside it).
        """
        handle = self._require(name)
        x, d = handle.validate_batch(x, d)
        self._pending.setdefault(name, []).append((x, d))
        self._epoch[name] = self._epoch.get(name, 0) + 1
        self.stats["updates"] += 1

    async def query(self, name: str, delta: str = "PR",
                    **params) -> ReductionResult:
        """Reduct for the dataset's current content (pending updates included).

        Raises :class:`ServerOverloaded` when the bounded queue is full."""
        self._require(name)
        self._ensure_running()
        params_t = _norm_items(params)
        dkey = None
        if self._batching:
            dkey = (name, self._epoch.get(name, 0), delta, params_t, None)
            fut = self._inflight.get(dkey)
            if fut is not None:  # in-flight dedup: ride the running query
                self._bump("dedup_hits", 1)
                self.metrics.inc("dedup_hits")
                return await asyncio.shield(fut)
        self._rid += 1
        req = ReduceRequest(
            rid=self._rid, dataset=name, delta=delta, params=params_t,
            future=asyncio.get_running_loop().create_future(),
            timing=RequestTiming().mark_enqueue())
        self._admit(req, dkey)
        if dkey is not None:
            # shield: a cancelled caller must not cancel a shared future
            return await asyncio.shield(req.future)
        return await req.future

    async def query_ensemble(self, name: str, configs, *, seeds=None,
                             **shared) -> List[ReductionResult]:
        """A whole config grid for the dataset's current content, served by
        ONE stacked engine dispatch (DESIGN.md §3.8).

        Pending updates drain first, exactly like :meth:`query`.  Each
        member is cached individually under ``(dataset, fingerprint, delta,
        params)`` — a repeat grid on unchanged content is C dictionary hits,
        a partially-cached grid re-runs only the missing configs (as a
        smaller stacked grid), and results come back in grid order
        (``configs`` × ``seeds``).
        """
        self._require(name)
        self._ensure_running()
        grid = expand_ensemble_grid(configs, seeds)
        params_t = _norm_items(shared)
        configs_t = tuple(_norm_items(c) for c in grid)
        dkey = None
        if self._batching:
            dkey = (name, self._epoch.get(name, 0), "<ensemble>", params_t,
                    configs_t)
            fut = self._inflight.get(dkey)
            if fut is not None:
                self._bump("dedup_hits", 1)
                self.metrics.inc("dedup_hits")
                return await asyncio.shield(fut)
        self._rid += 1
        req = ReduceRequest(
            rid=self._rid, dataset=name, delta="<ensemble>", params=params_t,
            configs=configs_t,
            future=asyncio.get_running_loop().create_future(),
            timing=RequestTiming().mark_enqueue())
        self._admit(req, dkey)
        if dkey is not None:
            return await asyncio.shield(req.future)
        return await req.future

    def handle(self, name: str) -> DatasetHandle:
        return self._require(name)

    def summary(self) -> Dict[str, Any]:
        """One flat dict: counters + aggregate serving metrics."""
        out = dict(self.stats)
        out.update(self.metrics.summary())
        return out

    # -- admission / dedup (event loop) -------------------------------------

    def _ensure_running(self) -> None:
        if self._stopping:
            raise RuntimeError("server stopped")
        if self._queue is None:
            raise RuntimeError(
                "server not started (use 'async with' or start())")

    def _admit(self, req: ReduceRequest, dkey: Optional[tuple]) -> None:
        try:
            self._queue.put_nowait(req)
        except asyncio.QueueFull:
            self._bump("rejected", 1)
            self.metrics.inc("rejected")
            raise ServerOverloaded(
                f"request queue full (max_queue={self._max_queue}); "
                f"retry after the backlog drains") from None
        if dkey is not None:
            self._inflight[dkey] = req.future
            req.future.add_done_callback(self._inflight_cleanup(dkey))

    def _inflight_cleanup(self, dkey: tuple):
        def _done(fut: asyncio.Future) -> None:
            if self._inflight.get(dkey) is fut:
                del self._inflight[dkey]
        return _done

    # -- shared state used by the scheduler (threads) -----------------------

    def _require(self, name: str) -> DatasetHandle:
        handle = self._handles.get(name)
        if handle is None:  # absent, or reserved by an in-flight submit
            raise KeyError(f"unknown dataset: {name!r}")
        return handle

    def _bump(self, name: str, by: int = 1) -> None:
        with self._lock:
            self.stats[name] = self.stats.get(name, 0) + by

    def _cache_get(self, key: tuple) -> Optional[ReductionResult]:
        with self._lock:
            return self._cache.get(key)

    def _cache_put(self, key: tuple, value) -> None:
        with self._lock:
            self._cache[key] = value
            self._cache_index.setdefault(key[0], {}).setdefault(
                key[1], set()).add(key)

    def _evict_stale(self, dataset: str, live_fp: int) -> None:
        """Drop a dataset's superseded-fingerprint entries: O(evicted) via
        the fingerprint index, not a scan of the whole cache."""
        with self._lock:
            by_fp = self._cache_index.get(dataset)
            if not by_fp:
                return
            for fp in [f for f in by_fp if f != live_fp]:
                for key in by_fp.pop(fp):
                    self._cache.pop(key, None)
