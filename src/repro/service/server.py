"""Async multi-tenant reduct server: batched dispatch, dedup, admission.

The serving layer of DESIGN.md §3.7/§3.9, shaped like ``serving/engine.py``'s
Request pattern: requests enter a *bounded* asyncio queue, one scheduler
task (:class:`~repro.service.scheduler.Scheduler`) drains it in windows,
and the expensive JAX work runs in threads so the event loop stays
responsive for admission, dedup, and rejection.

Operations:

* ``submit(name, ...)``  — create a :class:`DatasetHandle` (initial rows,
  a GranuleSource, or a prebuilt Granularity);
* ``update(name, x, d)`` — enqueue a row batch.  Updates are *lazy*: they
  buffer per dataset and are **coalesced into one monoid merge** when the
  next query for that dataset is served — k buffered batches cost one
  concat + one ``merge_granularity``, not k (the §3.6 merge is a monoid, so
  coalescing is exact);
* ``query(name, delta, **params)`` — reduct for the dataset's *current*
  content (pending updates drain first).  Served through two cache tiers:

  - **in-flight dedup** — an identical query (same dataset *content
    epoch*, measure, normalized params) that arrives while one is already
    queued or running awaits the same future instead of re-running;
  - **result cache** — keyed ``(dataset, content fingerprint, measure,
    normalized params)``; a repeat query on unchanged content is a
    dictionary hit, a changed fingerprint falls through to the handle's
    warm validate-and-repair path (state.py), and a merge evicts the
    dataset's superseded-fingerprint entries through a per-dataset
    fingerprint index (O(evicted), not O(total cache));

* ``query_ensemble(name, configs, seeds=..., **shared)`` — a whole config
  grid in one stacked engine dispatch (DESIGN.md §3.8), cached per config
  under the same key shape: only the grid's cache *misses* are re-run (as
  a smaller stacked grid).

Cross-query batching: compatible single-config cache misses that share a
scheduler window are answered by ONE stacked ``reduce_many`` dispatch
(§3.9) — byte-identical to serving each alone.  ``batching=False``
restores the PR 5 single-flight worker (the benchmark baseline).

Admission control: the queue depth is bounded (``max_queue``); when it is
full, ``query``/``query_ensemble`` fail fast with
:class:`~repro.service.errors.ServerOverloaded` instead of queueing
unboundedly.  ``stop()`` fails queued-but-unstarted requests with
:class:`~repro.service.errors.ServerStopped` — futures never hang across
shutdown — then **flushes** every buffered-but-unmerged update batch
through one final coalesced merge per dataset (counted as
``flushed_batches`` in :meth:`ReductServer.summary`), so accepted updates
are never silently dropped by an orderly shutdown.

Durability & resilience (DESIGN.md §3.10): with ``checkpoint_dir`` set,
the server checkpoints its :class:`DatasetHandle` map — granularity
arrays, content fingerprint, per-config reducts/Θ histories, shard lineage
— after every ``checkpoint_every``-th merged window (background write) and
once more, blocking, at ``stop()``.  A restarted server restores the
newest committed step in :meth:`start` and answers its first query through
the warm ``repair_reduce`` path.  ``retry``/``serve_stale``/``fault_plan``
configure the scheduler's failure hardening (scheduler.py docstring);
failures are surfaced through the typed
:class:`~repro.service.errors.ServiceError` hierarchy.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import threading
from typing import Any, Deque, Dict, List, Optional, Set, Tuple

import numpy as np

from repro import obs
from repro.core.reduction import ReductionResult, expand_ensemble_grid

from .checkpoint import ServiceCheckpointer
from .errors import (
    QueryPoisoned,
    ServerOverloaded,
    ServerStopped,
    ServiceError,
)
from .metrics import RequestTiming, ServiceMetrics
from .scheduler import RetryPolicy, Scheduler
from .state import DatasetHandle

__all__ = ["ReductServer", "ReduceRequest", "ServerOverloaded"]

_STOP = object()

# Completed-request log depth (introspection/stats only — not correctness).
_REQUEST_LOG = 1024

# Key params consumed at f32 precision by the engine (measures.f32_threshold):
# f32-rounding them in cache/dedup keys conflates only queries whose
# thresholds the engine cannot tell apart.
_F32_KEY_PARAMS = ("tol", "tie_tol")


def _norm_key_value(key: str, value: Any) -> Any:
    """Normalize one cache/dedup key value (the PR 6 engine-factory idiom):
    numpy scalars become python scalars, f32-consumed thresholds round to
    f32 — so ``np.float32(0.01)`` and ``0.01`` hash to ONE key."""
    if isinstance(value, np.generic):
        value = value.item()
    if key in _F32_KEY_PARAMS and isinstance(value, float):
        value = float(np.float32(value))
    return value


def _norm_items(params: Dict[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted((k, _norm_key_value(k, v)) for k, v in params.items()))


@dataclasses.dataclass
class ReduceRequest:
    """One query through the queue (the serving/engine.py Request shape)."""

    rid: int
    dataset: str
    delta: str
    params: Tuple[Tuple[str, Any], ...]
    future: asyncio.Future
    # ensemble queries: the expanded config grid (sorted-items tuples);
    # None marks a single-config query
    configs: Optional[Tuple[Tuple[Tuple[str, Any], ...], ...]] = None
    # latency accounting (shared shape with serving/engine.py):
    timing: RequestTiming = dataclasses.field(default_factory=RequestTiming)
    # filled by the scheduler:
    cached: bool = False
    warm: bool = False
    prefix_kept: int = 0
    merged_batches: int = 0
    batch_size: int = 0   # queries served by this request's engine dispatch
    latency_s: float = 0.0


class ReductServer:
    """Stateful attribute-reduction service over evolving decision tables.

    ``max_queue`` bounds the request queue (admission control);
    ``batching=False`` restores the PR 5 single-flight worker with dedup
    disabled — the serve-benchmark baseline.

    Resilience knobs (DESIGN.md §3.10): ``checkpoint_dir`` enables durable
    handle snapshots (restored on :meth:`start`, written after every
    ``checkpoint_every``-th merged window and at :meth:`stop`, keep-N =
    ``checkpoint_keep``); ``retry`` is the scheduler's
    :class:`~repro.service.scheduler.RetryPolicy`; ``serve_stale=True``
    degrades failed dispatches to the last known-good result flagged
    ``stale=True``; ``fault_plan`` wires a deterministic
    :class:`~repro.service.faults.FaultPlan` into every injection site.
    """

    def __init__(self, *, max_queue: int = 1024, batching: bool = True,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1, checkpoint_keep: int = 3,
                 retry: Optional[RetryPolicy] = None,
                 serve_stale: bool = False, fault_plan=None) -> None:
        self._max_queue = int(max_queue)
        self._batching = bool(batching)
        self._checkpoint_dir = checkpoint_dir
        self._checkpoint_every = max(1, int(checkpoint_every))
        self._checkpoint_keep = int(checkpoint_keep)
        self._retry = retry
        self._serve_stale = bool(serve_stale)
        self._fault_plan = fault_plan
        self._ckpt: Optional[ServiceCheckpointer] = None
        self._merges_since_ckpt = 0
        # §3.10 failure bookkeeping, keyed by query config *without* the
        # content fingerprint (scheduler._qkey): consecutive-failure counts,
        # quarantined configs, and last known-good results for serve_stale
        self._failures: Dict[tuple, int] = {}
        self._quarantined: Dict[tuple, QueryPoisoned] = {}
        self._last_good: Dict[tuple, ReductionResult] = {}
        # None marks a name reserved by an in-flight submit()
        self._handles: Dict[str, Optional[DatasetHandle]] = {}
        self._pending: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
        # content epoch per dataset: bumped on every update(); dedup keys
        # carry it so only queries over the same eventual content share a
        # future (the fingerprint is not known until the merge lands)
        self._epoch: Dict[str, int] = {}
        # result cache, keyed (dataset, fingerprint, measure, params), plus
        # a dataset → fingerprint → keys index so stale eviction touches
        # only the evicted entries
        self._cache: Dict[tuple, ReductionResult] = {}
        self._cache_index: Dict[str, Dict[int, Set[tuple]]] = {}
        self._lock = threading.Lock()
        # in-flight dedup tier: dedup key → the future already serving it
        self._inflight: Dict[tuple, asyncio.Future] = {}
        self._queue: Optional[asyncio.Queue] = None
        self._worker: Optional[asyncio.Task] = None
        self._stopping = False
        self._rid = 0
        self.requests: Deque[ReduceRequest] = collections.deque(
            maxlen=_REQUEST_LOG)
        # one per-server registry (DESIGN.md §3.11) backs both the stats
        # dict and the ServiceMetrics counters/histograms; reduce_server's
        # --metrics-port merges it into the process exposition
        self.registry = obs.MetricsRegistry()
        self.metrics = ServiceMetrics(registry=self.registry)
        self.stats = obs.CounterMap(
            self.registry, prefix="plar_server_",
            initial=("queries", "cache_hits", "warm", "cold",
                     "merges", "updates", "coalesced_batches",
                     "ensemble_queries", "ensemble_configs",
                     "dedup_hits", "rejected", "engine_runs",
                     "retries", "quarantined", "stale_served",
                     "flushed_batches", "flush_failures",
                     "checkpoints", "restored_datasets"))

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "ReductServer":
        if self._worker is not None:
            raise ServiceError("server already started")
        if self._checkpoint_dir is not None:
            # postmortems land next to the checkpoints (obs dump-on-failure)
            obs.set_dump_dir(self._checkpoint_dir)
            self._ckpt = ServiceCheckpointer(
                self._checkpoint_dir, keep=self._checkpoint_keep,
                fault_plan=self._fault_plan)
            try:
                _step, restored = await asyncio.to_thread(self._ckpt.restore)
            except FileNotFoundError:
                pass  # cold start: no committed step yet
            else:
                for name, handle in restored.items():
                    # live handles win over checkpointed state (a stop/start
                    # cycle must not roll a dataset back)
                    self._handles.setdefault(name, handle)
                self._bump("restored_datasets", len(restored))
        self._queue = asyncio.Queue(maxsize=self._max_queue)
        self._scheduler = Scheduler(
            self, batching=self._batching, retry=self._retry,
            fault_plan=self._fault_plan, serve_stale=self._serve_stale)
        self._worker = asyncio.create_task(self._scheduler.run(_STOP))
        return self

    async def stop(self) -> None:
        """Orderly shutdown.  The window being dispatched completes; every
        queued-but-unstarted request fails fast with
        :class:`ServerStopped` (futures never hang).  Then buffered-but-
        unmerged update batches are flushed through one final coalesced
        merge per dataset (``flushed_batches``), and — when checkpointing —
        a final blocking checkpoint makes the flushed state durable."""
        if self._worker is None:
            return
        self._stopping = True
        try:
            await self._queue.put(_STOP)
            await self._worker
            await asyncio.to_thread(self._flush_pending)
            if self._ckpt is not None:
                await asyncio.to_thread(self._checkpoint_now)
                await asyncio.to_thread(self._ckpt.wait)
        finally:
            self._worker = None
            self._queue = None
            self._inflight.clear()
            self._stopping = False

    async def __aenter__(self) -> "ReductServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- operations ---------------------------------------------------------

    async def submit(self, name: str, x=None, d=None, *, source=None,
                     n_dec: Optional[int] = None, v_max: Optional[int] = None,
                     exact: bool = True, chunk_rows: int = 65536,
                     n_shards: Optional[int] = None) -> int:
        """Create a dataset; returns its content fingerprint.

        ``n_shards`` (requires ``source=``) builds through the lineage-
        tracked sharded path (core/recovery.py): the handle records per
        shard which source chunk ranges folded into it, so a lost shard is
        rebuilt by re-folding only its own rows."""
        if name in self._handles:
            raise ValueError(f"dataset {name!r} already exists")
        if self._checkpoint_dir is not None and "/" in name:
            raise ValueError(
                f"dataset name {name!r} must not contain '/' when "
                f"checkpointing is enabled (names become npz key prefixes)")
        # reserve before awaiting: the to_thread suspension would otherwise
        # let a concurrent same-name submit pass the existence check too,
        # and the last writer would silently swallow the other's rows
        self._handles[name] = None
        try:
            if n_shards is not None:
                if source is None:
                    raise ValueError("n_shards requires source=")
                handle = await asyncio.to_thread(
                    DatasetHandle.create_sharded, source, n_shards,
                    chunk_rows=chunk_rows, exact=exact,
                    fault_plan=self._fault_plan)
            else:
                handle = await asyncio.to_thread(
                    DatasetHandle.create, x, d, source=source, n_dec=n_dec,
                    v_max=v_max, exact=exact, chunk_rows=chunk_rows)
        except BaseException:
            del self._handles[name]
            raise
        self._handles[name] = handle
        return handle.fingerprint

    async def update(self, name: str, x, d) -> None:
        """Buffer a row batch; applied (coalesced) before the next query.

        Validated against the dataset's declared schema *now*: a bad batch
        is rejected to its sender instead of poisoning the coalesced merge
        (which would silently drop the valid batches buffered beside it).
        """
        handle = self._require(name)
        x, d = handle.validate_batch(x, d)
        self._pending.setdefault(name, []).append((x, d))
        self._epoch[name] = self._epoch.get(name, 0) + 1
        self.stats["updates"] += 1

    async def query(self, name: str, delta: str = "PR",
                    **params) -> ReductionResult:
        """Reduct for the dataset's current content (pending updates included).

        Raises :class:`ServerOverloaded` when the bounded queue is full."""
        self._require(name)
        self._ensure_running()
        params_t = _norm_items(params)
        dkey = None
        if self._batching:
            dkey = (name, self._epoch.get(name, 0), delta, params_t, None)
            fut = self._inflight.get(dkey)
            if fut is not None:  # in-flight dedup: ride the running query
                self._bump("dedup_hits", 1)
                self.metrics.inc("dedup_hits")
                obs.event("scheduler.dedup", dataset=name, delta=delta)
                return await asyncio.shield(fut)
        self._rid += 1
        req = ReduceRequest(
            rid=self._rid, dataset=name, delta=delta, params=params_t,
            future=asyncio.get_running_loop().create_future(),
            timing=RequestTiming().mark_enqueue())
        self._admit(req, dkey)
        if dkey is not None:
            # shield: a cancelled caller must not cancel a shared future
            return await asyncio.shield(req.future)
        return await req.future

    async def query_ensemble(self, name: str, configs, *, seeds=None,
                             **shared) -> List[ReductionResult]:
        """A whole config grid for the dataset's current content, served by
        ONE stacked engine dispatch (DESIGN.md §3.8).

        Pending updates drain first, exactly like :meth:`query`.  Each
        member is cached individually under ``(dataset, fingerprint, delta,
        params)`` — a repeat grid on unchanged content is C dictionary hits,
        a partially-cached grid re-runs only the missing configs (as a
        smaller stacked grid), and results come back in grid order
        (``configs`` × ``seeds``).
        """
        self._require(name)
        self._ensure_running()
        grid = expand_ensemble_grid(configs, seeds)
        params_t = _norm_items(shared)
        configs_t = tuple(_norm_items(c) for c in grid)
        dkey = None
        if self._batching:
            dkey = (name, self._epoch.get(name, 0), "<ensemble>", params_t,
                    configs_t)
            fut = self._inflight.get(dkey)
            if fut is not None:
                self._bump("dedup_hits", 1)
                self.metrics.inc("dedup_hits")
                obs.event("scheduler.dedup", dataset=name, delta="<ensemble>")
                return await asyncio.shield(fut)
        self._rid += 1
        req = ReduceRequest(
            rid=self._rid, dataset=name, delta="<ensemble>", params=params_t,
            configs=configs_t,
            future=asyncio.get_running_loop().create_future(),
            timing=RequestTiming().mark_enqueue())
        self._admit(req, dkey)
        if dkey is not None:
            return await asyncio.shield(req.future)
        return await req.future

    def handle(self, name: str) -> DatasetHandle:
        return self._require(name)

    def summary(self) -> Dict[str, Any]:
        """One flat dict: counters + aggregate serving metrics."""
        out = dict(self.stats)
        out.update(self.metrics.summary())
        return out

    # -- admission / dedup (event loop) -------------------------------------

    def _ensure_running(self) -> None:
        if self._stopping:
            raise ServerStopped("server stopped")
        if self._queue is None:
            raise ServiceError(
                "server not started (use 'async with' or start())")

    def _admit(self, req: ReduceRequest, dkey: Optional[tuple]) -> None:
        try:
            self._queue.put_nowait(req)
        except asyncio.QueueFull:
            self._bump("rejected", 1)
            self.metrics.inc("rejected")
            raise ServerOverloaded(
                f"request queue full (max_queue={self._max_queue}); "
                f"retry after the backlog drains") from None
        if dkey is not None:
            self._inflight[dkey] = req.future
            req.future.add_done_callback(self._inflight_cleanup(dkey))

    def _inflight_cleanup(self, dkey: tuple):
        def _done(fut: asyncio.Future) -> None:
            if self._inflight.get(dkey) is fut:
                del self._inflight[dkey]
        return _done

    # -- shared state used by the scheduler (threads) -----------------------

    def _require(self, name: str) -> DatasetHandle:
        handle = self._handles.get(name)
        if handle is None:  # absent, or reserved by an in-flight submit
            raise KeyError(f"unknown dataset: {name!r}")
        return handle

    def _bump(self, name: str, by: int = 1) -> None:
        with self._lock:
            self.stats[name] = self.stats.get(name, 0) + by

    def _cache_get(self, key: tuple) -> Optional[ReductionResult]:
        with self._lock:
            return self._cache.get(key)

    def _cache_put(self, key: tuple, value) -> None:
        with self._lock:
            self._cache[key] = value
            self._cache_index.setdefault(key[0], {}).setdefault(
                key[1], set()).add(key)

    def _evict_stale(self, dataset: str, live_fp: int) -> None:
        """Drop a dataset's superseded-fingerprint entries: O(evicted) via
        the fingerprint index, not a scan of the whole cache."""
        with self._lock:
            by_fp = self._cache_index.get(dataset)
            if not by_fp:
                return
            for fp in [f for f in by_fp if f != live_fp]:
                for key in by_fp.pop(fp):
                    self._cache.pop(key, None)

    # -- §3.10 failure bookkeeping (scheduler threads) ----------------------

    def _poisoned(self, qkey: tuple) -> Optional[QueryPoisoned]:
        """The quarantine exception for this query config, if poisoned."""
        with self._lock:
            return self._quarantined.get(qkey)

    def _record_failure(self, qkey: tuple, exc: BaseException,
                        quarantine_after: int) -> None:
        """Count one exhausted dispatch failure; quarantine the config once
        it has failed ``quarantine_after`` times (followers then get the
        typed :class:`QueryPoisoned` without re-running the dispatch)."""
        quarantined_now = False
        with self._lock:
            n = self._failures.get(qkey, 0) + 1
            self._failures[qkey] = n
            if n >= quarantine_after and qkey not in self._quarantined:
                self._quarantined[qkey] = QueryPoisoned(
                    f"query {qkey[1]!r} on dataset {qkey[0]!r} quarantined "
                    f"after {n} failed dispatches "
                    f"({type(exc).__name__}: {exc}); quarantine clears when "
                    f"the dataset's content changes",
                    cause=exc, failures=n)
                self.stats["quarantined"] = self.stats.get(
                    "quarantined", 0) + 1
                quarantined_now = True
        if quarantined_now:  # outside the lock: dump serialization is slow
            obs.event("server.quarantine", dataset=qkey[0], query=qkey[1],
                      failures=n, error=f"{type(exc).__name__}: {exc}")
            obs.request_dump(
                f"quarantine-{qkey[0]}",
                meta={"dataset": qkey[0], "query": repr(qkey[1]),
                      "failures": n,
                      "error": f"{type(exc).__name__}: {exc}"})

    def _clear_failures(self, dataset: str) -> None:
        """Content changed (merge landed): the failure may have been a
        property of the old content — give the dataset's configs a clean
        quarantine slate."""
        with self._lock:
            for d in (self._failures, self._quarantined):
                for k in [k for k in d if k[0] == dataset]:
                    del d[k]

    def _last_good_put(self, qkey: tuple, result: ReductionResult) -> None:
        with self._lock:
            self._last_good[qkey] = result

    def _last_good_get(self, qkey: tuple) -> Optional[ReductionResult]:
        with self._lock:
            return self._last_good.get(qkey)

    # -- §3.10 durability (event loop + threads) ----------------------------

    @property
    def checkpointer(self) -> Optional[ServiceCheckpointer]:
        return self._ckpt

    def _note_merged(self) -> None:
        """Called by the scheduler after a window that merged updates:
        schedule a background checkpoint every ``checkpoint_every`` merged
        windows (the serving path never waits on disk)."""
        if self._ckpt is None:
            return
        self._merges_since_ckpt += 1
        if self._merges_since_ckpt >= self._checkpoint_every:
            self._checkpoint_now(blocking=False)

    def _checkpoint_now(self, *, blocking: bool = True) -> None:
        """Snapshot every live handle as one committed step (skips names
        reserved by in-flight submits).  Write failures are absorbed by the
        checkpointer (``failed_saves``/``last_error``) — durability must not
        take the serving path down."""
        if self._ckpt is None:
            return
        path = self._ckpt.save(dict(self._handles), blocking=blocking)
        if path is not None:
            self._bump("checkpoints", 1)
        self._merges_since_ckpt = 0

    def _flush_pending(self) -> None:
        """One final coalesced merge per dataset for batches that were
        buffered but never demanded by a query (stop() calls this): accepted
        updates survive an orderly shutdown.  A failing dataset is counted
        (``flush_failures``) and skipped — it must not block the others."""
        for name in list(self._pending):
            batches = self._pending.pop(name)
            handle = self._handles.get(name)
            if not batches or handle is None:
                continue
            try:
                xs = np.concatenate([b[0] for b in batches])
                ds = np.concatenate([b[1] for b in batches])
                handle.update(xs, ds)
            except BaseException:
                self._bump("flush_failures", len(batches))
                continue
            self._bump("merges", 1)
            self._bump("coalesced_batches", len(batches))
            self._bump("flushed_batches", len(batches))
            self._evict_stale(name, handle.fingerprint)
            self._clear_failures(name)
