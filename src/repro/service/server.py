"""Async online reduct server: queue + worker, coalesced updates, result cache.

The serving layer of DESIGN.md §3.7, shaped like ``serving/engine.py``'s
Request pattern: requests enter an asyncio queue, one worker drains it, and
the expensive JAX work runs in a thread so the event loop stays responsive.

Operations:

* ``submit(name, ...)``  — create a :class:`DatasetHandle` (initial rows,
  a GranuleSource, or a prebuilt Granularity);
* ``update(name, x, d)`` — enqueue a row batch.  Updates are *lazy*: they
  buffer per dataset and are **coalesced into one monoid merge** when the
  next query for that dataset is served — k buffered batches cost one
  concat + one ``merge_granularity``, not k (the §3.6 merge is a monoid, so
  coalescing is exact);
* ``query(name, delta, **params)`` — reduct for the dataset's *current*
  content (pending updates drain first).  Results are cached by
  ``(dataset, content fingerprint, measure, params)``; a repeat query on
  unchanged content is a dictionary hit, a changed fingerprint falls
  through to the handle's warm validate-and-repair path (state.py), and a
  merge evicts the dataset's superseded-fingerprint entries (they can
  never hit again), keeping the cache bounded by live content;
* ``query_ensemble(name, configs, seeds=..., **shared)`` — a whole config
  grid in one stacked engine dispatch (DESIGN.md §3.8), cached per config
  under the same key shape: only the grid's cache *misses* are re-run (as
  a smaller stacked grid).

The worker is deliberately single-flight: JAX dispatch is serialized anyway,
and one worker makes the coalescing window well-defined (everything buffered
before a query's turn merges ahead of it).
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.reduction import ReductionResult, expand_ensemble_grid

from .state import DatasetHandle

__all__ = ["ReductServer", "ReduceRequest"]

_STOP = object()

# Completed-request log depth (introspection/stats only — not correctness).
_REQUEST_LOG = 1024


@dataclasses.dataclass
class ReduceRequest:
    """One query through the queue (the serving/engine.py Request shape)."""

    rid: int
    dataset: str
    delta: str
    params: Tuple[Tuple[str, Any], ...]
    future: asyncio.Future
    # ensemble queries: the expanded config grid (sorted-items tuples);
    # None marks a single-config query
    configs: Optional[Tuple[Tuple[Tuple[str, Any], ...], ...]] = None
    # filled by the worker:
    cached: bool = False
    warm: bool = False
    prefix_kept: int = 0
    merged_batches: int = 0
    latency_s: float = 0.0


class ReductServer:
    """Stateful attribute-reduction service over evolving decision tables."""

    def __init__(self) -> None:
        # None marks a name reserved by an in-flight submit()
        self._handles: Dict[str, Optional[DatasetHandle]] = {}
        self._pending: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
        # keyed (dataset, fingerprint, measure, params); entries for a
        # dataset's superseded fingerprints are evicted when a merge lands
        self._cache: Dict[tuple, ReductionResult] = {}
        self._queue: Optional[asyncio.Queue] = None
        self._worker: Optional[asyncio.Task] = None
        self._rid = 0
        self.requests: Deque[ReduceRequest] = collections.deque(
            maxlen=_REQUEST_LOG)
        self.stats = {"queries": 0, "cache_hits": 0, "warm": 0, "cold": 0,
                      "merges": 0, "updates": 0, "coalesced_batches": 0,
                      "ensemble_queries": 0, "ensemble_configs": 0}

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "ReductServer":
        if self._worker is not None:
            raise RuntimeError("server already started")
        self._queue = asyncio.Queue()
        self._worker = asyncio.create_task(self._worker_loop())
        return self

    async def stop(self) -> None:
        if self._worker is None:
            return
        await self._queue.put(_STOP)
        await self._worker
        self._worker = None
        self._queue = None

    async def __aenter__(self) -> "ReductServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- operations ---------------------------------------------------------

    async def submit(self, name: str, x=None, d=None, *, source=None,
                     n_dec: Optional[int] = None, v_max: Optional[int] = None,
                     exact: bool = True, chunk_rows: int = 65536) -> int:
        """Create a dataset; returns its content fingerprint."""
        if name in self._handles:
            raise ValueError(f"dataset {name!r} already exists")
        # reserve before awaiting: the to_thread suspension would otherwise
        # let a concurrent same-name submit pass the existence check too,
        # and the last writer would silently swallow the other's rows
        self._handles[name] = None
        try:
            handle = await asyncio.to_thread(
                DatasetHandle.create, x, d, source=source, n_dec=n_dec,
                v_max=v_max, exact=exact, chunk_rows=chunk_rows)
        except BaseException:
            del self._handles[name]
            raise
        self._handles[name] = handle
        return handle.fingerprint

    async def update(self, name: str, x, d) -> None:
        """Buffer a row batch; applied (coalesced) before the next query.

        Validated against the dataset's declared schema *now*: a bad batch
        is rejected to its sender instead of poisoning the coalesced merge
        (which would silently drop the valid batches buffered beside it).
        """
        handle = self._require(name)
        x, d = handle.validate_batch(x, d)
        self._pending.setdefault(name, []).append((x, d))
        self.stats["updates"] += 1

    async def query(self, name: str, delta: str = "PR",
                    **params) -> ReductionResult:
        """Reduct for the dataset's current content (pending updates included)."""
        self._require(name)
        if self._queue is None:
            raise RuntimeError("server not started (use 'async with' or start())")
        self._rid += 1
        req = ReduceRequest(
            rid=self._rid, dataset=name, delta=delta,
            params=tuple(sorted(params.items())),
            future=asyncio.get_running_loop().create_future())
        await self._queue.put(req)
        return await req.future

    async def query_ensemble(self, name: str, configs, *, seeds=None,
                             **shared) -> List[ReductionResult]:
        """A whole config grid for the dataset's current content, served by
        ONE stacked engine dispatch (DESIGN.md §3.8).

        Pending updates drain first, exactly like :meth:`query`.  Each
        member is cached individually under ``(dataset, fingerprint, delta,
        params)`` — a repeat grid on unchanged content is C dictionary hits,
        a partially-cached grid re-runs only the missing configs (as a
        smaller stacked grid), and results come back in grid order
        (``configs`` × ``seeds``).
        """
        self._require(name)
        if self._queue is None:
            raise RuntimeError("server not started (use 'async with' or start())")
        grid = expand_ensemble_grid(configs, seeds)
        self._rid += 1
        req = ReduceRequest(
            rid=self._rid, dataset=name, delta="<ensemble>",
            params=tuple(sorted(shared.items())),
            configs=tuple(tuple(sorted(c.items())) for c in grid),
            future=asyncio.get_running_loop().create_future())
        await self._queue.put(req)
        return await req.future

    def handle(self, name: str) -> DatasetHandle:
        return self._require(name)

    # -- worker -------------------------------------------------------------

    def _require(self, name: str) -> DatasetHandle:
        handle = self._handles.get(name)
        if handle is None:  # absent, or reserved by an in-flight submit
            raise KeyError(f"unknown dataset: {name!r}")
        return handle

    async def _worker_loop(self) -> None:
        while True:
            req = await self._queue.get()
            if req is _STOP:
                return
            # drain the coalescing buffer on the event loop (no lock needed:
            # update() and this pop both run on the loop thread)
            batches = self._pending.pop(req.dataset, [])
            try:
                result = await asyncio.to_thread(self._process, req, batches)
                if not req.future.cancelled():
                    req.future.set_result(result)
            except Exception as e:  # surface to the awaiting caller
                if not req.future.cancelled():
                    req.future.set_exception(e)

    def _process(self, req: ReduceRequest,
                 batches: List[Tuple[np.ndarray, np.ndarray]]) -> ReductionResult:
        t0 = time.perf_counter()
        handle = self._handles[req.dataset]
        if batches:
            # coalesce: k buffered batches → one merge
            xs = np.concatenate([b[0] for b in batches])
            ds = np.concatenate([b[1] for b in batches])
            handle.update(xs, ds)
            self.stats["merges"] += 1
            self.stats["coalesced_batches"] += len(batches)
            # content moved on: results for superseded fingerprints of this
            # dataset can never hit again — drop them (bounds the cache)
            fp = handle.fingerprint
            stale = [k for k in self._cache
                     if k[0] == req.dataset and k[1] != fp]
            for k in stale:
                del self._cache[k]
        self.stats["queries"] += 1
        if req.configs is not None:
            result = self._process_ensemble(req, handle)
        else:
            key = (req.dataset, handle.fingerprint, req.delta, req.params)
            hit = self._cache.get(key)
            if hit is not None:
                req.cached = True
                self.stats["cache_hits"] += 1
                result = hit
            else:
                result = handle.reduce(req.delta, **dict(req.params))
                self._cache[key] = result
                req.warm = handle.last_was_warm
                req.prefix_kept = handle.last_prefix_kept
                self.stats["warm" if req.warm else "cold"] += 1
        req.merged_batches = len(batches)
        req.latency_s = time.perf_counter() - t0
        self.requests.append(req)
        return result

    def _process_ensemble(self, req: ReduceRequest,
                          handle: DatasetHandle) -> List[ReductionResult]:
        """Serve a config grid: per-config cache probes, then one stacked
        run for exactly the missing configs."""
        shared = dict(req.params)
        fp = handle.fingerprint
        self.stats["ensemble_queries"] += 1
        self.stats["ensemble_configs"] += len(req.configs)

        grid = [dict(items) for items in req.configs]
        keys = []
        for c in grid:
            delta = c.get("delta", "PR")
            params = {**shared,
                      **{k: v for k, v in c.items() if k != "delta"}}
            keys.append((req.dataset, fp, delta, tuple(sorted(params.items()))))

        results: List[Optional[ReductionResult]] = []
        misses: List[int] = []
        for j, key in enumerate(keys):
            hit = self._cache.get(key)
            if hit is not None:
                self.stats["cache_hits"] += 1
            else:
                misses.append(j)
            results.append(hit)
        if misses:
            fresh = handle.reduce_ensemble(
                [grid[j] for j in misses], **shared)
            for j, r in zip(misses, fresh):
                self._cache[keys[j]] = r
                results[j] = r
            self.stats["cold"] += len(misses)
        req.cached = not misses
        return results
