"""Multi-tenant batched dispatch for the reduct server (DESIGN.md §3.9).

The PR 5 worker was single-flight: one queue, one request per engine
dispatch.  This scheduler replaces it with *cross-query batching* — the
continuous-batching idiom of ``serving/engine.py`` applied to attribute
reduction:

* **Window** — when a request is picked up, the queue is drained
  non-blocking; everything already queued forms the batching window.
  Requests arriving during a dispatch wait for the next window, so the
  window needs no timer and adds zero latency to a lone request.
* **Grouping** — window requests are grouped per dataset.  Within a
  dataset, cache misses whose ``(delta, params)`` can be expressed on the
  stacked §3.8 engine (``partition_reduce_params``) and whose *shared*
  knobs agree are served by ONE ``DatasetHandle.reduce_many`` dispatch:
  heterogeneous per-config knobs (measure, tol, max_features, ...) ride
  the traced `EnsembleOperands`, warm members resume from their previous
  reducts via the per-config ``warm_start`` operand.  Results are
  byte-identical to serving each query alone (stacked-vs-sequential
  parity, §3.8 + §3.7 repair), so answers never depend on grouping.
* **Merge/dispatch overlap** — each dataset's pending update batches are
  coalesced into one monoid merge on a worker thread; merges for datasets
  B, C, ... run while dataset A's engine dispatch is in flight (engine
  dispatches themselves stay serialized — JAX serializes them anyway, and
  serializing keeps the §3.7 coalescing window well-defined per dataset).
* **Admission control** — the queue is bounded; over-capacity submits
  fail fast with :class:`ServerOverloaded` (raised by the server's
  ``query``/``query_ensemble``, defined here with the scheduler because it
  is the scheduler's capacity being protected).

The scheduler runs as one asyncio task inside :class:`ReductServer`; all
JAX work happens in ``asyncio.to_thread`` so the event loop keeps
admitting, deduplicating, and rejecting while engines run.
"""
from __future__ import annotations

import asyncio
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.reduction import partition_reduce_params

__all__ = ["Scheduler", "ServerOverloaded"]


class ServerOverloaded(RuntimeError):
    """Raised by ``query``/``query_ensemble`` when the bounded request
    queue is full: the submit fails fast instead of growing the queue
    unboundedly (admission control, DESIGN.md §3.9)."""


class _Work:
    """One dataset's share of a batching window: its requests (arrival
    order) and the update batches captured for its coalesced merge."""

    __slots__ = ("dataset", "requests", "batches", "merge_error")

    def __init__(self, dataset: str) -> None:
        self.dataset = dataset
        self.requests: List[Any] = []
        self.batches: List[Tuple[np.ndarray, np.ndarray]] = []
        self.merge_error: Optional[BaseException] = None


class Scheduler:
    """Drains the server queue in windows and dispatches batched work.

    ``batching=False`` degrades to the PR 5 single-flight worker — one
    request per window, solo dispatch — which is the benchmark baseline
    (``benchmarks/serve_bench.py``).
    """

    def __init__(self, server, *, batching: bool = True) -> None:
        self.srv = server
        self.batching = batching

    # -- the worker loop ----------------------------------------------------

    async def run(self, stop_marker: object) -> None:
        queue = self.srv._queue
        while True:
            req = await queue.get()
            if req is stop_marker or self.srv._stopping:
                self._shutdown(stop_marker,
                               [] if req is stop_marker else [req])
                return
            window = [req]
            if self.batching:
                # the batching window: everything already queued rides along
                while True:
                    try:
                        nxt = queue.get_nowait()
                    except asyncio.QueueEmpty:
                        break
                    if nxt is stop_marker:
                        self._shutdown(stop_marker, window)
                        return
                    window.append(nxt)
            works = self._plan(window)
            await self._execute(works)

    def _shutdown(self, stop_marker: object, pending: List[Any]) -> None:
        """Drain the queue on stop: queued-but-unstarted requests fail fast
        with ``RuntimeError("server stopped")`` instead of hanging forever
        (their work will never run)."""
        queue = self.srv._queue
        while True:
            try:
                nxt = queue.get_nowait()
            except asyncio.QueueEmpty:
                break
            if nxt is not stop_marker:
                pending.append(nxt)
        for req in pending:
            if not req.future.done():
                req.future.set_exception(RuntimeError("server stopped"))

    # -- planning (event loop: may touch _pending without locks) ------------

    def _plan(self, window: List[Any]) -> List[_Work]:
        """Group the window per dataset (first-arrival order) and capture
        each dataset's pending update batches for the coalesced merge."""
        works: Dict[str, _Work] = {}
        for req in window:
            work = works.get(req.dataset)
            if work is None:
                work = works[req.dataset] = _Work(req.dataset)
            work.requests.append(req)
        for work in works.values():
            work.batches = self.srv._pending.pop(work.dataset, [])
        return list(works.values())

    # -- execution ----------------------------------------------------------

    async def _execute(self, works: List[_Work]) -> None:
        # kick every dataset's coalescing merge off immediately: dataset B's
        # host-side merge overlaps dataset A's engine dispatch (the
        # continuous-batching overlap; handles are disjoint per dataset and
        # the result cache is lock-guarded)
        merges = {
            work.dataset: asyncio.create_task(
                asyncio.to_thread(self._merge, work))
            for work in works
        }
        for work in works:
            await merges[work.dataset]
            if work.merge_error is not None:
                outcomes = [(req, ("err", work.merge_error))
                            for req in work.requests]
            else:
                outcomes = await asyncio.to_thread(self._dispatch, work)
            for req, (kind, payload) in outcomes:
                if req.future.cancelled():
                    continue
                if kind == "ok":
                    req.future.set_result(payload)
                else:
                    req.future.set_exception(payload)

    def _merge(self, work: _Work) -> None:
        """Coalesce one dataset's buffered update batches into ONE monoid
        merge, then evict the dataset's superseded cache entries (runs on a
        worker thread; may overlap another dataset's engine dispatch)."""
        srv = self.srv
        if not work.batches:
            return
        try:
            handle = srv._handles[work.dataset]
            xs = np.concatenate([b[0] for b in work.batches])
            ds = np.concatenate([b[1] for b in work.batches])
            handle.update(xs, ds)
            srv._bump("merges", 1)
            srv._bump("coalesced_batches", len(work.batches))
            # content moved on: superseded-fingerprint entries can never hit
            # again — O(evicted) via the per-dataset fingerprint index
            srv._evict_stale(work.dataset, handle.fingerprint)
        except BaseException as e:  # surfaced to every request of this work
            work.merge_error = e

    def _dispatch(self, work: _Work) -> List[Tuple[Any, Tuple[str, Any]]]:
        """Serve one dataset's window share (runs on a worker thread).

        Cache probes first; misses that fit the stacked engine group into
        ``reduce_many`` dispatches (identical configs collapse — the
        window-level half of in-flight dedup); everything else runs solo.
        """
        srv = self.srv
        handle = srv._handles[work.dataset]
        fp = handle.fingerprint
        for req in work.requests:
            req.timing.mark_start()
            req.merged_batches = len(work.batches)

        outcome: Dict[int, Tuple[str, Any]] = {}
        # stackable misses: group key (sorted shared items) → list of
        # (config, params-dict, [requests])  — identical configs share slots
        groups: Dict[tuple, List[Tuple[dict, dict, List[Any]]]] = {}

        for req in work.requests:
            srv._bump("queries", 1)
            if req.configs is not None:
                outcome[req.rid] = self._serve_ensemble(handle, req, fp)
                continue
            key = (work.dataset, fp, req.delta, req.params)
            hit = srv._cache_get(key)
            if hit is not None:
                req.cached = True
                srv._bump("cache_hits", 1)
                outcome[req.rid] = ("ok", hit)
                continue
            params = dict(req.params)
            split = partition_reduce_params(req.delta, params)
            if split is None or not self.batching:
                outcome[req.rid] = self._serve_solo(handle, req, key, params)
                continue
            config, shared = split
            gkey = tuple(sorted(shared.items()))
            members = groups.setdefault(gkey, [])
            for cfg, _p, reqs in members:
                if cfg == config:          # in-window dedup: same config,
                    reqs.append(req)       # one engine slot
                    break
            else:
                members.append((config, params, [req]))

        for gkey, members in groups.items():
            self._serve_group(handle, dict(gkey), members, fp, outcome)

        results: List[Tuple[Any, Tuple[str, Any]]] = []
        for req in work.requests:
            req.timing.mark_done()
            req.latency_s = req.timing.service_s
            srv.metrics.observe(req.timing, req.batch_size)
            srv.requests.append(req)
            results.append((req, outcome[req.rid]))
        return results

    # -- dispatch units ------------------------------------------------------

    def _serve_solo(self, handle, req, key, params) -> Tuple[str, Any]:
        """The PR 5 path: one query, one engine run (warm repair when the
        handle knows a previous result) — for queries the stacked engine
        cannot express, and every query of a ``batching=False`` server."""
        srv = self.srv
        try:
            result = handle.reduce(req.delta, **params)
        except BaseException as e:
            return ("err", e)
        srv._cache_put(key, result)
        req.warm = handle.last_was_warm
        req.prefix_kept = handle.last_prefix_kept
        req.batch_size = 1
        srv._bump("warm" if req.warm else "cold", 1)
        srv._bump("engine_runs", 1)
        srv.metrics.observe_dispatch(1)
        return ("ok", result)

    def _serve_group(self, handle, shared: dict, members, fp,
                     outcome: Dict[int, Tuple[str, Any]]) -> None:
        """One stacked ``reduce_many`` dispatch for a shared-knob group of
        heterogeneous configs; results fan out to every deduped request."""
        srv = self.srv
        if len(members) == 1:
            # a lone config gains nothing from stacking: keep the PR 5 solo
            # warm-repair path (byte-identical either way — §3.8 parity)
            _cfg, params, reqs = members[0]
            lead = reqs[0]
            key = (lead.dataset, fp, lead.delta, lead.params)
            out = self._serve_solo(handle, lead, key, params)
            for req in reqs:
                req.warm = lead.warm
                req.prefix_kept = lead.prefix_kept
                req.batch_size = lead.batch_size
                outcome[req.rid] = out
            return
        queries = [(cfg["delta"], {k: v for k, v in cfg.items()
                                   if k != "delta"})
                   for cfg, _p, _r in members]
        n_queries = sum(len(reqs) for _c, _p, reqs in members)
        try:
            results, kept, was_warm = handle.reduce_many(queries, **shared)
        except BaseException as e:
            for _cfg, _params, reqs in members:
                for req in reqs:
                    outcome[req.rid] = ("err", e)
            return
        srv._bump("engine_runs", 1)
        srv.metrics.observe_dispatch(n_queries)
        for (cfg, params, reqs), result, k, warm in zip(
                members, results, kept, was_warm):
            key = (reqs[0].dataset, fp, reqs[0].delta, reqs[0].params)
            srv._cache_put(key, result)
            srv._bump("warm" if warm else "cold", 1)
            for req in reqs:
                req.warm = warm
                req.prefix_kept = k
                req.batch_size = n_queries
                outcome[req.rid] = ("ok", result)

    def _serve_ensemble(self, handle, req, fp) -> Tuple[str, Any]:
        """Serve a ``query_ensemble`` grid: per-config cache probes, one
        stacked run for exactly the missing configs (DESIGN.md §3.8)."""
        srv = self.srv
        shared = dict(req.params)
        srv._bump("ensemble_queries", 1)
        srv._bump("ensemble_configs", len(req.configs))

        grid = [dict(items) for items in req.configs]
        keys = []
        for c in grid:
            delta = c.get("delta", "PR")
            params = {**shared,
                      **{k: v for k, v in c.items() if k != "delta"}}
            keys.append((req.dataset, fp, delta,
                         tuple(sorted(params.items()))))

        results: List[Optional[Any]] = []
        misses: List[int] = []
        for j, key in enumerate(keys):
            hit = srv._cache_get(key)
            if hit is not None:
                srv._bump("cache_hits", 1)
            else:
                misses.append(j)
            results.append(hit)
        if misses:
            try:
                fresh = handle.reduce_ensemble(
                    [grid[j] for j in misses], **shared)
            except BaseException as e:
                return ("err", e)
            srv._bump("engine_runs", 1)
            srv.metrics.observe_dispatch(len(misses))
            for j, r in zip(misses, fresh):
                srv._cache_put(keys[j], r)
                results[j] = r
            srv._bump("cold", len(misses))
        req.cached = not misses
        req.batch_size = len(misses)
        return ("ok", results)
