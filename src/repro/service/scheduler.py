"""Multi-tenant batched dispatch for the reduct server (DESIGN.md §3.9/§3.10).

The PR 5 worker was single-flight: one queue, one request per engine
dispatch.  This scheduler replaces it with *cross-query batching* — the
continuous-batching idiom of ``serving/engine.py`` applied to attribute
reduction:

* **Window** — when a request is picked up, the queue is drained
  non-blocking; everything already queued forms the batching window.
  Requests arriving during a dispatch wait for the next window, so the
  window needs no timer and adds zero latency to a lone request.
* **Grouping** — window requests are grouped per dataset.  Within a
  dataset, cache misses whose ``(delta, params)`` can be expressed on the
  stacked §3.8 engine (``partition_reduce_params``) and whose *shared*
  knobs agree are served by ONE ``DatasetHandle.reduce_many`` dispatch:
  heterogeneous per-config knobs (measure, tol, max_features, ...) ride
  the traced `EnsembleOperands`, warm members resume from their previous
  reducts via the per-config ``warm_start`` operand.  Results are
  byte-identical to serving each query alone (stacked-vs-sequential
  parity, §3.8 + §3.7 repair), so answers never depend on grouping.
* **Merge/dispatch overlap** — each dataset's pending update batches are
  coalesced into one monoid merge on a worker thread; merges for datasets
  B, C, ... run while dataset A's engine dispatch is in flight (engine
  dispatches themselves stay serialized — JAX serializes them anyway, and
  serializing keeps the §3.7 coalescing window well-defined per dataset).
* **Admission control** — the queue is bounded; over-capacity submits
  fail fast with :class:`ServerOverloaded` (raised by the server's
  ``query``/``query_ensemble``).

Failure hardening (DESIGN.md §3.10): every engine dispatch and coalescing
merge runs through :meth:`Scheduler._attempt` — fault-plan injection,
optional timeout, and bounded exponential-backoff retry of *transient*
errors (:func:`is_transient`).  Deterministic errors (``ValueError`` from a
bad config) are never retried.  A query config that keeps failing is
**quarantined**: after ``RetryPolicy.quarantine_after`` exhausted attempts
its followers get a typed :class:`QueryPoisoned` immediately instead of
re-running the dispatch or wedging the shared dedup future; the quarantine
clears when the dataset's content changes (the merge may fix it).  With
``serve_stale=True`` a failed dispatch degrades gracefully: the last
known-good result for that config is served flagged ``stale=True`` instead
of erroring.  A failed *stacked* dispatch falls back to per-member solo
serves, so one poisoned member cannot take down its whole group.

The scheduler runs as one asyncio task inside :class:`ReductServer`; all
JAX work happens in ``asyncio.to_thread`` so the event loop keeps
admitting, deduplicating, and rejecting while engines run.
"""
from __future__ import annotations

import asyncio
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.reduction import partition_reduce_params

from .errors import QueryPoisoned, ServerOverloaded, ServerStopped
from .faults import FaultInjected

__all__ = ["Scheduler", "RetryPolicy", "ServerOverloaded", "is_transient"]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential-backoff retry for dispatches and merges.

    ``max_attempts`` counts total tries (1 = no retry); backoff sleeps
    ``base_delay_s · 2^i`` capped at ``max_delay_s`` between them — on the
    dispatching worker thread, so the event loop keeps admitting.
    ``timeout_s`` (None = off) bounds one attempt; a timed-out attempt
    counts as transient.  NOTE: Python cannot preempt a running JAX
    dispatch — a timed-out attempt's thread is abandoned to finish in the
    background, so enable timeouts only where duplicated work is acceptable.
    ``quarantine_after`` exhausted dispatch failures poison the query config
    (:class:`QueryPoisoned` for followers) until the dataset's content
    changes.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.02
    max_delay_s: float = 1.0
    timeout_s: Optional[float] = None
    quarantine_after: int = 2


def is_transient(exc: BaseException) -> bool:
    """Transient (retry) vs deterministic (fail fast) classification.

    Infrastructure-shaped failures — injected faults flagged transient,
    timeouts, I/O errors — are worth retrying; ``ValueError``/``TypeError``
    and friends are properties of the *query*, and retrying them only
    burns engine time reproducing the same exception.
    """
    if isinstance(exc, FaultInjected):
        return exc.transient
    return isinstance(exc, (TimeoutError, ConnectionError, OSError))


def _call_with_timeout(fn, timeout_s: Optional[float]):
    """Run ``fn()`` with a wall-clock bound.  On timeout the worker thread
    is abandoned (daemon) and ``TimeoutError`` raised — see RetryPolicy."""
    if not timeout_s:
        return fn()
    box: Dict[str, Any] = {}
    done = threading.Event()

    def run():
        try:
            box["ok"] = fn()
        except BaseException as e:
            box["err"] = e
        finally:
            done.set()

    t = threading.Thread(target=run, daemon=True)
    t.start()
    if not done.wait(timeout_s):
        raise TimeoutError(f"dispatch exceeded timeout_s={timeout_s}")
    if "err" in box:
        raise box["err"]
    return box["ok"]


class _Work:
    """One dataset's share of a batching window: its requests (arrival
    order) and the update batches captured for its coalesced merge."""

    __slots__ = ("dataset", "requests", "batches", "merge_error", "merged")

    def __init__(self, dataset: str) -> None:
        self.dataset = dataset
        self.requests: List[Any] = []
        self.batches: List[Tuple[np.ndarray, np.ndarray]] = []
        self.merge_error: Optional[BaseException] = None
        self.merged = False


class Scheduler:
    """Drains the server queue in windows and dispatches batched work.

    ``batching=False`` degrades to the PR 5 single-flight worker — one
    request per window, solo dispatch — which is the benchmark baseline
    (``benchmarks/serve_bench.py``).  ``retry``/``fault_plan``/
    ``serve_stale`` are the §3.10 resilience knobs (module docstring).
    """

    def __init__(self, server, *, batching: bool = True,
                 retry: Optional[RetryPolicy] = None,
                 fault_plan=None, serve_stale: bool = False) -> None:
        self.srv = server
        self.batching = batching
        self.retry = retry or RetryPolicy()
        self.fault_plan = fault_plan
        self.serve_stale = serve_stale

    # -- resilience primitives ----------------------------------------------

    def _attempt(self, site: str, fn):
        """Fault injection + timeout + bounded-backoff retry around one
        dispatch or merge.  Transient failures retry up to
        ``retry.max_attempts``; the last error (or the first deterministic
        one) propagates to the caller's classification logic."""
        delay = self.retry.base_delay_s
        for attempt in range(self.retry.max_attempts):
            try:
                if self.fault_plan is not None:
                    self.fault_plan.inject(site)
                return _call_with_timeout(fn, self.retry.timeout_s)
            except BaseException as e:
                last_try = attempt + 1 >= self.retry.max_attempts
                if last_try or not is_transient(e):
                    raise
                self.srv._bump("retries", 1)
                obs.event("scheduler.retry", site=site, attempt=attempt + 1,
                          error=f"{type(e).__name__}: {e}")
                time.sleep(delay)
                delay = min(delay * 2, self.retry.max_delay_s)

    def _dispatch_failed(self, qkey: tuple, exc: BaseException,
                         stale_key: Optional[tuple]) -> Tuple[str, Any]:
        """Post-mortem of an exhausted dispatch: record the failure toward
        quarantine, then either degrade to the last known-good result
        (flagged ``stale=True``) or surface the error."""
        srv = self.srv
        srv._record_failure(qkey, exc, self.retry.quarantine_after)
        if self.serve_stale and stale_key is not None:
            stale = srv._last_good_get(stale_key)
            if stale is not None:
                srv._bump("stale_served", 1)
                obs.event("scheduler.stale_served", dataset=qkey[0],
                          query=qkey[1])
                return ("ok", dataclasses.replace(stale, stale=True))
        obs.event("scheduler.dispatch_failed", dataset=qkey[0],
                  query=qkey[1], error=f"{type(exc).__name__}: {exc}")
        return ("err", exc)

    # -- the worker loop ----------------------------------------------------

    async def run(self, stop_marker: object) -> None:
        queue = self.srv._queue
        while True:
            req = await queue.get()
            if req is stop_marker or self.srv._stopping:
                self._shutdown(stop_marker,
                               [] if req is stop_marker else [req])
                return
            window = [req]
            if self.batching:
                # the batching window: everything already queued rides along
                while True:
                    try:
                        nxt = queue.get_nowait()
                    except asyncio.QueueEmpty:
                        break
                    if nxt is stop_marker:
                        self._shutdown(stop_marker, window)
                        return
                    window.append(nxt)
            works = self._plan(window)
            with obs.span("scheduler.window", requests=len(window),
                          datasets=len(works)):
                await self._execute(works)
            if any(w.merged for w in works):
                self.srv._note_merged()

    def _shutdown(self, stop_marker: object, pending: List[Any]) -> None:
        """Drain the queue on stop: queued-but-unstarted requests fail fast
        with :class:`ServerStopped` instead of hanging forever (their work
        will never run)."""
        queue = self.srv._queue
        while True:
            try:
                nxt = queue.get_nowait()
            except asyncio.QueueEmpty:
                break
            if nxt is not stop_marker:
                pending.append(nxt)
        for req in pending:
            if not req.future.done():
                req.future.set_exception(ServerStopped("server stopped"))

    # -- planning (event loop: may touch _pending without locks) ------------

    def _plan(self, window: List[Any]) -> List[_Work]:
        """Group the window per dataset (first-arrival order) and capture
        each dataset's pending update batches for the coalesced merge."""
        works: Dict[str, _Work] = {}
        for req in window:
            work = works.get(req.dataset)
            if work is None:
                work = works[req.dataset] = _Work(req.dataset)
            work.requests.append(req)
        for work in works.values():
            work.batches = self.srv._pending.pop(work.dataset, [])
        return list(works.values())

    # -- execution ----------------------------------------------------------

    async def _execute(self, works: List[_Work]) -> None:
        # kick every dataset's coalescing merge off immediately: dataset B's
        # host-side merge overlaps dataset A's engine dispatch (the
        # continuous-batching overlap; handles are disjoint per dataset and
        # the result cache is lock-guarded)
        merges = {
            work.dataset: asyncio.create_task(
                asyncio.to_thread(self._merge, work))
            for work in works
        }
        for work in works:
            await merges[work.dataset]
            if work.merge_error is not None:
                outcomes = [(req, ("err", work.merge_error))
                            for req in work.requests]
            else:
                outcomes = await asyncio.to_thread(self._dispatch, work)
            for req, (kind, payload) in outcomes:
                if req.future.cancelled():
                    continue
                if kind == "ok":
                    req.future.set_result(payload)
                else:
                    req.future.set_exception(payload)

    def _merge(self, work: _Work) -> None:
        """Coalesce one dataset's buffered update batches into ONE monoid
        merge, then evict the dataset's superseded cache entries (runs on a
        worker thread; may overlap another dataset's engine dispatch).
        Retried under the §3.10 policy: a transient fault mid-merge loses
        nothing — the batches stay captured in this work item and the next
        attempt re-folds them."""
        srv = self.srv
        if not work.batches:
            return
        try:
            handle = srv._handles[work.dataset]
            xs = np.concatenate([b[0] for b in work.batches])
            ds = np.concatenate([b[1] for b in work.batches])
            with obs.span("scheduler.merge", dataset=work.dataset,
                          batches=len(work.batches), rows=int(xs.shape[0])):
                self._attempt("merge", lambda: handle.update(xs, ds))
            srv._bump("merges", 1)
            srv._bump("coalesced_batches", len(work.batches))
            work.merged = True
            # content moved on: superseded-fingerprint entries can never hit
            # again — O(evicted) via the per-dataset fingerprint index —
            # and the new content gets a clean quarantine slate
            srv._evict_stale(work.dataset, handle.fingerprint)
            srv._clear_failures(work.dataset)
        except BaseException as e:  # surfaced to every request of this work
            work.merge_error = e

    def _dispatch(self, work: _Work) -> List[Tuple[Any, Tuple[str, Any]]]:
        """Serve one dataset's window share (runs on a worker thread).

        Cache probes first; misses that fit the stacked engine group into
        ``reduce_many`` dispatches (identical configs collapse — the
        window-level half of in-flight dedup); everything else runs solo.
        """
        srv = self.srv
        handle = srv._handles[work.dataset]
        fp = handle.fingerprint
        for req in work.requests:
            req.timing.mark_start()
            req.merged_batches = len(work.batches)

        outcome: Dict[int, Tuple[str, Any]] = {}
        # stackable misses: group key (sorted shared items) → list of
        # (config, params-dict, [requests])  — identical configs share slots
        groups: Dict[tuple, List[Tuple[dict, dict, List[Any]]]] = {}

        for req in work.requests:
            srv._bump("queries", 1)
            if req.configs is not None:
                outcome[req.rid] = self._serve_ensemble(handle, req, fp)
                continue
            key = (work.dataset, fp, req.delta, req.params)
            hit = srv._cache_get(key)
            if hit is not None:
                req.cached = True
                srv._bump("cache_hits", 1)
                outcome[req.rid] = ("ok", hit)
                continue
            poison = srv._poisoned(self._qkey(req))
            if poison is not None:
                outcome[req.rid] = ("err", poison)
                continue
            params = dict(req.params)
            split = partition_reduce_params(req.delta, params)
            if split is None or not self.batching:
                outcome[req.rid] = self._serve_solo(handle, req, key, params)
                continue
            config, shared = split
            gkey = tuple(sorted(shared.items()))
            members = groups.setdefault(gkey, [])
            for cfg, _p, reqs in members:
                if cfg == config:          # in-window dedup: same config,
                    reqs.append(req)       # one engine slot
                    break
            else:
                members.append((config, params, [req]))

        for gkey, members in groups.items():
            self._serve_group(handle, dict(gkey), members, fp, outcome)

        results: List[Tuple[Any, Tuple[str, Any]]] = []
        for req in work.requests:
            req.timing.mark_done()
            req.latency_s = req.timing.service_s
            srv.metrics.observe(req.timing)
            srv.requests.append(req)
            results.append((req, outcome[req.rid]))
        return results

    # -- dispatch units ------------------------------------------------------

    @staticmethod
    def _qkey(req) -> tuple:
        """Quarantine/last-good key: the query config *without* the content
        fingerprint — a poisoned config stays poisoned across retries on the
        same content, and the slate clears when content changes."""
        return (req.dataset, req.delta, req.params, req.configs)

    def _serve_solo(self, handle, req, key, params) -> Tuple[str, Any]:
        """The PR 5 path: one query, one engine run (warm repair when the
        handle knows a previous result) — for queries the stacked engine
        cannot express, and every query of a ``batching=False`` server."""
        srv = self.srv
        qkey = self._qkey(req)
        try:
            with obs.span("scheduler.dispatch", dataset=req.dataset,
                          delta=req.delta, kind="solo"):
                result = self._attempt(
                    "dispatch", lambda: handle.reduce(req.delta, **params))
        except BaseException as e:
            return self._dispatch_failed(qkey, e, qkey)
        srv._cache_put(key, result)
        srv._last_good_put(qkey, result)
        req.warm = handle.last_was_warm
        req.prefix_kept = handle.last_prefix_kept
        req.batch_size = 1
        srv._bump("warm" if req.warm else "cold", 1)
        srv._bump("engine_runs", 1)
        srv.metrics.observe_dispatch(1)
        return ("ok", result)

    def _serve_group(self, handle, shared: dict, members, fp,
                     outcome: Dict[int, Tuple[str, Any]]) -> None:
        """One stacked ``reduce_many`` dispatch for a shared-knob group of
        heterogeneous configs; results fan out to every deduped request.
        If the stacked dispatch exhausts its retries, the group degrades to
        per-member solo serves: one poisoned member costs its own
        requesters, never the whole group."""
        srv = self.srv
        if len(members) == 1:
            # a lone config gains nothing from stacking: keep the PR 5 solo
            # warm-repair path (byte-identical either way — §3.8 parity)
            _cfg, params, reqs = members[0]
            lead = reqs[0]
            key = (lead.dataset, fp, lead.delta, lead.params)
            out = self._serve_solo(handle, lead, key, params)
            for req in reqs:
                req.warm = lead.warm
                req.prefix_kept = lead.prefix_kept
                req.batch_size = lead.batch_size
                outcome[req.rid] = out
            return
        queries = [(cfg["delta"], {k: v for k, v in cfg.items()
                                   if k != "delta"})
                   for cfg, _p, _r in members]
        n_queries = sum(len(reqs) for _c, _p, reqs in members)
        try:
            with obs.span("scheduler.dispatch", dataset=members[0][2][0]
                          .dataset, kind="stacked", configs=len(members),
                          queries=n_queries):
                results, kept, was_warm = self._attempt(
                    "dispatch",
                    lambda: handle.reduce_many(queries, **shared))
        except BaseException:
            # stacked path failed: serve members individually — each solo
            # serve brings its own retry/quarantine/stale handling
            for _cfg, params, reqs in members:
                lead = reqs[0]
                key = (lead.dataset, fp, lead.delta, lead.params)
                out = self._serve_solo(handle, lead, key, params)
                for req in reqs:
                    req.warm = lead.warm
                    req.prefix_kept = lead.prefix_kept
                    req.batch_size = lead.batch_size
                    outcome[req.rid] = out
            return
        srv._bump("engine_runs", 1)
        srv.metrics.observe_dispatch(n_queries)
        for (cfg, params, reqs), result, k, warm in zip(
                members, results, kept, was_warm):
            key = (reqs[0].dataset, fp, reqs[0].delta, reqs[0].params)
            srv._cache_put(key, result)
            srv._last_good_put(self._qkey(reqs[0]), result)
            srv._bump("warm" if warm else "cold", 1)
            for req in reqs:
                req.warm = warm
                req.prefix_kept = k
                req.batch_size = n_queries
                outcome[req.rid] = ("ok", result)

    def _serve_ensemble(self, handle, req, fp) -> Tuple[str, Any]:
        """Serve a ``query_ensemble`` grid: per-config cache probes, one
        stacked run for exactly the missing configs (DESIGN.md §3.8)."""
        srv = self.srv
        shared = dict(req.params)
        srv._bump("ensemble_queries", 1)
        srv._bump("ensemble_configs", len(req.configs))
        qkey = self._qkey(req)
        poison = srv._poisoned(qkey)
        if poison is not None:
            return ("err", poison)

        grid = [dict(items) for items in req.configs]
        keys = []
        for c in grid:
            delta = c.get("delta", "PR")
            params = {**shared,
                      **{k: v for k, v in c.items() if k != "delta"}}
            keys.append((req.dataset, fp, delta,
                         tuple(sorted(params.items()))))

        results: List[Optional[Any]] = []
        misses: List[int] = []
        for j, key in enumerate(keys):
            hit = srv._cache_get(key)
            if hit is not None:
                srv._bump("cache_hits", 1)
            else:
                misses.append(j)
            results.append(hit)
        if misses:
            try:
                with obs.span("scheduler.dispatch", dataset=req.dataset,
                              kind="ensemble", configs=len(misses)):
                    fresh = self._attempt(
                        "dispatch",
                        lambda: handle.reduce_ensemble(
                            [grid[j] for j in misses], **shared))
            except BaseException as e:
                return self._dispatch_failed(qkey, e, None)
            srv._bump("engine_runs", 1)
            srv.metrics.observe_dispatch(len(misses))
            for j, r in zip(misses, fresh):
                srv._cache_put(keys[j], r)
                results[j] = r
            srv._bump("cold", len(misses))
        req.cached = not misses
        req.batch_size = len(misses)
        return ("ok", results)
