"""Online reduct state: a live, updatable granularity and its reducts.

The paper's GrC representation is explicitly a *cacheable* compressed form
of the decision table; PR 3 made its build a monoid fold.  This module is
the stateful consequence (DESIGN.md §3.7): a :class:`DatasetHandle` keeps a
device-resident :class:`~repro.core.granularity.Granularity` alive across
row-batch updates (``update`` = one ``merge_granularity`` fold, O(new rows),
pow2 capacity growth so engine compiles stay stable) and repairs its reducts
incrementally instead of recomputing them from scratch:

* **resume (optimistic)** — ``plar_reduce(warm_start=prev)`` folds the
  previous reduct through the engine's compiled while_loop
  (:func:`~repro.core.engine.init_state_from_reduct` +
  :func:`~repro.core.engine.engine_resume`) and continues greedy from
  there: prefix attributes cost one fold each — no candidate sweeps — and
  their re-recorded Θ-history entries double as the validation record;
* **validate + trim** — :func:`valid_prefix_len` keeps the longest prefix
  whose every attribute still strictly improves Θ (and cuts at the
  stopping target: anything after is redundant);
* **retry** — only when the prefix was trimmed does the reduction re-run
  from ``prev[:k]``; on stable streams the optimistic pass is final.

Repair is a heuristic with a hard guarantee: the result is always a valid
super-reduct (the greedy stopping rule re-checks Θ against the *current*
Θ(D|C)), but the prefix is kept on significance, not re-checked for
argmin-optimality — re-checking would cost exactly a full recompute.  On
incrementally grown tables the greedy prefix is stable and the repaired
reduct matches the from-scratch one (asserted end-to-end in
tests/test_service.py; measured in benchmarks/service_bench.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.granularity import Granularity, fold_chunk, row_fingerprints
from repro.core.measures import f32_threshold
from repro.core.recovery import ShardLineage, ShardedBuild, build_sharded, recover
from repro.core.reduction import (
    ReductionResult,
    expand_ensemble_grid,
    plar_reduce,
    plar_reduce_ensemble,
    resolve_granularity,
)

from .errors import ShardLost

__all__ = [
    "DatasetHandle",
    "granularity_fingerprint",
    "valid_prefix_len",
    "repair_reduce",
    "repair_reduce_many",
]

# Seeds for the content fingerprint — distinct from the GrC build seeds
# (0 / 7919) so the fingerprint is independent of the sort bucketing.
_FP_SEED_1 = 104_729
_FP_SEED_2 = 1_299_709


@jax.jit
def _fp_sums(x, d, w, valid):
    """Two uint32 content sums over live granules (order-invariant)."""
    key = jnp.concatenate(
        [x, d[:, None].astype(x.dtype), w[:, None].astype(x.dtype)], axis=1)
    h1 = row_fingerprints(key, _FP_SEED_1)
    h2 = row_fingerprints(key, _FP_SEED_2)
    z = jnp.uint32(0)
    return (jnp.where(valid, h1, z).sum(dtype=jnp.uint32),
            jnp.where(valid, h2, z).sum(dtype=jnp.uint32))


def granularity_fingerprint(gran: Granularity) -> int:
    """64-bit content fingerprint of a granularity (the cache key half).

    Hash of the *live* ``(row, d, w)`` multiset: summing per-granule
    fingerprints makes it invariant to slot order and padding capacity, so
    a streamed build and a monolithic build of the same rows fingerprint
    identically (tests/test_service.py::test_fingerprint_content_invariance).
    Reuses the linear row-fingerprint machinery of the GrC build with
    service-private seeds.
    """
    s1, s2 = _fp_sums(gran.x, gran.d, gran.w, gran.valid)
    return (int(s1) << 32) | int(s2)


def valid_prefix_len(theta_history: Sequence[float], theta_full: float, *,
                     tol: float = 1e-6, tie_tol: float = 1e-5) -> int:
    """Longest still-valid prefix given its re-recorded Θ history.

    ``theta_history[i]`` must be Θ(D|prefix[:i+1]) on the *current*
    granularity (what :func:`~repro.core.engine.init_state_from_reduct`
    records).  An attribute stays valid while it still strictly improves Θ
    beyond the tie tolerance — the same band ``argmin_with_ties`` treats as
    indistinguishable; an attribute whose fold no longer clears it would not
    be picked by any greedy iteration.  The prefix is also cut right after
    Θ first reaches the stopping target (``f32_threshold(theta_full, tol)``,
    the engine's own f32 stopping arithmetic): later attributes are
    redundant, so updates can *shrink* a reduct, not only extend it.
    """
    stop = f32_threshold(theta_full, tol)
    prev = float("inf")
    k = 0
    for t in theta_history:
        t = float(t)
        if prev - t <= tie_tol:
            break
        k += 1
        prev = t
        if t <= stop:
            break
    return k


def repair_reduce(gran: Granularity, prev_reduct: Sequence[int], *,
                  delta: str = "PR", **params) -> Tuple[ReductionResult, int]:
    """Validate-and-repair: warm-start a reduction from a previous reduct.

    Returns ``(result, prefix_kept)``.  Optimistic single pass: resume
    greedy directly from the full previous reduct — one driver call whose
    first ``len(prev)`` Θ-history entries (the forced folds, no candidate
    sweeps) double as the validation record.  Only when
    :func:`valid_prefix_len` finds a stale prefix attribute (no longer
    improving Θ, or past an already-reached stopping target) does the
    reduction re-run once from the trimmed prefix; on stable streams the
    common case is exactly one engine seed + resume and one Θ(D|C)
    evaluation.

    Adversarial previous reducts (a stale or corrupt checkpoint, §3.10) are
    sanitized rather than handed to the engine: attributes outside
    ``[0, n_attrs)`` and duplicates are dropped from the warm-start prefix
    (first occurrence wins).  The result is still a valid reduct — the
    warm start is only a hint; validation and the stopping rule run against
    the *current* granularity either way.
    """
    seen: set = set()
    prev = [int(a) for a in prev_reduct
            if 0 <= int(a) < gran.n_attrs
            and not (int(a) in seen or seen.add(int(a)))]
    if not prev:
        return plar_reduce(source=gran, delta=delta, **params), 0

    tol = float(params.get("tol", 1e-6))
    tie_tol = float(params.get("tie_tol", 1e-5))
    result = plar_reduce(source=gran, delta=delta, warm_start=prev, **params)
    k = valid_prefix_len(result.theta_history[: len(prev)], result.theta_full,
                         tol=tol, tie_tol=tie_tol)
    if k == len(prev):
        return result, k
    result = plar_reduce(source=gran, delta=delta, warm_start=prev[:k],
                         **params)
    return result, k


def repair_reduce_many(
    gran: Granularity,
    configs: Sequence[dict],
    prevs: Sequence[Optional[Sequence[int]]],
    **shared,
) -> Tuple["list[ReductionResult]", "list[int]"]:
    """The batched twin of :func:`repair_reduce`: one *stacked* dispatch
    repairs (or cold-runs) a heterogeneous group of configs over one
    granularity (DESIGN.md §3.9).

    ``configs[j]`` is a per-config dict (``delta`` + §3.8 grid knobs);
    ``prevs[j]`` is the previous reduct to warm-resume from (``None``/empty
    = cold member: core computed, greedy from scratch).  The whole group
    runs through ONE :func:`~repro.core.reduction.plar_reduce_ensemble`
    call — warm members ride the per-config ``warm_start`` operand — then
    every warm member validates its prefix with :func:`valid_prefix_len`,
    and only the *trimmed* members re-run, again as one (smaller) stacked
    grid.  Returns ``(results, prefix_kept)`` in input order.

    Parity contract: member ``j`` is byte-identical (reduct + Θ history) to
    the solo path — ``repair_reduce(gran, prevs[j], ...)`` when warm,
    ``plar_reduce(source=gran, ...)`` when cold — because the stacked
    engine's per-config trajectories are byte-identical to sequential runs
    (§3.8) and the validate/trim/retry logic here is the same code path as
    the solo repair.  Answers therefore never depend on how the serving
    scheduler happened to group queries.
    """
    if len(configs) != len(prevs):
        raise ValueError(
            f"configs ({len(configs)}) and prevs ({len(prevs)}) must align")

    def member(cfg: dict, prev) -> dict:
        prev = [int(a) for a in prev] if prev else None
        return {**cfg, "warm_start": prev} if prev else dict(cfg)

    grid = [member(c, p) for c, p in zip(configs, prevs)]
    results = list(plar_reduce_ensemble(source=gran, configs=grid, **shared))

    kept = [0] * len(grid)
    retry_idx: list = []
    for j, (cfg, prev) in enumerate(zip(configs, prevs)):
        if not prev:
            continue
        tol = float(cfg.get("tol", 1e-6))
        tie_tol = float(cfg.get("tie_tol", 1e-5))
        k = valid_prefix_len(
            results[j].theta_history[: len(prev)], results[j].theta_full,
            tol=tol, tie_tol=tie_tol)
        kept[j] = k
        if k < len(prev):
            retry_idx.append(j)
    if retry_idx:
        # a fully-trimmed prefix retries with warm_start=[] — greedy from
        # scratch with the core skipped, exactly repair_reduce's
        # ``plar_reduce(warm_start=prev[:0])`` retry
        retry_grid = [
            {**configs[j],
             "warm_start": [int(a) for a in prevs[j][: kept[j]]]}
            for j in retry_idx
        ]
        fresh = plar_reduce_ensemble(source=gran, configs=retry_grid, **shared)
        for j, r in zip(retry_idx, fresh):
            results[j] = r
    return results, kept


@dataclasses.dataclass
class DatasetHandle:
    """Device-resident state of one evolving dataset (DESIGN.md §3.7).

    Holds the live :class:`Granularity`, the last
    :class:`~repro.core.reduction.ReductionResult` per reduction config
    (the warm-start prefixes and their Θ histories), and a content
    fingerprint.  ``update`` absorbs a row batch in O(batch + live granules)
    via the §3.6 monoid merge; ``reduce`` answers with a warm repair when a
    previous result exists for the config, a cold run otherwise.
    """

    gran: Granularity
    exact: bool = True
    n_updates: int = 0
    rows_absorbed: int = 0
    last_prefix_kept: int = 0
    last_was_warm: bool = False
    # shard lineage (DESIGN.md §3.10): set by create_sharded(); persisted by
    # service/checkpoint.py as replay metadata — a lost shard re-folds from
    # its recorded chunk ranges instead of triggering a full rebuild
    lineage: Optional[Tuple[ShardLineage, ...]] = None
    _sharded: Optional[ShardedBuild] = None
    _results: Dict[tuple, ReductionResult] = dataclasses.field(
        default_factory=dict)
    _fp: Optional[int] = None  # fingerprint cache, invalidated by update()

    @classmethod
    def create(cls, x=None, d=None, *, source=None, n_dec: Optional[int] = None,
               v_max: Optional[int] = None, exact: bool = True,
               chunk_rows: int = 65536) -> "DatasetHandle":
        """Build the initial granularity from arrays, a GranuleSource, or a
        prebuilt Granularity.  Raw arrays require explicit ``n_dec``/
        ``v_max``: an online dataset will see rows beyond the first batch,
        so inferred cardinalities would make later updates ill-defined
        (merge metadata must match, and packed ids must stay in range).
        """
        if source is None and (n_dec is None or v_max is None):
            raise ValueError(
                "DatasetHandle.create from raw arrays requires explicit "
                "n_dec and v_max (future updates must fit the declared "
                "cardinalities)")
        gran = resolve_granularity(
            x, d, source=source, n_dec=n_dec, v_max=v_max, exact=exact,
            chunk_rows=chunk_rows)
        return cls(gran=gran, exact=exact,
                   rows_absorbed=int(gran.n_total))

    @classmethod
    def create_sharded(cls, source, n_shards: int, *,
                       chunk_rows: int = 65536, exact: bool = True,
                       fault_plan=None) -> "DatasetHandle":
        """Build from a GranuleSource as ``n_shards`` lineage-tracked data
        shards (:func:`~repro.core.recovery.build_sharded`).  The handle
        serves reductions from the merged granularity exactly like
        :meth:`create`, but keeps the per-shard granularities and their
        :class:`~repro.core.recovery.ShardLineage` recipes alive so a lost
        shard costs one re-fold (:meth:`recover_shards`), not a rebuild.
        """
        build = build_sharded(source, n_shards, chunk_rows=chunk_rows,
                              exact=exact, fault_plan=fault_plan)
        h = cls(gran=build.merged, exact=exact,
                rows_absorbed=int(build.merged.n_total),
                lineage=tuple(build.lineages))
        h._sharded = build
        return h

    @property
    def lost_shards(self) -> "list[int]":
        return list(self._sharded.lost) if self._sharded is not None else []

    def drop_shard(self, shard_index: int) -> None:
        """Simulate shard loss (the chaos harness's shard_drop fault)."""
        if self._sharded is None:
            raise ShardLost(
                "handle holds no sharded build (create_sharded required)",
                shard_index=shard_index)
        self._sharded.drop(shard_index)

    def recover_shards(self, source) -> "list[int]":
        """Re-fold every lost shard from its lineage and re-merge.

        The recovered merged granularity is bitwise identical to the
        pre-loss one (deterministic replay, §3.10), so the fingerprint —
        and every cached reduct's validity — is unchanged; asserted by
        tests/test_recovery.py.  Raises :class:`ShardLost` when the handle
        has no lineage to replay from.
        """
        if self._sharded is None:
            raise ShardLost("handle holds no shard lineage to recover from")
        recovered = recover(self._sharded, source)
        if recovered:
            self.gran = self._sharded.merged
            self._fp = None
        return recovered

    @property
    def fingerprint(self) -> int:
        if self._fp is None:
            self._fp = granularity_fingerprint(self.gran)
        return self._fp

    @property
    def n_granules(self) -> int:
        return int(self.gran.num)

    def validate_batch(self, x, d) -> Tuple[np.ndarray, np.ndarray]:
        """Check a row batch against the declared schema *without* folding.

        Exposed so the server can reject bad batches at ``update()`` time —
        before they are buffered next to valid ones — rather than losing the
        whole coalesced merge at query time.
        """
        x = np.asarray(x, np.int32)
        d = np.asarray(d, np.int32)
        if x.ndim != 2 or x.shape[1] != self.gran.n_attrs:
            raise ValueError(
                f"update batch has {x.shape[1] if x.ndim == 2 else '?'} "
                f"attributes, dataset has {self.gran.n_attrs}")
        if d.shape != (x.shape[0],):
            raise ValueError(
                f"decision shape {d.shape} does not match {x.shape[0]} rows")
        if x.size and not 0 <= int(x.min()) <= int(x.max()) < self.gran.v_max:
            raise ValueError(
                f"update batch values [{int(x.min())}, {int(x.max())}] "
                f"outside the declared v_max range [0, {self.gran.v_max})")
        if d.size and not 0 <= int(d.min()) <= int(d.max()) < self.gran.n_dec:
            raise ValueError(
                f"update batch decisions [{int(d.min())}, {int(d.max())}] "
                f"outside the declared n_dec range [0, {self.gran.n_dec})")
        return x, d

    def update(self, x, d) -> None:
        """Fold one row batch into the granularity (one monoid merge).

        Capacity follows the §3.6 pow2 policy (``fold_chunk``), so the
        engine's static ``n_bins = cap·v_max`` — and therefore its compile —
        only changes when the live granule count crosses a power of two.
        """
        x, d = self.validate_batch(x, d)
        folded = fold_chunk(self.gran, x, d, n_dec=self.gran.n_dec,
                            v_max=self.gran.v_max, exact=self.exact)
        if folded is not self.gran:  # empty batches are identity
            self.gran = folded
            self._fp = None
            # streamed rows are not replayable from the source lineage —
            # once the handle absorbs online updates, durability comes from
            # checkpoints (service/checkpoint.py), not shard re-folds
            self._sharded = None
            self.lineage = None
        self.n_updates += 1
        self.rows_absorbed += int(x.shape[0])

    def reduce(self, delta: str = "PR", *, warm: bool = True,
               **params) -> ReductionResult:
        """Reduct for the current granularity under ``(delta, params)``.

        Warm-repairs from the last result of the same config when one
        exists (``warm=False`` forces a cold run — the benchmark baseline).
        The handle's ``exact`` mode rides along unless the caller overrides
        it, so a hashed-id (``exact=False``) handle is reduced with the same
        id regime it was built and updated with.
        """
        params = {"exact": self.exact, **params}
        key = (delta, tuple(sorted(params.items())))
        prev = self._results.get(key)
        if warm and prev is not None:
            r, kept = repair_reduce(self.gran, prev.reduct, delta=delta,
                                    **params)
            self.last_prefix_kept = kept
            self.last_was_warm = True
        else:
            r = plar_reduce(source=self.gran, delta=delta, **params)
            self.last_prefix_kept = 0
            self.last_was_warm = False
        self._results[key] = r
        return r

    def reduce_many(self, queries, **shared) -> "list[ReductionResult]":
        """A heterogeneous group of single-config queries as ONE stacked
        dispatch — the scheduler's batched hot path (DESIGN.md §3.9).

        ``queries`` is a list of ``(delta, params)`` pairs whose ``params``
        are per-config §3.8 grid knobs; ``shared`` holds the group's common
        driver kwargs (``backend``, ``mode``, ...), with the handle's
        ``exact`` mode riding along like :meth:`reduce`.  Each member
        warm-resumes from the handle's previous result for the same config
        when one exists (:func:`repair_reduce_many` — stacked validate/
        trim/retry), runs cold otherwise, and lands in the per-config
        result table under the same key :meth:`reduce` uses, so the two
        paths warm-start each other.  Returns ``(results, prefix_kept,
        was_warm)`` in query order; results are byte-identical to serving
        each query alone through :meth:`reduce`.
        """
        shared = {"exact": self.exact, **shared}
        configs, prevs, keys = [], [], []
        for delta, params in queries:
            config = {"delta": delta, **dict(params)}
            key = self.ensemble_result_key(config, shared)
            prev = self._results.get(key)
            configs.append(config)
            prevs.append(list(prev.reduct) if prev is not None else None)
            keys.append(key)
        results, kept = repair_reduce_many(self.gran, configs, prevs,
                                           **shared)
        for key, r in zip(keys, results):
            self._results[key] = r
        was_warm = [p is not None for p in prevs]
        self.last_was_warm = any(was_warm)
        self.last_prefix_kept = max(kept) if kept else 0
        return results, kept, was_warm

    @staticmethod
    def ensemble_result_key(config: dict, shared: dict) -> tuple:
        """The ``_results`` key an ensemble member is stored under.

        Built from the *explicitly provided* per-config fields (defaults not
        filled in) merged over the shared driver kwargs — the same shape
        :meth:`reduce` keys with, so ``reduce(delta, **same_params)`` later
        warm-starts from the matching ensemble member.  Bagged members carry
        their ``seed`` in the key and therefore never collide with unbagged
        reductions.
        """
        delta = config.get("delta", "PR")
        params = {**shared, **{k: v for k, v in config.items() if k != "delta"}}
        return (delta, tuple(sorted(params.items())))

    def reduce_ensemble(self, configs, *, seeds=None,
                        **shared) -> "list[ReductionResult]":
        """A whole config grid over the current granularity in one stacked
        engine dispatch (:func:`~repro.core.reduction.plar_reduce_ensemble`).

        ``configs``/``seeds`` follow the driver's grid semantics (configs ×
        bag seeds); ``shared`` kwargs (``backend``, ``ladder``, ``mode``,
        per-config defaults like ``tol``) go to the driver, with the
        handle's ``exact`` mode riding along like :meth:`reduce`.  Every
        member lands in the per-config result table under
        :meth:`ensemble_result_key`, so later single-config ``reduce``
        calls with matching params warm-start from it.
        """
        shared = {"exact": self.exact, **shared}
        grid = expand_ensemble_grid(configs, seeds)
        results = plar_reduce_ensemble(
            source=self.gran, configs=grid, **shared)
        for c, r in zip(grid, results):
            self._results[self.ensemble_result_key(c, shared)] = r
        self.last_prefix_kept = 0
        self.last_was_warm = False
        return results
