"""Request latency accounting + aggregate serving metrics (DESIGN.md §3.9).

Two layers, deliberately small:

* :class:`RequestTiming` — the per-request stamp triple every serving
  surface in the repo records: enqueue → start (admitted / batch formed) →
  done.  Both the reduct server's :class:`~repro.service.ReduceRequest`
  and the LM engine's :class:`~repro.serving.engine.Request` carry one, so
  "queue wait" and "service time" mean the same thing across subsystems.
* :class:`ServiceMetrics` — the aggregate view the multi-tenant scheduler
  feeds: bounded windows of wait/latency samples (p50/p99 without keeping
  every request alive), batch-occupancy accounting per engine dispatch,
  and monotonically increasing counters (dedup hits, admission rejects,
  engine runs) that tests assert exactly.

Everything here is host-side plain Python: no JAX, no locks beyond what
callers provide (the scheduler serializes engine dispatches; merge threads
touch only counters, which are guarded by the server's cache lock).

Since DESIGN.md §3.11 the counters and latency samples are backed by an
:class:`repro.obs.MetricsRegistry` — per instance, because tests and
benchmarks build many servers per process — so the same numbers that feed
``summary()`` also render on the Prometheus exposition.  ``summary()``
output is byte-compatible with the pre-registry version.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Sequence

from ..obs.registry import CounterMap, MetricsRegistry

__all__ = ["RequestTiming", "ServiceMetrics", "percentile"]

# Bounded sample-window depth: enough for stable p99 estimates under the
# benchmark firehose, small enough to never dominate server memory.
_WINDOW = 4096


@dataclasses.dataclass
class RequestTiming:
    """The three stamps of one request's life (``time.perf_counter``).

    ``t_enqueue`` — entered the queue; ``t_start`` — picked up by the
    scheduler (admitted into a batch / prefill started); ``t_done`` —
    result ready.  Derived views: ``queue_wait_s`` (enqueue → start),
    ``service_s`` (start → done), ``latency_s`` (enqueue → done).
    """

    t_enqueue: float = 0.0
    t_start: float = 0.0
    t_done: float = 0.0

    def mark_enqueue(self) -> "RequestTiming":
        self.t_enqueue = time.perf_counter()
        return self

    def mark_start(self) -> "RequestTiming":
        self.t_start = time.perf_counter()
        return self

    def mark_done(self) -> "RequestTiming":
        self.t_done = time.perf_counter()
        return self

    @property
    def queue_wait_s(self) -> float:
        return max(self.t_start - self.t_enqueue, 0.0)

    @property
    def service_s(self) -> float:
        return max(self.t_done - self.t_start, 0.0)

    @property
    def latency_s(self) -> float:
        return max(self.t_done - self.t_enqueue, 0.0)


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (``q`` in [0, 100]); 0.0 when empty."""
    if not values:
        return 0.0
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * (q / 100.0)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


class ServiceMetrics:
    """Aggregate serving metrics: latency percentiles, occupancy, counters.

    ``observe(timing)`` records one completed request;
    ``observe_dispatch(n)`` records one engine dispatch serving ``n``
    queries (batch occupancy); counters are plain ``inc(name)`` bumps.
    ``summary()`` renders the whole thing as a flat dict for benchmarks,
    the CLI, and tests.

    Pass ``registry=`` to land the counters/histograms on a shared
    registry (the reduct server shares one with its ``stats``); by default
    each instance owns a private one.
    """

    def __init__(self, window: int = _WINDOW,
                 registry: Optional[MetricsRegistry] = None) -> None:
        self._waits: Deque[float] = collections.deque(maxlen=window)
        self._latencies: Deque[float] = collections.deque(maxlen=window)
        self._occupancies: Deque[int] = collections.deque(maxlen=window)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.counters: Dict[str, int] = CounterMap(
            self.registry, prefix="plar_service_",
            initial=("completed", "engine_dispatches", "batched_queries",
                     "dedup_hits", "rejected"))
        self._h_wait = self.registry.histogram(
            "plar_service_queue_wait_seconds",
            "request queue wait (enqueue to scheduler pickup)")
        self._h_latency = self.registry.histogram(
            "plar_service_latency_seconds",
            "end-to-end request latency (enqueue to done)")
        self._g_occupancy = self.registry.gauge(
            "plar_service_last_batch_occupancy",
            "queries served by the most recent engine dispatch")
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    # -- recording ----------------------------------------------------------

    def observe(self, timing: RequestTiming) -> None:
        self._waits.append(timing.queue_wait_s)
        self._latencies.append(timing.latency_s)
        self._h_wait.observe(timing.queue_wait_s)
        self._h_latency.observe(timing.latency_s)
        self.counters["completed"] += 1
        if self._t_first is None:
            self._t_first = timing.t_done
        self._t_last = timing.t_done

    def observe_dispatch(self, n_queries: int) -> None:
        """One engine dispatch that served ``n_queries`` batched queries."""
        self._occupancies.append(int(n_queries))
        self._g_occupancy.set(int(n_queries))
        self.counters["engine_dispatches"] += 1
        if n_queries > 1:
            self.counters["batched_queries"] += n_queries

    def inc(self, name: str, by: int = 1) -> None:
        self.counters[name] += by

    # -- views --------------------------------------------------------------

    @property
    def completed(self) -> int:
        return self.counters["completed"]

    def sustained_qps(self) -> float:
        """Completed queries per second over the observed completion span."""
        if self._t_first is None or self._t_last is None:
            return 0.0
        span = self._t_last - self._t_first
        done = self.counters["completed"]
        if span <= 0.0:
            return float(done)
        # first completion anchors the span, so it is not *inside* it
        return (done - 1) / span if done > 1 else float(done)

    def mean_occupancy(self) -> float:
        occ: List[int] = list(self._occupancies)
        return sum(occ) / len(occ) if occ else 0.0

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "completed": self.counters["completed"],
            "engine_dispatches": self.counters["engine_dispatches"],
            "batched_queries": self.counters["batched_queries"],
            "dedup_hits": self.counters["dedup_hits"],
            "rejected": self.counters["rejected"],
            "qps_sustained": round(self.sustained_qps(), 2),
            "mean_batch_occupancy": round(self.mean_occupancy(), 2),
            "queue_wait_p50_s": round(percentile(list(self._waits), 50), 4),
            "queue_wait_p99_s": round(percentile(list(self._waits), 99), 4),
            "latency_p50_s": round(percentile(list(self._latencies), 50), 4),
            "latency_p99_s": round(percentile(list(self._latencies), 99), 4),
        }
        # carry through any extra counters callers bumped (engine_runs, ...)
        for k, v in self.counters.items():
            out.setdefault(k, v)
        return out
