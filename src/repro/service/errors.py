"""Typed exception hierarchy for the reduct service (DESIGN.md §3.10).

Every failure the serving tier can hand a caller derives from
:class:`ServiceError`, so clients can catch the whole family — or match a
specific, actionable subtype — instead of pattern-matching ad-hoc
``RuntimeError`` strings.  ``ServiceError`` subclasses ``RuntimeError`` so
pre-hierarchy callers keep working unchanged.

Kept dependency-free (no jax/numpy/asyncio imports): the hierarchy is
importable from anywhere — checkpoint restore paths, CLI entrypoints,
benchmark harnesses — without dragging the serving stack along.
"""
from __future__ import annotations

__all__ = [
    "ServiceError",
    "ServerOverloaded",
    "ServerStopped",
    "QueryPoisoned",
    "ShardLost",
    "CheckpointCorrupt",
]


class ServiceError(RuntimeError):
    """Base of every typed service failure."""


class ServerOverloaded(ServiceError):
    """Raised by ``query``/``query_ensemble`` when the bounded request
    queue is full: the submit fails fast instead of growing the queue
    unboundedly (admission control, DESIGN.md §3.9)."""


class ServerStopped(ServiceError):
    """The server is stopping (or stopped): queued-but-unstarted requests
    fail fast with this instead of hanging on futures whose work will
    never run."""


class QueryPoisoned(ServiceError):
    """A query config that failed ``quarantine_after`` consecutive engine
    dispatches is quarantined: followers get this typed error immediately
    instead of re-running (and re-failing) the dispatch or wedging a shared
    dedup future.  ``cause`` carries the original failure; the quarantine
    clears when the dataset's content changes (a merge may fix it)."""

    def __init__(self, message: str, *, cause: BaseException = None,
                 failures: int = 0):
        super().__init__(message)
        self.cause = cause
        self.failures = failures


class ShardLost(ServiceError):
    """A data shard's device-resident granularity is gone (host death,
    evicted buffer, injected fault).  Recoverable: re-fold the shard from
    its :class:`~repro.core.recovery.ShardLineage` (DESIGN.md §3.10)."""

    def __init__(self, message: str, *, shard_index: int = -1):
        super().__init__(message)
        self.shard_index = shard_index


class CheckpointCorrupt(ServiceError):
    """A checkpoint explicitly asked for is unreadable (truncated npz,
    invalid manifest).  Auto-selecting restores skip+warn past corrupt
    steps instead of raising this (train/checkpoint.py)."""
