"""Durable service state: DatasetHandle checkpoints (DESIGN.md §3.10).

The §3.7 service keeps everything that matters on the device: the live
granularity, the per-config reducts and Θ histories that make warm repair
possible, and (for sharded builds) the lineage metadata.  A process restart
loses all of it — the first post-restart query would pay a cold rebuild and
a cold reduction.  This module persists that state with the
``train/checkpoint.py`` idioms (flatten → npz, committed-sentinel atomic
steps, keep-N retention, background writer thread), so a restarted
:class:`~repro.service.server.ReductServer` restores its handles and
answers its first query through the §3.7 warm ``repair_reduce`` path.

Layout: one committed step holds every dataset —

* arrays  (``arrays.npz``): per dataset, the granularity arrays
  (``<name>/gran/{x,d,w,valid,num,n_total}``) and every cached result's
  vector state (``<name>/results/<i>/{reduct,core,theta_history,
  per_iteration_s}``);
* metadata (``manifest.json`` → ``extra``): per dataset, the static schema
  (``n_attrs``/``n_dec``/``v_max``/``exact``), counters, the content
  fingerprint (verified on restore — a mismatch is
  :class:`~repro.service.errors.CheckpointCorrupt`), the result cache keys
  (repr-encoded param tuples), and the shard lineage as JSON.

Dataset names become npz key prefixes, so they must not contain ``/``
(``ReductServer.submit`` enforces this when checkpointing is on).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.granularity import Granularity
from repro.core.recovery import ShardLineage
from repro.core.reduction import ReductionResult
from repro.train.checkpoint import CheckpointManager

from .errors import CheckpointCorrupt
from .state import DatasetHandle, granularity_fingerprint

__all__ = ["ServiceCheckpointer", "handle_to_state", "handle_from_state"]


def handle_to_state(handle: DatasetHandle) -> Tuple[dict, dict]:
    """Snapshot one handle as ``(array_tree, json_meta)``.

    The array copy to host happens here, on the caller's thread, so a
    background writer never races live device buffers being replaced by a
    concurrent merge.
    """
    g = handle.gran
    tree: Dict[str, Any] = {"gran": {
        "x": np.asarray(g.x), "d": np.asarray(g.d), "w": np.asarray(g.w),
        "valid": np.asarray(g.valid), "num": np.asarray(g.num),
        "n_total": np.asarray(g.n_total),
    }}
    results: Dict[str, Any] = {}
    results_meta = []
    for i, (key, r) in enumerate(
            sorted(handle._results.items(), key=lambda kv: repr(kv[0]))):
        results[str(i)] = {
            "reduct": np.asarray(r.reduct, np.int32),
            "core": np.asarray(r.core, np.int32),
            "theta_history": np.asarray(r.theta_history, np.float64),
            "per_iteration_s": np.asarray(r.per_iteration_s, np.float64),
        }
        results_meta.append({
            "key": repr(key),
            "theta_full": float(r.theta_full),
            "iterations": int(r.iterations),
            "n_evaluations": int(r.n_evaluations),
            "elapsed_s": float(r.elapsed_s),
        })
    if results:
        tree["results"] = results
    meta = {
        "n_attrs": g.n_attrs, "n_dec": g.n_dec, "v_max": g.v_max,
        "exact": handle.exact,
        "n_updates": handle.n_updates,
        "rows_absorbed": handle.rows_absorbed,
        "fingerprint": handle.fingerprint,
        "results": results_meta,
        "lineage": ([l.to_dict() for l in handle.lineage]
                    if handle.lineage is not None else None),
    }
    return tree, meta


def handle_from_state(tree: dict, meta: dict) -> DatasetHandle:
    """Rebuild a handle from its checkpointed state (inverse of
    :func:`handle_to_state`).  The restored content fingerprint is
    recomputed from the arrays and checked against the recorded one — a
    mismatch means the arrays and metadata are out of sync
    (:class:`CheckpointCorrupt`), not silently-wrong warm starts later.
    """
    g = tree["gran"]
    gran = Granularity(
        x=jnp.asarray(g["x"], jnp.int32), d=jnp.asarray(g["d"], jnp.int32),
        w=jnp.asarray(g["w"], jnp.int32), valid=jnp.asarray(g["valid"], bool),
        num=jnp.asarray(g["num"], jnp.int32),
        n_total=jnp.asarray(g["n_total"], jnp.int32),
        n_attrs=int(meta["n_attrs"]), n_dec=int(meta["n_dec"]),
        v_max=int(meta["v_max"]),
    )
    fp = granularity_fingerprint(gran)
    if fp != int(meta["fingerprint"]):
        raise CheckpointCorrupt(
            f"restored granularity fingerprint {fp:#x} != recorded "
            f"{int(meta['fingerprint']):#x} (arrays and metadata disagree)")
    results: Dict[tuple, ReductionResult] = {}
    arrays = tree.get("results", {})
    for i, rm in enumerate(meta.get("results", [])):
        arr = arrays[str(i)]
        key = ast.literal_eval(rm["key"])
        results[key] = ReductionResult(
            reduct=[int(a) for a in np.asarray(arr["reduct"])],
            core=[int(a) for a in np.asarray(arr["core"])],
            theta_full=float(rm["theta_full"]),
            theta_history=[float(t) for t in np.asarray(arr["theta_history"])],
            iterations=int(rm["iterations"]),
            n_evaluations=int(rm["n_evaluations"]),
            elapsed_s=float(rm["elapsed_s"]),
            per_iteration_s=[float(t)
                             for t in np.asarray(arr["per_iteration_s"])],
        )
    lineage = None
    if meta.get("lineage") is not None:
        lineage = tuple(ShardLineage.from_dict(d) for d in meta["lineage"])
    handle = DatasetHandle(
        gran=gran, exact=bool(meta["exact"]),
        n_updates=int(meta["n_updates"]),
        rows_absorbed=int(meta["rows_absorbed"]),
        lineage=lineage,
    )
    handle._results = results
    handle._fp = fp
    return handle


class _ServiceManager(CheckpointManager):
    """CheckpointManager with the chaos harness's checkpoint-crash site:
    the fault fires *after* the arrays and manifest are staged but *before*
    the commit (sentinel + rename), so an injected crash exercises exactly
    the window the atomic layout protects — prior committed steps survive
    untouched (tests/test_recovery.py).

    Write failures (injected or real: full disk, dead mount) are absorbed
    into ``last_error`` instead of raised: a checkpoint is an availability
    feature, and a broken disk must not take the serving path — or the
    background writer thread — down with it.
    """

    fault_plan = None
    last_error: Optional[BaseException] = None

    def _pre_commit(self, tmp_dir: str) -> None:
        if self.fault_plan is not None:
            self.fault_plan.inject("checkpoint")

    def _write(self, step, host, extra):
        try:
            with obs.span("checkpoint.write", step=int(step)):
                return super()._write(step, host, extra)
        except BaseException as e:
            self.last_error = e
            obs.event("checkpoint.write_failed", step=int(step),
                      error=f"{type(e).__name__}: {e}")
            obs.counter("plar_checkpoint_failed_total",
                        "checkpoint writes that failed (absorbed)").inc()
            return ""


class ServiceCheckpointer:
    """Keep-N durable snapshots of a server's :class:`DatasetHandle` map.

    ``save`` snapshots host-side on the calling thread (cheap: one
    device→host copy per live array) and, with ``blocking=False``, hands
    the write to the manager's background thread — the §3.7 serving path
    never waits on disk.  ``restore`` returns the newest readable committed
    step's handles (corrupt steps are skipped with a warning by the
    underlying manager).
    """

    def __init__(self, directory: str, *, keep: int = 3,
                 fault_plan=None) -> None:
        self.directory = directory
        self._mgr = _ServiceManager(directory, keep=keep)
        self._mgr.fault_plan = fault_plan
        self._step = (self._mgr.latest_step() or 0)
        self.saves = 0
        self.failed_saves = 0
        self.last_error: Optional[BaseException] = None

    def _harvest(self) -> bool:
        """Collect a write failure recorded by the (possibly background)
        writer since the last check.  True when one was found."""
        err = self._mgr.last_error
        if err is None:
            return False
        self._mgr.last_error = None
        self.last_error = err
        self.failed_saves += 1
        return True

    def save(self, handles: Dict[str, Optional[DatasetHandle]], *,
             blocking: bool = True) -> Optional[str]:
        """Snapshot every live handle as one committed step.

        Names still reserved by an in-flight ``submit`` (value ``None``)
        are skipped — they have no state yet.  Returns the step path, or
        ``None`` when a blocking write failed (failures are absorbed and
        counted in ``failed_saves``/``last_error``; background-write
        failures surface at the next ``save``/``wait``).  The previous
        committed step always remains restorable — the atomic step layout
        commits all-or-nothing.
        """
        tree: Dict[str, Any] = {}
        metas: Dict[str, Any] = {}
        with obs.span("checkpoint.snapshot", datasets=len(handles)):
            for name, handle in handles.items():
                if handle is None:
                    continue
                t, m = handle_to_state(handle)
                tree[name] = t
                metas[name] = m
        obs.counter("plar_checkpoint_saves_total",
                    "checkpoint steps staged for write").inc()
        if blocking:
            self._mgr.wait()  # never two writers racing in one directory
        self._harvest()  # a background failure from the previous save
        self._step += 1
        path = self._mgr.save(self._step, tree, extra={"datasets": metas},
                              blocking=blocking)
        if blocking and self._harvest():
            return None
        self.saves += 1
        return path

    def wait(self) -> None:
        """Join the background writer (call before process exit)."""
        self._mgr.wait()
        self._harvest()

    def restore(self) -> Tuple[int, Dict[str, DatasetHandle]]:
        """Handles from the newest readable committed step.

        Raises ``FileNotFoundError`` when no committed step exists (a cold
        start) and :class:`CheckpointCorrupt` when a step's arrays and
        metadata disagree.
        """
        with obs.span("checkpoint.restore"):
            step, tree, extra = self._mgr.restore()
            handles = {
                name: handle_from_state(tree.get(name, {}), meta)
                for name, meta in extra.get("datasets", {}).items()
            }
        obs.counter("plar_checkpoint_restores_total",
                    "checkpoint restore calls that found a step").inc()
        return step, handles
