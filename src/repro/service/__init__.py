"""Online attribute-reduction service (DESIGN.md §3.7/§3.9/§3.10).

Turns the batch reproduction into a stateful subsystem: a device-resident
granularity absorbs row-batch deltas through the §3.6 monoid merge, and
reducts are repaired by warm-starting the §3.5 selection engine from the
previous result instead of recomputing from an empty reduct.  The serving
tier is multi-tenant: a scheduler batches compatible concurrent queries
into stacked engine dispatches, deduplicates identical in-flight queries,
and bounds the queue with fail-fast admission control.

The resilience layer (§3.10) makes the service survive the failures a
long-lived deployment actually sees: shard lineage + re-fold recovery
(core/recovery.py), durable DatasetHandle checkpoints (checkpoint.py),
retry/quarantine/stale-degradation around dispatches (scheduler.py), a
typed :class:`ServiceError` hierarchy (errors.py), and a deterministic
fault-injection harness (faults.py).
"""
from .checkpoint import ServiceCheckpointer, handle_from_state, handle_to_state
from .errors import (
    CheckpointCorrupt,
    QueryPoisoned,
    ServerOverloaded,
    ServerStopped,
    ServiceError,
    ShardLost,
)
from .faults import FaultInjected, FaultPlan, FaultSpec
from .metrics import RequestTiming, ServiceMetrics, percentile
from .scheduler import RetryPolicy, Scheduler
from .server import ReduceRequest, ReductServer
from .state import (
    DatasetHandle,
    granularity_fingerprint,
    repair_reduce,
    repair_reduce_many,
    valid_prefix_len,
)

__all__ = [
    "CheckpointCorrupt",
    "DatasetHandle",
    "FaultInjected",
    "FaultPlan",
    "FaultSpec",
    "QueryPoisoned",
    "ReduceRequest",
    "ReductServer",
    "RequestTiming",
    "RetryPolicy",
    "Scheduler",
    "ServerOverloaded",
    "ServerStopped",
    "ServiceCheckpointer",
    "ServiceError",
    "ServiceMetrics",
    "ShardLost",
    "granularity_fingerprint",
    "handle_from_state",
    "handle_to_state",
    "percentile",
    "repair_reduce",
    "repair_reduce_many",
    "valid_prefix_len",
]
