"""Online attribute-reduction service (DESIGN.md §3.7).

Turns the batch reproduction into a stateful subsystem: a device-resident
granularity absorbs row-batch deltas through the §3.6 monoid merge, and
reducts are repaired by warm-starting the §3.5 selection engine from the
previous result instead of recomputing from an empty reduct.
"""
from .server import ReduceRequest, ReductServer
from .state import (
    DatasetHandle,
    granularity_fingerprint,
    repair_reduce,
    valid_prefix_len,
)

__all__ = [
    "DatasetHandle",
    "ReduceRequest",
    "ReductServer",
    "granularity_fingerprint",
    "repair_reduce",
    "valid_prefix_len",
]
