"""Online attribute-reduction service (DESIGN.md §3.7/§3.9).

Turns the batch reproduction into a stateful subsystem: a device-resident
granularity absorbs row-batch deltas through the §3.6 monoid merge, and
reducts are repaired by warm-starting the §3.5 selection engine from the
previous result instead of recomputing from an empty reduct.  The serving
tier is multi-tenant: a scheduler batches compatible concurrent queries
into stacked engine dispatches, deduplicates identical in-flight queries,
and bounds the queue with fail-fast admission control.
"""
from .metrics import RequestTiming, ServiceMetrics, percentile
from .scheduler import Scheduler, ServerOverloaded
from .server import ReduceRequest, ReductServer
from .state import (
    DatasetHandle,
    granularity_fingerprint,
    repair_reduce,
    repair_reduce_many,
    valid_prefix_len,
)

__all__ = [
    "DatasetHandle",
    "ReduceRequest",
    "ReductServer",
    "RequestTiming",
    "Scheduler",
    "ServerOverloaded",
    "ServiceMetrics",
    "granularity_fingerprint",
    "percentile",
    "repair_reduce",
    "repair_reduce_many",
    "valid_prefix_len",
]
