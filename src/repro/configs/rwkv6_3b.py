"""rwkv6-3b (Finch) — attention-free: 32L, d=2560, d_ff=8960, vocab=65536.

[arXiv:2404.05892; hf-verified] Data-dependent decay (LoRA), head_dim=64.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=8960,
    vocab=65_536,
    rwkv_head_dim=64,
    rwkv_decay_lora=64,
    sub_quadratic=True,
    note="Finch — data-dependent decay; attention-free",
)
