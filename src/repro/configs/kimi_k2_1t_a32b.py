"""kimi-k2-1t-a32b — 61L, d=7168, 64H (GQA kv=8), MoE 384e top-8 + 1 shared.

[arXiv:2501.kimi2 paper-table; unverified] Trillion-parameter MoE.  The brief
specifies GQA kv=8 (the real K2 uses MLA; we follow the brief's table).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab=163_840,
    n_experts=384,
    top_k=8,
    moe_d_ff=2048,
    moe_every=1,
    n_shared_experts=1,
    rope_theta=50_000.0,
    note="trillion-param MoE; 384 experts top-8 + 1 shared",
)
