"""mistral-nemo-12b — 40L, d=5120, 32H (GQA kv=8), d_ff=14336, 128k ctx.

[hf:mistralai/Mistral-Nemo-Base-2407; hf-verified] rope_theta=1M for 128k.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131_072,
    rope_theta=1_000_000.0,
    note="128k context",
)
