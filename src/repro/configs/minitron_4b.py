"""minitron-4b — pruned Nemotron: 32L, d=3072, 24H (GQA kv=8), d_ff=9216.

[arXiv:2407.14679; hf-verified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab=256_000,
    note="pruned nemotron",
)
