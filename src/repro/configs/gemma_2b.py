"""gemma-2b — 18L, d=2048, 8H MQA (kv=1), GeGLU d_ff=16384, head_dim=256.

[arXiv:2403.08295; hf-verified] Tied embeddings, sqrt(d_model) embed scale.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256_000,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    note="GeGLU, head_dim=256, MQA",
)
