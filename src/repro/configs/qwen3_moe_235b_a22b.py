"""qwen3-moe-235b-a22b — 94L, d=4096, 64H (GQA kv=4), MoE 128e top-8.

[hf:Qwen/Qwen3-30B-A3B scaled per brief; hf-verified family]
Every layer is MoE (no dense interleave, no shared expert); qk-norm per Qwen3.
d_ff=1536 is the per-expert width.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,            # unused (all layers MoE); kept for reference
    vocab=151_936,
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    moe_every=1,
    qk_norm=True,
    rope_theta=1_000_000.0,
    note="128 experts top-8; qk-norm; GQA 64/4",
)
