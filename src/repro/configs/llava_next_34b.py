"""llava-next-34b — VLM backbone: 60L, d=7168, 56H (GQA kv=8), d_ff=20480.

[hf:llava-hf/llava-v1.6-mistral-7b-hf family; unverified]
Backbone only per the brief; the vision frontend is a STUB — input_specs()
provides precomputed anyres patch embeddings (5 tiles × 576 = 2880 prefix
positions, 1152-d SigLIP-class features) projected by one learned matrix.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64_000,
    frontend="vision",
    frontend_dim=1152,
    frontend_tokens=2880,   # anyres: 5 tiles × 576 patches
    note="anyres tiling; vision frontend stubbed",
)
