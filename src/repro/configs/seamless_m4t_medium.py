"""seamless-m4t-medium — enc-dec multimodal: 12+12L, d=1024, 16H, d_ff=4096.

[arXiv:2308.11596; hf-verified] Audio frontend STUBBED — input_specs()
provides precomputed frame embeddings (160-d fbank-stack class features).
Decode shapes run the decoder against the encoder memory; long_500k skipped
(full attention).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256_206,
    enc_layers=12,
    dec_layers=12,
    frontend="audio",
    frontend_dim=160,
    note="enc-dec, multimodal; audio frontend stubbed",
)
