"""Architecture registry: ``--arch <id>`` resolution + per-arch shape sets.

Each assigned architecture has its own config module; ``get_config`` maps the
public arch id to its :class:`repro.models.config.ArchConfig`.  ``cells()``
enumerates the assigned (arch × shape) grid, honoring the brief's skips:
``long_500k`` only for sub-quadratic archs (SSM / hybrid).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.models.config import ArchConfig, SHAPES, ShapeConfig

from . import (
    gemma_2b,
    jamba_1_5_large_398b,
    kimi_k2_1t_a32b,
    llava_next_34b,
    minitron_4b,
    mistral_nemo_12b,
    qwen3_moe_235b_a22b,
    rwkv6_3b,
    seamless_m4t_medium,
    tinyllama_1_1b,
)

_REGISTRY: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen3_moe_235b_a22b,
        kimi_k2_1t_a32b,
        minitron_4b,
        gemma_2b,
        mistral_nemo_12b,
        tinyllama_1_1b,
        llava_next_34b,
        jamba_1_5_large_398b,
        rwkv6_3b,
        seamless_m4t_medium,
    )
}

ARCH_IDS: List[str] = list(_REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return _REGISTRY[name]


def shape_applies(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    """The brief's applicability rules (skips recorded in DESIGN.md)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic  # needs sub-quadratic attention
    return True


def cells() -> List[Tuple[str, str]]:
    """All assigned (arch, shape) dry-run cells (40 total incl. noted skips)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            out.append((arch, shape.name))
    return out


def runnable_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a, s in cells() if shape_applies(get_config(a), SHAPES[s])]
