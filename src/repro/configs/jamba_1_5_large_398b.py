"""jamba-1.5-large-398b — hybrid Mamba+attention 7:1, MoE 16e top-2.

[arXiv:2403.19887; hf-verified] 72L, d=8192, 64H (GQA kv=8), d_ff=24576.
Attention layers carry a 32k sliding window in long-context serving (the
Mamba layers give the O(1)-state sub-quadratic path for long_500k).
MoE every other layer (16 experts top-2); dense MLP between.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65_536,
    n_experts=16,
    top_k=2,
    moe_d_ff=24576,
    moe_every=2,
    attn_every=8,            # 1 attention layer per 8 (1:7 interleave)
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
    window=32_768,
    sub_quadratic=True,
    note="Mamba+attn 1:7 interleave, MoE every other layer",
)
