"""Forward heuristic attribute reduction: HAR / FSPA baselines + PLAR.

Implements the paper's Algorithm 1 (HAR), the FSPA accelerator of Qian et al.
(the paper's single-machine state-of-the-art baseline), and the PLAR greedy
loop (Algorithm 2) in single-process form.  The mesh-distributed MDP version
lives in :mod:`repro.core.distributed` and reuses these building blocks.

Faithfulness notes (DESIGN.md §2):

* HAR here means: no GrC initialization (every raw row is its own record), no
  model parallelism (candidates evaluated one chunk of 1 at a time), and every
  evaluation re-keys from scratch (``mode="spark"``) — the cost shape of the
  original sequential algorithm, vectorized enough to run under XLA.
* FSPA = HAR + universe shrinking.  Because θ of a *pure* class is exactly 0
  for SCE/LCE/CCE and exactly ``-|E|/|U|`` for PR, dropping pure classes and
  carrying a single PR correction scalar reproduces HAR's Θ values *exactly*
  (so reducts are identical, matching the paper's Tables 6–9).
* PLAR = GrC init + MP (candidate chunks) + the incremental packed-id
  evaluation (beyond-paper; ``mode="spark"`` gives the paper-faithful loop).
"""
from __future__ import annotations

import dataclasses
import time
from functools import lru_cache
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import measures
from .engine import (
    DEVICE_BACKENDS,
    ENSEMBLE_BACKENDS,
    ENSEMBLE_DELTAS,
    EnsembleOperands,
    make_engine_run,
    make_ensemble_run,
    run_engine,
    run_ensemble,
    unpack_ensemble_result,
)
from .granularity import (
    Granularity,
    build_granularity,
    build_granularity_streaming,
    column_terms,
    dyn_column_terms,
    compact_ids,
    next_pow2,
    pack_ids,
    row_fingerprints,
    with_capacity,
)
from .plan import (
    SWEEP_BACKENDS,
    candidate_theta,
    contingency_from_ids,
    ids_by_sort,
    ladder_rungs,
    rung_for,
    subset_ids,
)

__all__ = ["ReductionResult", "plar_reduce", "plar_reduce_ensemble",
           "har_reduce", "fspa_reduce", "raw_granularity",
           "resolve_granularity", "bagged_weights", "expand_ensemble_grid",
           "normalize_ensemble_configs", "partition_reduce_params",
           "ENSEMBLE_SHARED_KEYS"]

_MODES = ("incremental", "spark")
_BACKENDS = ("segment", "onehot", "pallas", "fused", "fused_xla", "sweep",
             "sweep_xla")
_ENGINES = ("auto", "host", "device")


def _resolve_engine(engine: str, backend: str) -> str:
    """Validate the engine knob and resolve ``auto``.

    ``auto`` prefers the device-resident while_loop engine (core/engine.py)
    and falls back to the host loop only where the device engine cannot run:
    the interpret-mode Pallas backends (``pallas``/``fused``).
    """
    if engine not in _ENGINES:
        raise ValueError(
            f"unknown engine: {engine!r} (one of: {', '.join(_ENGINES)})")
    if engine == "device" and backend not in DEVICE_BACKENDS:
        raise ValueError(
            f"engine='device' does not support backend={backend!r} "
            f"(one of: {', '.join(DEVICE_BACKENDS)}); use engine='host'")
    if engine == "auto":
        return "device" if backend in DEVICE_BACKENDS else "host"
    return engine


# kept as an alias: the canonical definition moved next to the capacity
# policy it governs (granularity.merge_granularity)
_next_pow2 = next_pow2


@dataclasses.dataclass
class ReductionResult:
    reduct: List[int]               # selected attributes, core first then greedy order
    core: List[int]
    theta_full: float               # Θ(D|C) — the stopping target
    theta_history: List[float]      # Θ(D|R) after each greedy addition
    iterations: int
    n_evaluations: int              # candidate evaluations performed (bench metric)
    elapsed_s: float
    per_iteration_s: List[float]
    # set by the serving layer's graceful degradation (§3.10): True marks a
    # last-known-good result served because the fresh dispatch failed
    stale: bool = False

    @property
    def n_selected(self) -> int:
        return len(self.reduct)


def raw_granularity(x: jnp.ndarray, d: jnp.ndarray, *, n_dec: int, v_max: int) -> Granularity:
    """A decision table *without* GrC initialization: every row is a granule.

    This is what HAR/FSPA operate on — evaluation cost scales with |U|, not
    |U/A|, exactly the gap the paper's Fig. 9 measures.
    """
    n, n_attrs = x.shape
    return Granularity(
        x=jnp.asarray(x, jnp.int32),
        d=jnp.asarray(d, jnp.int32),
        w=jnp.ones((n,), jnp.int32),
        valid=jnp.ones((n,), bool),
        num=jnp.int32(n),
        n_total=jnp.int32(n),
        n_attrs=n_attrs,
        n_dec=n_dec,
        v_max=v_max,
    )


# ---------------------------------------------------------------------------
# jitted inner pieces
# ---------------------------------------------------------------------------


@jax.jit
def _full_fingerprints(x, valid):
    h1 = row_fingerprints(x, 0)
    h2 = row_fingerprints(x, 7919)
    return h1, h2


@lru_cache(maxsize=None)
def _eval_chunk_incremental(delta, backend, n_bins, m, v_max,
                            selector=None):
    """Evaluate a chunk of candidates via packed incremental ids (optimized)."""

    @jax.jit
    def run(r_ids, cand_cols, x, d, w, active, n, pr_correction):
        x_cand = jnp.take(x, cand_cols, axis=1).T          # [nc, G]
        packed = pack_ids(r_ids[None, :], x_cand, v_max)    # [nc, G]
        return candidate_theta(
            delta, packed, d, w, active, n, n_bins=n_bins, m=m,
            backend=backend, selector=selector
        ) + pr_correction

    return run


@lru_cache(maxsize=None)
def _eval_chunk_sweep(delta, backend, n_bins, m, v_max, selector=None):
    """Sweep backends (DESIGN.md §5.3): read-once slab form — candidate rows
    sliced from the pre-transposed ``x_t [A, cap]``, pack fused downstream."""

    @jax.jit
    def run(r_ids, cand_cols, x_t, d, w, active, n, pr_correction):
        x_cand = jnp.take(x_t, cand_cols, axis=0)          # [nc, cap]
        return candidate_theta(
            delta, None, d, w, active, n, n_bins=n_bins, m=m,
            backend=backend, x_t=x_cand, r_ids=r_ids, v_max=v_max,
            selector=selector
        ) + pr_correction

    return run


@lru_cache(maxsize=None)
def _eval_chunk_spark(delta, n_bins, m, v_max):
    """Paper-faithful: re-key granules from scratch + sort per candidate."""

    @jax.jit
    def run(hR1, hR2, cand_cols, x, d, w, active, n, pr_correction):
        def one(col):
            t1 = dyn_column_terms(x, col, 0)
            t2 = dyn_column_terms(x, col, 7919)
            ids, _k = ids_by_sort([hR2 + t2, hR1 + t1], active)
            cont = contingency_from_ids(ids, d, w, active, n_bins=n_bins, m=m)
            return measures.evaluate(delta, cont, n)

        return jax.lax.map(one, cand_cols) + pr_correction

    return run


@lru_cache(maxsize=None)
def _make_advance(n_bins, v_max, m, delta):
    @jax.jit
    def advance(r_ids, x_col, d, w, active, n):
        packed = pack_ids(r_ids, x_col, v_max)
        new_ids, k_new, _ = compact_ids(packed, active, n_bins)
        cont = contingency_from_ids(new_ids, d, w, active, n_bins=n_bins, m=m)
        theta = measures.evaluate(delta, cont, n)
        # purity per class → per granule (for FSPA-style shrinking)
        e = cont.sum(-1)
        pure_row = (cont.max(-1) == e) & (e > 0)
        g_pure = pure_row[new_ids] & active
        return new_ids, k_new, theta, g_pure

    return advance


# ---------------------------------------------------------------------------
# core (attribute core) computation
# ---------------------------------------------------------------------------


def _core_inner_thetas(gran: Granularity, delta: str, *, exact: bool, chunk: int = 64) -> np.ndarray:
    """Θ(D|C\\{a}) for every a ∈ C (paper lines 3–8, the MP'd core step)."""
    A = gran.n_attrs
    cap = gran.capacity
    n_bins = cap  # ≤ G distinct classes always
    out = np.zeros((A,), np.float64)

    if exact and A <= 128:
        for a in range(A):
            cols = jnp.asarray([j for j in range(A) if j != a], jnp.int32)
            ids, _ = subset_ids(gran, cols, exact=True)
            cont = contingency_from_ids(ids, gran.d, gran.w, gran.valid, n_bins=n_bins, m=gran.n_dec)
            out[a] = float(measures.evaluate(delta, cont, gran.n_total))
        return out

    # Linear-sketch path: h(C\{a}) = h(C) - term_a  — O(1) per candidate.
    h1, h2 = _full_fingerprints(gran.x, gran.valid)

    @jax.jit
    def chunk_fn(cand_cols):
        def one(col):
            t1 = dyn_column_terms(gran.x, col, 0)
            t2 = dyn_column_terms(gran.x, col, 7919)
            ids, _k = ids_by_sort([h2 - t2, h1 - t1], gran.valid)
            cont = contingency_from_ids(ids, gran.d, gran.w, gran.valid, n_bins=n_bins, m=gran.n_dec)
            return measures.evaluate(delta, cont, gran.n_total)

        return jax.lax.map(one, cand_cols)

    for s in range(0, A, chunk):
        cols = np.arange(s, min(s + chunk, A), dtype=np.int32)
        pad = chunk - len(cols)
        padded = np.concatenate([cols, np.zeros((pad,), np.int32)])
        vals = np.asarray(chunk_fn(jnp.asarray(padded)))
        out[s : s + len(cols)] = vals[: len(cols)]
    return out


# ---------------------------------------------------------------------------
# main driver
# ---------------------------------------------------------------------------


def _shrink_capacity(gran: Granularity) -> Granularity:
    """Shrink the static capacity to the live granule count (next pow2):
    the paper's space win |U/A| ≪ |U| only pays if downstream shapes shrink
    with it.  One host sync — the Spark analogue is the driver's count()
    action after caching the RDD.  Streaming and monolithic builds land on
    the *same* capacity here (same live count), which is what makes their
    reducts and Θ histories byte-identical (engine n_bins = cap·v_max)."""
    cap2 = next_pow2(max(int(gran.num), 16))
    return with_capacity(gran, cap2) if cap2 != gran.capacity else gran


def _iter_chunks(source, chunk_rows: int):
    """Chunk iterator over the *protocol* surface (``n_chunks``/``chunk``)
    only — a conforming GranuleSource need not provide the ``chunks``
    convenience wrapper TabularStream has."""
    return (source.chunk(i, chunk_rows) for i in range(source.n_chunks(chunk_rows)))


def _materialize(source, chunk_rows: int):
    """Concatenate a GranuleSource's chunks into full (x, d) host arrays."""
    xs, ds = zip(*_iter_chunks(source, chunk_rows))
    return np.concatenate(xs), np.concatenate(ds)


def _check_source_args(x, d, source):
    """Shared (x, d)/source exclusivity + source-type validation — one copy
    for both drivers, so the error surface cannot drift between them."""
    if source is not None and (x is not None or d is not None):
        raise ValueError("pass either (x, d) arrays or source=, not both")
    if source is None and (x is None or d is None):
        raise ValueError("pass (x, d) arrays or source=")
    if (source is not None and not isinstance(source, Granularity)
            and not hasattr(source, "chunk")):
        raise TypeError(
            f"source must be a Granularity or GranuleSource, got {type(source)!r}")


def resolve_granularity(
    x=None,
    d=None,
    *,
    source=None,
    grc_init: bool = True,
    n_dec: Optional[int] = None,
    v_max: Optional[int] = None,
    exact: bool = True,
    chunk_rows: int = 65536,
) -> Granularity:
    """The one ingestion seam: everything the drivers accept → ``Granularity``.

    * a prebuilt :class:`Granularity` (``source=``) — used as-is (capacity
      re-packed when ``grc_init``, verbatim otherwise);
    * a :class:`~repro.data.GranuleSource` (``source=``, anything with a
      ``chunk`` method) — streamed chunkwise through
      :func:`build_granularity_streaming`, so the decision table never
      exists whole.  ``grc_init=False`` (the HAR/FSPA cost model: every raw
      row its own granule) has no compressed representation to stream into,
      so the chunks are materialized — unrunnable at paper scale *by
      design*; that cost gap is the paper's Fig. 9.
    * raw ``(x, d)`` arrays — the legacy path, now a thin adapter over the
      same build.

    Metadata: a source's declared ``n_dec``/``v_max`` are authoritative; the
    array adapter *infers* them from realized data when not given.  Byte
    parity between the two paths therefore requires passing the declared
    values to the array call too (a class that happens never to materialize
    would otherwise change the inferred ``m``/``n_bins``).
    """
    _check_source_args(x, d, source)

    if isinstance(source, Granularity):
        return _shrink_capacity(source) if grc_init else source

    if source is not None:
        n_dec = source.n_dec if n_dec is None else n_dec
        v_max = source.v_max if v_max is None else v_max
        if grc_init:
            return _shrink_capacity(build_granularity_streaming(
                _iter_chunks(source, chunk_rows), n_dec=n_dec, v_max=v_max,
                exact=exact))
        x, d = _materialize(source, chunk_rows)

    x = jnp.asarray(x, jnp.int32)
    d = jnp.asarray(d, jnp.int32)
    if n_dec is None:
        n_dec = int(jnp.max(d)) + 1
    if v_max is None:
        v_max = int(jnp.max(x)) + 1
    if not grc_init:
        return raw_granularity(x, d, n_dec=n_dec, v_max=v_max)
    return _shrink_capacity(
        build_granularity(x, d, n_dec=n_dec, v_max=v_max, exact=exact))


def _validate_warm_start(warm_start, n_attrs: Optional[int]) -> List[int]:
    """Canonicalize + validate a warm-start prefix (shared by the sequential
    driver and the ensemble grid — one error surface for both).

    ``n_attrs=None`` skips the range check (grid normalization runs before a
    granularity exists; the driver re-validates with the real A).

    A prefix longer than ``max_features`` is deliberately NOT an error:
    like core attributes, the forced prefix folds unconditionally and the
    cap gates only further greedy additions — a cold run whose core
    overflows the cap returns more than ``max_features`` attributes, and
    warm-repairing from that result must be expressible (DESIGN.md §3.9).
    """
    warm: List[int] = []
    for a in warm_start:
        ai = int(a)
        if ai != a:
            raise ValueError(
                f"warm_start entries must be integral attribute "
                f"indices, got {a!r}")
        warm.append(ai)
    if len(set(warm)) != len(warm):
        raise ValueError(f"warm_start contains duplicates: {warm}")
    if n_attrs is not None:
        bad = [a for a in warm if not 0 <= a < n_attrs]
        if bad:
            raise ValueError(
                f"warm_start attributes {bad} out of range [0, {n_attrs})")
    return warm


def plar_reduce(
    x=None,
    d=None,
    *,
    source=None,                         # Granularity | GranuleSource (alt. to x, d)
    chunk_rows: int = 65536,             # streaming-ingestion chunk size
    delta: str = "PR",
    n_dec: Optional[int] = None,
    v_max: Optional[int] = None,
    eps: float = 0.0,
    tol: float = 1e-6,
    tie_tol: float = 1e-5,
    max_features: Optional[int] = None,
    mode: str = "incremental",          # "incremental" (optimized) | "spark" (paper-faithful)
    backend: str = "segment",           # Θ backend: segment|onehot|pallas|fused|fused_xla|sweep|sweep_xla
    ladder: bool = False,                # K-adaptive bin ladder (DESIGN.md §5.3)
    selector: str = "analytic",          # tile/rung selection: heuristic|analytic|pinned
    mp_chunk: int = 64,                  # model-parallelism level (paper Table 12 knob)
    grc_init: bool = True,               # paper Fig. 9 knob
    shrink: bool = False,                # FSPA universe shrinking
    exact: bool = True,
    compute_core: bool = True,
    engine: str = "auto",                # "device" while_loop | "host" legacy loop
    warm_start: Optional[Sequence[int]] = None,  # resume greedy from this prefix
) -> ReductionResult:
    """PLAR (Algorithm 2) on one process.  See module docstring for modes.

    ``warm_start`` seeds the selection with a previously chosen prefix (the
    online-service repair path, DESIGN.md §3.7): the prefix attributes are
    folded as forced selections — re-recording their Θ values on *this*
    granularity — and the greedy loop resumes from there.  It replaces the
    core computation (the prefix stands in for the core, so ``core`` comes
    back empty) and, on the device engine, runs as a seed + resume pair of
    dispatches of the same single compile
    (:func:`~repro.core.engine.init_state_from_reduct` /
    :func:`~repro.core.engine.engine_resume`).  For a prefix the cold run
    would itself have selected, the result is byte-identical to the cold run
    (asserted by tests/test_engine.py::test_warm_start_parity).

    Like core attributes, the forced prefix folds unconditionally:
    ``max_features`` caps only further *greedy* additions — a prefix longer
    than the cap folds whole and adds nothing, mirroring a cold run whose
    forced core overflows the cap (so warm-repairing from such a result
    stays expressible).  A prefix is validated up front — entries must be
    integral, unique, and in ``[0, A)`` — raising ``ValueError`` instead of
    a shape error inside the compiled engine.  ``warm_start=prefix,
    max_features=len(prefix)`` folds the prefix and adds nothing — a pure
    re-evaluation of its Θ trajectory.
    """
    t0 = time.perf_counter()
    if mode not in _MODES:
        raise ValueError(
            f"unknown mode: {mode!r} (one of: {', '.join(_MODES)})")
    if backend not in _BACKENDS:
        raise ValueError(
            f"unknown Θ backend: {backend!r} (one of: {', '.join(_BACKENDS)})")
    from repro.kernels.contingency.autotune import SELECTOR_MODES
    if selector not in SELECTOR_MODES:
        raise ValueError(
            f"unknown selector: {selector!r} "
            f"(one of: {', '.join(SELECTOR_MODES)})")
    engine = _resolve_engine(engine, backend)
    gran = resolve_granularity(
        x, d, source=source, grc_init=grc_init, n_dec=n_dec, v_max=v_max,
        exact=exact, chunk_rows=chunk_rows)

    A = gran.n_attrs
    m = gran.n_dec
    cap = gran.capacity
    n = gran.n_total
    n_evals = 0

    warm: Optional[List[int]] = None
    if warm_start is not None:
        warm = _validate_warm_start(warm_start, A)

    # Θ(D|C): stopping target.
    all_cols = jnp.arange(A, dtype=jnp.int32)
    ids_c, _k = subset_ids(gran, all_cols, exact=exact)
    cont_c = contingency_from_ids(ids_c, gran.d, gran.w, gran.valid, n_bins=cap, m=m)
    theta_full = float(measures.evaluate(delta, cont_c, n))

    # --- core (skipped under warm_start: the prefix stands in for it) ---
    core: List[int] = []
    if compute_core and warm is None:
        inner = _core_inner_thetas(gran, delta, exact=exact)
        sig = inner - theta_full  # Θ(D|C\{a}) - Θ(D|C)
        core = [int(a) for a in range(A) if sig[a] > eps + tie_tol]
        n_evals += A
    forced = core if warm is None else warm

    if engine == "device":
        # Device-resident engine: core folding + greedy loop + stopping rule
        # run as ONE lax.while_loop (core/engine.py) — a single dispatch, a
        # single compile (n_bins = cap·v_max is static), and one device→host
        # transfer at the end.
        max_sel = int(max_features) if max_features is not None else A
        runner = make_engine_run(
            delta, mode, backend, A, cap, m, gran.v_max, float(tol),
            float(tie_tol), bool(shrink), max_sel, int(mp_chunk),
            bool(ladder), str(selector))
        reduct, theta_hist, iterations, ev, per_iter = run_engine(
            runner, cap, A, gran.valid, gran.x, gran.d, gran.w, n,
            theta_full, core, warm_start=warm)
        return ReductionResult(
            reduct=reduct,
            core=core,
            theta_full=theta_full,
            theta_history=theta_hist,
            iterations=iterations,
            n_evaluations=n_evals + ev,
            elapsed_s=time.perf_counter() - t0,
            per_iteration_s=per_iter,
        )

    # --- greedy loop state (engine == "host": the legacy escape hatch) ---
    r_ids = jnp.zeros((cap,), jnp.int32)
    k = 1
    active = gran.valid
    # float32 accumulation, mirroring the device engine bit-for-bit (so the
    # two engines' theta histories are byte-identical, asserted in tests)
    pr_correction = np.float32(0.0)
    reduct: List[int] = []
    theta_hist: List[float] = []
    per_iter_s: List[float] = []

    v = gran.v_max

    # The advance (and, ladder off, the evaluation) uses the engine's static
    # bin bound cap·V: one compile for the whole run (no power-of-two
    # recompile ladder) and Θ summed over the same padded rows as
    # engine="device" — zero rows add exactly 0 in f32, but reduction
    # *grouping* depends on length, so equal lengths ⇒ equal bits (candidate
    # thetas AND recorded histories).  The §5.3 ladder shrinks only the
    # *candidate evaluation* bins; the advance keeps the full bound, which is
    # what keeps theta histories byte-identical across every (backend,
    # ladder) combination.
    adv = _make_advance(cap * v, v, m, delta)

    # K-adaptive candidate-eval bins (ladder on): the host twin of the
    # engine's lax.switch — same static rung set, chosen per iteration from
    # the synced k, one (lru-cached) compile per rung actually visited.
    # The selector-pruned set is a function of (cap, m) only, so host and
    # device engines derive identical rungs (byte parity, DESIGN.md §5.3).
    rungs = ladder_rungs(cap * v, selector=selector, g=cap, m=m)

    def _eval_bins_for(k_):
        if ladder:
            return rung_for(k_, v, rungs)
        # device-capable backends pin the full static bound for bit parity
        # with engine="device"; host-only Pallas backends keep the cheaper
        # pow2 ladder (no device twin to match)
        return cap * v if backend in DEVICE_BACKENDS else _next_pow2(max(k_, 1)) * v

    # read-once candidate slab for the sweep backends, hoisted out of the
    # loop (the device engine hoists the same transpose before its while_loop)
    x_t_full = jnp.swapaxes(gran.x, 0, 1) if backend in SWEEP_BACKENDS else None

    # The stop threshold mirrors the device cond's f32 arithmetic exactly, so
    # both engines run the same number of iterations even when theta_r lands
    # within an ulp of it.
    stop_thresh = measures.f32_threshold(theta_full, tol)

    def _shrink_step(g_pure):
        nonlocal pr_correction, active
        if delta == "PR":
            shed = jnp.sum(jnp.where(g_pure, gran.w, 0)).astype(jnp.float32)
            pr_correction = pr_correction - np.float32(shed / jnp.float32(n))
        active = active & ~g_pure

    # fold the forced prefix (core attributes, or the warm-start prefix)
    for a in forced:
        r_ids, k_new, theta_r, g_pure = adv(r_ids, gran.x[:, a], gran.d, gran.w, active, n)
        k = int(k_new)
        reduct.append(a)
        theta_hist.append(float(np.float32(theta_r) + pr_correction))
        if shrink:
            _shrink_step(g_pure)

    theta_r = theta_hist[-1] if theta_hist else float("inf")

    remaining = [a for a in range(A) if a not in reduct]
    iterations = 0
    while remaining and theta_r > stop_thresh:
        if max_features is not None and len(reduct) >= max_features:
            break
        it0 = time.perf_counter()
        nc = min(mp_chunk, max(len(remaining), 1))

        thetas = np.full((len(remaining),), np.inf, np.float64)
        if mode == "spark":
            # re-key from scratch: fingerprint of current R columns
            if reduct:
                hR1 = sum_terms(gran.x, reduct, 0)
                hR2 = sum_terms(gran.x, reduct, 7919)
            else:
                hR1 = jnp.zeros((cap,), jnp.uint32)
                hR2 = jnp.zeros((cap,), jnp.uint32)
            runner = _eval_chunk_spark(delta, cap, m, v)
            for s in range(0, len(remaining), nc):
                cols = np.asarray(remaining[s : s + nc], np.int32)
                pad = nc - len(cols)
                padded = np.concatenate([cols, np.full((pad,), cols[-1], np.int32)])
                vals = np.asarray(
                    runner(hR1, hR2, jnp.asarray(padded), gran.x, gran.d, gran.w, active, n, pr_correction)
                )
                thetas[s : s + len(cols)] = vals[: len(cols)]
        else:
            # Candidate-eval bin bound: full static cap·V for device-capable
            # backends (bit parity with engine="device"), a §5.3 rung when
            # the ladder is on (matching the device engine's switch), pow2
            # for the host-only Pallas backends.
            eval_bins = _eval_bins_for(k)
            if backend in SWEEP_BACKENDS:
                runner = _eval_chunk_sweep(delta, backend, eval_bins, m, v,
                                           selector)
                table = x_t_full
            else:
                runner = _eval_chunk_incremental(delta, backend, eval_bins,
                                                 m, v, selector)
                table = gran.x
            for s in range(0, len(remaining), nc):
                cols = np.asarray(remaining[s : s + nc], np.int32)
                pad = nc - len(cols)
                padded = np.concatenate([cols, np.full((pad,), cols[-1], np.int32)])
                vals = np.asarray(
                    runner(r_ids, jnp.asarray(padded), table, gran.d, gran.w, active, n, pr_correction)
                )
                thetas[s : s + len(cols)] = vals[: len(cols)]
        n_evals += len(remaining)

        best = measures.argmin_with_ties(thetas, tie_tol)  # paper line 13: argmin Θ
        a_opt = remaining[best]

        r_ids, k_new, theta_active, g_pure = adv(r_ids, gran.x[:, a_opt], gran.d, gran.w, active, n)
        k = int(k_new)
        theta_r = float(np.float32(theta_active) + pr_correction)
        reduct.append(a_opt)
        remaining.remove(a_opt)
        theta_hist.append(theta_r)
        if shrink:
            _shrink_step(g_pure)
        iterations += 1
        per_iter_s.append(time.perf_counter() - it0)

    return ReductionResult(
        reduct=reduct,
        core=core,
        theta_full=theta_full,
        theta_history=theta_hist,
        iterations=iterations,
        n_evaluations=n_evals,
        elapsed_s=time.perf_counter() - t0,
        per_iteration_s=per_iter_s,
    )


# ---------------------------------------------------------------------------
# reduct ensembles: one compile for a whole config grid (DESIGN.md §3.8)
# ---------------------------------------------------------------------------


# Per-config knobs the ensemble grid accepts; everything else (mode, backend,
# ladder, mp_chunk, ingestion) is shared — those are *static* trace choices,
# and sharing them is what lets the grid share one compile.
_ENSEMBLE_DEFAULTS = {
    "delta": "PR",
    "tol": 1e-6,
    "tie_tol": 1e-5,
    "max_features": None,
    "shrink": False,
    "compute_core": True,
    "eps": 0.0,
    "seed": None,          # bagged row-weight resample seed (None = no bag)
    "warm_start": None,    # forced greedy-resume prefix (replaces the core)
}

# Driver kwargs of :func:`plar_reduce` that the stacked engine *shares*
# across a grid (static trace choices + ingestion) — the complement of
# ``_ENSEMBLE_DEFAULTS``.  The serving scheduler uses this split to decide
# whether heterogeneous single-config queries can ride one stacked dispatch:
# per-config knobs may differ, shared knobs must agree.
ENSEMBLE_SHARED_KEYS = ("mode", "backend", "ladder", "selector", "mp_chunk",
                        "exact", "grc_init", "chunk_rows")


def partition_reduce_params(delta: str, params: dict):
    """Split one ``plar_reduce``-style ``(delta, params)`` query into the
    ``(config, shared)`` pair the stacked ensemble engine takes — or return
    ``None`` when the query cannot be expressed on it.

    A query is stackable when its measure is in :data:`ENSEMBLE_DELTAS`,
    every param is either a per-config grid knob (``_ENSEMBLE_DEFAULTS``) or
    a shared static (:data:`ENSEMBLE_SHARED_KEYS`), the backend (if given)
    is an :data:`ENSEMBLE_BACKENDS` member, and the ladder (if on) rides
    ``sweep_xla`` (the §3.8 shared-rung constraint).  Queries that fall
    outside — host-only Pallas backends, ``engine="host"``, unknown knobs —
    are served solo by the scheduler instead.
    """
    if delta not in ENSEMBLE_DELTAS:
        return None
    config = {"delta": delta}
    shared = {}
    for k, v in params.items():
        if k in _ENSEMBLE_DEFAULTS and k != "delta":
            config[k] = v
        elif k in ENSEMBLE_SHARED_KEYS:
            shared[k] = v
        else:
            return None
    if shared.get("backend", "segment") not in ENSEMBLE_BACKENDS:
        return None
    if shared.get("ladder") and shared.get("backend") != "sweep_xla":
        return None
    if shared.get("mode", "incremental") not in _MODES:
        return None
    return config, shared


def expand_ensemble_grid(configs, seeds=None):
    """Expand ``configs`` (dicts or bare measure names) × ``seeds``.

    ``seeds`` crosses every config with one bagged replica per seed (the
    bagged-ensemble idiom: ``configs=["PR"], seeds=range(8)`` is an 8-bag
    PR ensemble).  Configs carrying their own explicit ``seed`` cannot be
    combined with ``seeds=`` (ambiguous).  Returns plain dicts, defaults
    NOT yet filled — callers that key caches off configs use this expanded
    raw form so cache keys stay minimal.
    """
    expanded = []
    for c in configs:
        if isinstance(c, str):
            c = {"delta": c}
        c = dict(c)
        if seeds is None:
            expanded.append(c)
            continue
        if c.get("seed") is not None:
            raise ValueError(
                "pass bag seeds either per config ('seed') or via seeds=, "
                "not both")
        for s in seeds:
            expanded.append({**c, "seed": int(s)})
    return expanded


def normalize_ensemble_configs(configs, seeds=None) -> List[dict]:
    """Validate + default-fill an ensemble grid (see ``_ENSEMBLE_DEFAULTS``)."""
    expanded = expand_ensemble_grid(configs, seeds)
    if not expanded:
        raise ValueError("ensemble configs must be non-empty")
    out = []
    for c in expanded:
        unknown = sorted(set(c) - set(_ENSEMBLE_DEFAULTS))
        if unknown:
            raise ValueError(
                f"unknown ensemble config keys {unknown} "
                f"(one of: {', '.join(sorted(_ENSEMBLE_DEFAULTS))})")
        full = {**_ENSEMBLE_DEFAULTS, **c}
        if full["delta"] not in ENSEMBLE_DELTAS:
            raise ValueError(
                f"unknown measure: {full['delta']!r} "
                f"(one of: {', '.join(ENSEMBLE_DELTAS)})")
        if full["warm_start"] is not None:
            # integral/dupe validation here; range re-checked by the
            # driver once the granularity (and so A) exists
            full["warm_start"] = _validate_warm_start(
                full["warm_start"], None)
        out.append(full)
    return out


def bagged_weights(gran: Granularity, seed: int) -> np.ndarray:
    """Bootstrap resample of the row multiset as granule weights ``[cap]``.

    Draws ``n_total`` rows with replacement from the live rows — a
    multinomial over granules weighted by ``w`` — and returns the resampled
    per-granule counts.  Reweighting ``w`` keeps the granularity itself
    (``x``/ids/capacity) shared across every bag: granules are equivalence
    classes of *attribute values*, so a row resample only changes how many
    rows sit in each class, never the classes — no per-seed rebuild, and the
    stacked engine can carry all bags over one granule table.  Zero-weight
    granules stay live (``valid`` is untouched): they contribute 0 to every
    contingency and Θ, and keeping them preserves class numbering so results
    match a sequential run on the same reweighted granularity bit-for-bit.
    """
    w = np.asarray(gran.w, np.int64)
    valid = np.asarray(gran.valid)
    live = np.where(valid, w, 0)
    total = int(live.sum())
    if total <= 0:
        raise ValueError("cannot bag an empty granularity")
    rng = np.random.default_rng(int(seed))
    return rng.multinomial(total, live / live.sum()).astype(np.int32)


def plar_reduce_ensemble(
    x=None,
    d=None,
    *,
    source=None,                         # Granularity | GranuleSource (alt. to x, d)
    configs: Sequence,                   # per-config dicts (or measure names)
    seeds: Optional[Sequence[int]] = None,  # bag grid: configs × seeds
    chunk_rows: int = 65536,
    n_dec: Optional[int] = None,
    v_max: Optional[int] = None,
    mode: str = "incremental",
    backend: str = "segment",            # ENSEMBLE_BACKENDS
    ladder: bool = False,                # requires backend="sweep_xla"
    selector: str = "analytic",          # tile/rung selection mode
    mp_chunk: int = 64,
    grc_init: bool = True,
    exact: bool = True,
) -> List[ReductionResult]:
    """A grid of PLAR reductions over ONE granularity in ONE engine dispatch.

    Every config runs the same greedy selection :func:`plar_reduce` would —
    per-config reducts and Θ histories are byte-identical to N sequential
    runs (tests/test_ensemble.py) — but the grid shares a single XLA compile
    and a single pass over the granule/candidate tiles per iteration
    (DESIGN.md §3.8).  Per-config knobs: ``delta``, ``tol``, ``tie_tol``,
    ``max_features``, ``shrink``, ``compute_core``, ``eps``, ``seed``
    (a bagged row-weight resample via :func:`bagged_weights`; the sequential
    twin of config ``c`` is then ``plar_reduce`` on the same granularity
    with ``w`` replaced), and ``warm_start`` (a forced greedy-resume prefix
    riding the forced-core path — the stacked twin of
    ``plar_reduce(warm_start=...)``, byte-identical to it per config, which
    is what lets the serving scheduler batch warm repairs).  Shared knobs
    (``mode``, ``backend``, ``ladder``, ``mp_chunk``) are static trace
    choices.

    Results come back in grid order (``configs`` × ``seeds``); ``elapsed_s``
    is the per-config share of the total wall clock, and ``per_iteration_s``
    entries are the loop average over every executed body in the grid.
    """
    t0 = time.perf_counter()
    if mode not in _MODES:
        raise ValueError(
            f"unknown mode: {mode!r} (one of: {', '.join(_MODES)})")
    if backend not in ENSEMBLE_BACKENDS:
        raise ValueError(
            f"ensemble backend must be one of {', '.join(ENSEMBLE_BACKENDS)}; "
            f"got {backend!r} (run plar_reduce per config for host-only "
            f"backends)")
    cfgs = normalize_ensemble_configs(configs, seeds)
    gran = resolve_granularity(
        x, d, source=source, grc_init=grc_init, n_dec=n_dec, v_max=v_max,
        exact=exact, chunk_rows=chunk_rows)

    A = gran.n_attrs
    m = gran.n_dec
    cap = gran.capacity
    C = len(cfgs)

    # Θ(D|C) ids are w-independent — computed once for the whole grid; only
    # the contingency reweights per config.
    all_cols = jnp.arange(A, dtype=jnp.int32)
    ids_c, _k = subset_ids(gran, all_cols, exact=exact)

    base_w = np.asarray(gran.w, np.int32)
    ws = np.zeros((C, cap), np.int32)
    core_attrs = np.zeros((C, max(A, 1)), np.int32)
    core_counts = np.zeros((C,), np.int32)
    delta_idx = np.zeros((C,), np.int32)
    theta_fulls = np.zeros((C,), np.float64)
    ns = np.zeros((C,), np.int64)
    cores: List[List[int]] = []
    evals0 = np.zeros((C,), np.int64)

    for j, c in enumerate(cfgs):
        w_j = (bagged_weights(gran, c["seed"]) if c["seed"] is not None
               else base_w)
        n_j = int(np.where(np.asarray(gran.valid), w_j, 0).sum())
        ws[j] = w_j
        ns[j] = n_j
        delta_idx[j] = ENSEMBLE_DELTAS.index(c["delta"])
        cont_j = contingency_from_ids(
            ids_c, gran.d, jnp.asarray(w_j), gran.valid, n_bins=cap, m=m)
        theta_fulls[j] = float(
            measures.evaluate(c["delta"], cont_j, jnp.int32(n_j)))

        core_j: List[int] = []
        if c["warm_start"] is not None:
            # warm resume (DESIGN.md §3.7 on the stacked engine): the prefix
            # stands in for the core — forced folds through the same
            # core_attrs path, core computation skipped, ``core`` comes back
            # empty, exactly like ``plar_reduce(warm_start=...)``
            forced_j = _validate_warm_start(c["warm_start"], A)
            core_attrs[j, : len(forced_j)] = forced_j
            core_counts[j] = len(forced_j)
        elif c["compute_core"]:
            gran_j = gran if c["seed"] is None else dataclasses.replace(
                gran, w=jnp.asarray(w_j), n_total=jnp.int32(n_j))
            inner = _core_inner_thetas(gran_j, c["delta"], exact=exact)
            sig = inner - theta_fulls[j]
            core_j = [int(a) for a in range(A)
                      if sig[a] > c["eps"] + c["tie_tol"]]
            evals0[j] = A
            core_attrs[j, : len(core_j)] = core_j
            core_counts[j] = len(core_j)
        cores.append(core_j)

    ops = EnsembleOperands(
        delta_idx=jnp.asarray(delta_idx),
        tol=jnp.asarray([c["tol"] for c in cfgs], jnp.float32),
        tie_tol=jnp.asarray([c["tie_tol"] for c in cfgs], jnp.float32),
        max_sel=jnp.asarray(
            [A if c["max_features"] is None else int(c["max_features"])
             for c in cfgs], jnp.int32),
        shrink=jnp.asarray([bool(c["shrink"]) for c in cfgs], bool),
        theta_full=jnp.asarray(theta_fulls, jnp.float32),
        n=jnp.asarray(ns, jnp.int32),
        w=jnp.asarray(ws),
        core_attrs=jnp.asarray(core_attrs),
        core_count=jnp.asarray(core_counts),
    )
    runner = make_ensemble_run(
        mode, backend, C, A, cap, m, gran.v_max, int(mp_chunk), bool(ladder),
        str(selector))
    fin, loop_s = run_ensemble(
        runner, cap, A, gran.valid, gran.x, gran.d, ops)
    per_cfg = unpack_ensemble_result(fin, core_counts)

    elapsed = time.perf_counter() - t0
    total_bodies = sum(len(r[0]) for r in per_cfg)
    per_body = loop_s / total_bodies if total_bodies else 0.0
    results = []
    for j, (reduct, hist, iters, ev) in enumerate(per_cfg):
        results.append(ReductionResult(
            reduct=reduct,
            core=cores[j],
            theta_full=float(theta_fulls[j]),
            theta_history=hist,
            iterations=iters,
            n_evaluations=int(evals0[j]) + ev,
            elapsed_s=elapsed / C,
            per_iteration_s=[per_body] * len(reduct),
        ))
    return results


def sum_terms(x, cols: Sequence[int], seed: int):
    """Fingerprint restricted to a column subset (recomputed from scratch)."""
    h = jnp.zeros((x.shape[0],), jnp.uint32)
    for c in cols:
        h = h + column_terms(x[:, c], c, x.shape[1], seed)
    return h


def har_reduce(x=None, d=None, **kw) -> ReductionResult:
    """Paper baseline: Algorithm 1 — no GrC, sequential, re-key per candidate."""
    kw.setdefault("mode", "spark")
    kw.setdefault("mp_chunk", 1)
    return plar_reduce(x, d, grc_init=False, shrink=False, **kw)


def fspa_reduce(x=None, d=None, **kw) -> ReductionResult:
    """Paper baseline: FSPA — HAR + exact universe shrinking (positive approximation)."""
    kw.setdefault("mode", "spark")
    kw.setdefault("mp_chunk", 1)
    return plar_reduce(x, d, grc_init=False, shrink=True, **kw)
