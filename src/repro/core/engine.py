"""Device-resident greedy selection engine (DESIGN.md §3.5).

The paper's PLAR loop (Algorithm 2) is "cache once, iterate on device", but
the original drivers here were host-driven Python loops: every iteration
synced ``int(k_new)``, gathered thetas to numpy for the argmin, mutated a
Python ``remaining`` list, and re-jitted whenever ``bins_for(k)`` crossed a
power of two.  That is exactly the per-iteration driver round-trip the paper
fights in Spark, reintroduced at small scale.

This module keeps the *whole* reduction on device:

* :class:`SelectionState` — a pytree carrying everything the loop mutates:
  current class ids ``r_ids``, the FSPA shrink mask ``active`` + PR
  correction scalar, the remaining-attribute mask ``[A]``, a fixed
  ``[A]``-slot ``theta_history`` buffer, the selection ``order`` buffer, and
  the class count ``k``.
* ``engine_step`` — one jitted greedy iteration: evaluate **all** candidates,
  masked argmin-with-ties, fold the winner (presence-bitmap id compaction),
  update history/shrink state.  All shapes are static: the packed-id range is
  bounded by ``capacity · v_max`` for *every* iteration (ids are dense in
  ``[0, K)`` with ``K ≤ capacity``), so one compile covers the whole run —
  the host loop's ``bins_for(k)`` ladder trades per-iteration FLOPs for a
  recompile per power of two; the engine trades padding FLOPs for zero
  recompiles and zero host transfers.
* ``engine_run`` — the full reduction (core folding + greedy loop + stopping
  rule) as a single ``lax.while_loop``.  Core attributes are *forced*
  selections for the first ``core_count`` iterations of the same loop, so
  the core-fold/greedy/stopping/result-assembly logic exists exactly once.
* ``init_state_from_reduct`` / ``engine_resume`` — the warm-start seam for
  the online reduct service (DESIGN.md §3.7): seeding folds a previously
  selected prefix through the same compiled loop with the greedy phase
  disabled (``theta_full = +inf``), resuming continues greedy from the
  seeded state.  A warm reduction is two dispatches of the one trace.

The same ``cond``/``body`` serve the mesh driver: collectives are injected
via a tiny adapter (:class:`_LocalColl` is the identity; :class:`_MeshColl`
psums contingencies over the data axes and all-gathers per-model-shard
thetas), and :mod:`repro.core.distributed` wraps the loop in ``shard_map``.
The ``fused`` collective schedule is the one consumer that *must* return to
the host between iterations (its class re-grouping stages granule tables
through the driver), so it stays on the legacy host loop — see
``plar_reduce_distributed``.

The candidate evaluation is K-adaptive when ``ladder=True`` (DESIGN.md
§5.3): a ``lax.switch`` on the device-resident ``st.k`` picks the smallest
static bin rung covering ``K·v_max``, every rung branch living inside the
one while_loop compile, and the candidate slab ``x.T`` is hoisted out of
the loop.  The advance keeps the full static bound, so theta histories are
byte-identical with the ladder on or off.

Where the host loop is still required (the ``engine="host"`` escape hatch):

* ``backend="pallas"`` / ``"fused"`` / ``"sweep"`` — the interpret-mode
  Pallas kernels are not exercised inside ``while_loop`` bodies;
* ``collective="fused"`` — host-staged class regrouping (above);
* per-iteration wall-clock introspection (the host loop times each iteration
  individually; the engine reports the loop-average).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from . import measures
from .granularity import dyn_column_terms, ids_from_presence, presence_bitmap
from .plan import (
    candidate_contingency,
    candidate_theta,
    contingency_from_ids,
    ids_by_sort,
    ladder_rungs,
    sweep_contingency,
    theta_tiled_raw,
)

__all__ = [
    "SelectionState",
    "init_state",
    "init_state_from_reduct",
    "engine_resume",
    "make_engine_step",
    "make_engine_run",
    "unpack_result",
    "DEVICE_BACKENDS",
    "EnsembleOperands",
    "init_ensemble_state",
    "make_ensemble_run",
    "run_ensemble",
    "unpack_ensemble_result",
    "ENSEMBLE_DELTAS",
    "ENSEMBLE_BACKENDS",
]

# Θ backends that may run inside the while_loop body (DESIGN.md §3.5).
# ``sweep_xla`` is the read-once slab backend of DESIGN.md §5.3; the Pallas
# kernels (``pallas``/``fused``/``sweep``) stay on the host loop.
DEVICE_BACKENDS = ("segment", "onehot", "fused_xla", "sweep_xla")

# The static measure branch set of the ensemble engine's per-config
# lax.switch: every config's delta is a traced *index* into this tuple, so
# the compiled executable is independent of which measures a grid uses.
ENSEMBLE_DELTAS = tuple(measures.RAW_ROWS)  # ("PR", "SCE", "LCE", "CCE")

# Θ backends the stacked engine supports (DESIGN.md §3.8).  ``fused_xla`` is
# excluded: its measure is fused into the contingency accumulation itself, so
# it cannot split into a shared contingency + per-config measure epilogue.
ENSEMBLE_BACKENDS = ("segment", "onehot", "sweep_xla")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SelectionState:
    """Everything the greedy loop mutates, as one device-resident pytree.

    Shapes (``cap`` = granule capacity, ``A`` = number of attributes):

      r_ids          [cap] int32   dense class ids of U/R (K ≤ cap)
      h1, h2         [cap] uint32  linear-sketch fingerprints of R's columns
                                   (spark mode only; zeros otherwise)
      active         [cap] bool    live-granule mask (FSPA shrink)
      remaining      [A]   bool    attributes not yet selected
      theta_history  [A]   f32     Θ(D|R) after each selection (+inf unused)
      order          [A]   i32     attribute selected at each iteration (-1)
      k              []    i32     current class count K
      theta_r        []    f32     Θ(D|R) incl. PR correction (+inf initial)
      pr_correction  []    f32     FSPA PR-correction scalar
      n_selected     []    i32     |R| = iteration counter
    """

    r_ids: jnp.ndarray
    h1: jnp.ndarray
    h2: jnp.ndarray
    active: jnp.ndarray
    remaining: jnp.ndarray
    theta_history: jnp.ndarray
    order: jnp.ndarray
    k: jnp.ndarray
    theta_r: jnp.ndarray
    pr_correction: jnp.ndarray
    n_selected: jnp.ndarray

    def tree_flatten(self):
        return (
            self.r_ids, self.h1, self.h2, self.active, self.remaining,
            self.theta_history, self.order, self.k, self.theta_r,
            self.pr_correction, self.n_selected,
        ), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_state(cap: int, n_attrs: int, valid) -> SelectionState:
    """Fresh state: one class (the whole universe), nothing selected."""
    return SelectionState(
        r_ids=jnp.zeros((cap,), jnp.int32),
        h1=jnp.zeros((cap,), jnp.uint32),
        h2=jnp.zeros((cap,), jnp.uint32),
        active=jnp.asarray(valid, bool),
        remaining=jnp.ones((n_attrs,), bool),
        theta_history=jnp.full((n_attrs,), jnp.inf, jnp.float32),
        order=jnp.full((n_attrs,), -1, jnp.int32),
        k=jnp.int32(1),
        theta_r=jnp.float32(jnp.inf),
        pr_correction=jnp.float32(0.0),
        n_selected=jnp.int32(0),
    )


@dataclasses.dataclass(frozen=True)
class _Cfg:
    """Static trace-time configuration (hashable → one compile per value)."""

    delta: str
    mode: str            # "incremental" | "spark"
    backend: str         # DEVICE_BACKENDS
    n_attrs: int
    cap: int
    m: int
    v_max: int
    tol: float
    tie_tol: float
    shrink: bool
    max_sel: int         # max_features, or n_attrs when unbounded
    mp_chunk: int        # candidates evaluated per inner step (memory bound)
    ladder: bool = False  # K-adaptive bin ladder for the eval sweep (§5.3)
    selector: str = "heuristic"  # ladder-rung choice: heuristic|analytic|pinned

    @property
    def n_bins(self) -> int:
        # Static for the whole run: packed ids p = r·V + v live in [0, K·V)
        # and K ≤ cap always, so cap·V bounds every iteration.  Padding rows
        # are all-zero and contribute exactly 0 to every measure.
        return self.cap * self.v_max

    @property
    def rungs(self):
        # The static bucket set the eval sweep selects from per iteration
        # when ``ladder`` is on; the top rung is the full n_bins bound, so
        # the ladder-off path is exactly the degenerate one-rung ladder.
        # ``selector="analytic"`` prunes the pow2 set by the modeled
        # padding-vs-traffic tradeoff — a function of (cap, m) only, so the
        # host loop and mesh driver derive the identical set (§5.3 parity).
        return ladder_rungs(self.n_bins, selector=self.selector,
                            g=self.cap, m=self.m)


# ---------------------------------------------------------------------------
# collective adapters — the one seam between the two drivers
# ---------------------------------------------------------------------------


class _LocalColl:
    """Single-process: every collective is the identity."""

    n_data = 1
    daxes = ()

    def psum_data(self, x):
        return x

    def gather_model(self, thetas_local, n_attrs):
        return thetas_local[:n_attrs]


class _MeshColl:
    """Inside ``shard_map``: granules sharded over the data axes, candidates
    over 'model'.  Construct only inside the shard_map-traced function."""

    def __init__(self, daxes, nd: int, has_model: bool):
        self.daxes = daxes
        self.n_data = nd
        self.has_model = has_model

    def psum_data(self, x):
        return jax.lax.psum(x, self.daxes) if self.daxes else x

    def gather_model(self, thetas_local, n_attrs):
        if self.has_model:
            thetas_local = jax.lax.all_gather(
                thetas_local, "model", tiled=True)
        return thetas_local[:n_attrs]


# ---------------------------------------------------------------------------
# the shared step pieces
# ---------------------------------------------------------------------------


def _advance(cfg, coll, r_ids, x_col, d, w, active, n, eval_theta=None):
    """Fold one attribute into the class ids: pack → compact → Θ → purity.

    The presence bitmap psums over data shards before ranking, so every shard
    agrees on the global dense numbering (DESIGN.md §3.1) — with
    :class:`_LocalColl` this is exactly ``granularity.compact_ids``.

    ``eval_theta(cont, n)`` overrides the measure evaluation: the ensemble
    engine passes a ``lax.switch`` over the measures so ``delta`` can be a
    traced per-config operand instead of the static ``cfg.delta`` (the
    default, bit-identical for all existing callers).
    """
    nb = cfg.n_bins
    packed = r_ids * cfg.v_max + x_col
    presence = coll.psum_data(presence_bitmap(packed, active, nb))
    new_ids, k_new = ids_from_presence(presence, packed, active)

    w_ = jnp.where(active, w, 0).astype(jnp.float32)
    seg = jnp.where(active, new_ids * cfg.m + d, nb * cfg.m)
    cont = jax.ops.segment_sum(w_, seg, num_segments=nb * cfg.m + 1)[:-1]
    cont = coll.psum_data(cont.reshape(nb, cfg.m))
    theta = (measures.evaluate(cfg.delta, cont, n) if eval_theta is None
             else eval_theta(cont, n))

    e = cont.sum(-1)
    pure_row = (cont.max(-1) == e) & (e > 0)
    g_pure = pure_row[new_ids] & active
    return new_ids, k_new.astype(jnp.int32), theta, g_pure


def _rung_index(cfg, k):
    """Device-side ladder rung selection: first rung ≥ K·V (DESIGN.md §5.3).

    ``cfg`` is any config carrying ``v_max``/``rungs`` (``_Cfg`` or the
    ensemble ``_EnsCfg``).

    ``k`` is the device-resident class count (``st.k``): packed ids live in
    ``[0, K·V)``, rungs are ascending, and the top rung is the exact full
    bound, so the index is always in range — no host sync, no clamp.
    """
    need = k.astype(jnp.int32) * cfg.v_max
    return jnp.sum(need > jnp.asarray(cfg.rungs, jnp.int32)).astype(jnp.int32)


def _eval_local(cfg: _Cfg, st: SelectionState, x, x_t, d, w, n):
    """Single-process candidate evaluation: Θ(D|R∪{a}) for every a, [A].

    ``x_t`` is the pre-transposed ``[A, cap]`` candidate slab, hoisted out of
    the loop by the callers: candidate rows are contiguous slices instead of
    a per-iteration gather+transpose of ``x``.
    """
    cols = jnp.arange(cfg.n_attrs, dtype=jnp.int32)
    if cfg.mode == "spark":
        # Paper-faithful cost shape: re-key every granule from scratch per
        # candidate (fingerprint sort), exactly `_eval_chunk_spark` but with
        # the R-fingerprints maintained incrementally in the state (the
        # linear-sketch property: h(R∪{a}) = h(R) + term_a, uint32-exact).
        # The bin ladder does not apply: sort-ranked ids are bounded by the
        # live-granule count, not K·V.
        def one(col):
            t1 = dyn_column_terms(x, col, 0)
            t2 = dyn_column_terms(x, col, 7919)
            ids, _k = ids_by_sort([st.h2 + t2, st.h1 + t1], st.active)
            cont = contingency_from_ids(
                ids, d, w, st.active, n_bins=cfg.cap, m=cfg.m)
            return measures.evaluate(cfg.delta, cont, n)

        return jax.lax.map(one, cols) + st.pr_correction

    def eval_all(nb):
        def chunk(cc):
            x_cand = jnp.take(x_t, cc, axis=0)                 # [nc, cap]
            if cfg.backend == "sweep_xla":
                return candidate_theta(
                    cfg.delta, None, d, w, st.active, n,
                    n_bins=nb, m=cfg.m, backend=cfg.backend,
                    x_t=x_cand, r_ids=st.r_ids, v_max=cfg.v_max)
            packed = st.r_ids[None, :] * cfg.v_max + x_cand
            return candidate_theta(
                cfg.delta, packed, d, w, st.active, n,
                n_bins=nb, m=cfg.m, backend=cfg.backend)

        # mp_chunk (the paper's MP level) bounds peak memory to
        # [mp_chunk, nb, m] per inner step, exactly like the host loop's
        # chunked dispatch; per-candidate values are independent, so chunking
        # never changes bits.
        nc = min(cfg.mp_chunk, cfg.n_attrs)
        a_pad = -(-cfg.n_attrs // nc) * nc
        if a_pad == nc:
            return chunk(cols)
        grid = (jnp.arange(a_pad, dtype=jnp.int32) % cfg.n_attrs).reshape(-1, nc)
        return jax.lax.map(chunk, grid).reshape(-1)[: cfg.n_attrs]

    if not cfg.ladder or len(cfg.rungs) == 1:
        return eval_all(cfg.n_bins) + st.pr_correction

    # K-adaptive bin ladder (§5.3): all rung branches trace into the one
    # while_loop compile; per iteration a lax.switch on the device-resident
    # st.k picks the smallest rung covering K·V — early iterations pay
    # K-proportional work with zero recompiles and zero host transfers.
    thetas = jax.lax.switch(
        _rung_index(cfg, st.k), [partial(eval_all, nb) for nb in cfg.rungs])
    return thetas + st.pr_correction


def merge_candidate_cont(delta, cont, n, coll, collective: str):
    """Per-shard candidate contingency ``[nc, nb, m]`` → merged thetas [nc].

    The §3.2 collective schedules, shared by both mesh step implementations
    (this engine's ``_eval_mesh`` and the legacy ``distributed._eval_step``):
    ``all_reduce`` psums the full contingency (paper-faithful DP);
    ``reduce_scatter`` scatters contingency *rows* over the data shards,
    reduces θ locally (row-separability, Eq. 8) and psums the scalar.
    """
    nb = cont.shape[1]
    if collective == "reduce_scatter" and coll.n_data > 1 and nb % coll.n_data == 0:
        cont_slice = jax.lax.psum_scatter(
            cont, coll.daxes, scatter_dimension=1, tiled=True)
        return jax.lax.psum(
            measures.theta_rows(delta, cont_slice, n).sum(-1), coll.daxes)
    return measures.evaluate(delta, coll.psum_data(cont), n)


def _mesh_cand_slab(cfg: _Cfg, coll: _MeshColl, n_model, x):
    """This model shard's candidate slice + pre-transposed slab [A_loc, G_loc].

    Hoisted out of the while_loop by ``_engine_run_mesh``: the gather and
    transpose of the granule table happen once per run, not per iteration.
    """
    a_pad = -(-cfg.n_attrs // n_model) * n_model
    a_loc = a_pad // n_model
    midx = jax.lax.axis_index("model") if coll.has_model else 0
    cand = jnp.minimum(midx * a_loc + jnp.arange(a_loc, dtype=jnp.int32),
                       cfg.n_attrs - 1)
    return jnp.take(x, cand, axis=1).T.astype(jnp.int32)


def _eval_mesh(cfg: _Cfg, coll: _MeshColl, collective, st, x_tl, d, w, n):
    """Mesh candidate evaluation: this shard's candidate slab → gather [A].

    ``x_tl [A_loc, G_loc]`` is this shard's pre-transposed candidate slab
    (:func:`_mesh_cand_slab`).  Contingencies merge via
    :func:`merge_candidate_cont`; every §5.3 ladder rung stays divisible by
    the data-shard count (rungs below the top are pow2 multiples of the
    256-bin tile; the top rung ``cap·V`` has ``cap = nd · cap_per_shard``),
    so ``reduce_scatter`` keeps tiling at every rung.
    """
    w_ = jnp.where(st.active, w, 0).astype(jnp.float32)
    d32 = d.astype(jnp.int32)

    def eval_all(nb):
        if cfg.backend == "sweep_xla":
            # fused-pack contingency (packed [A_loc, G_loc] never staged)
            cont = sweep_contingency(
                x_tl, st.r_ids, d32, w_, st.active, v_max=cfg.v_max,
                n_bins=nb, m=cfg.m)
        else:
            packed = st.r_ids[None, :] * cfg.v_max + x_tl

            def one(p):
                seg = jnp.where(st.active, p * cfg.m + d32, nb * cfg.m)
                return jax.ops.segment_sum(
                    w_, seg, num_segments=nb * cfg.m + 1)[:-1]

            cont = jax.vmap(one)(packed).reshape(-1, nb, cfg.m)
        return merge_candidate_cont(cfg.delta, cont, n, coll, collective)

    if not cfg.ladder or len(cfg.rungs) == 1:
        th_loc = eval_all(cfg.n_bins)
    else:
        # K·V is globally consistent (st.k is replicated by the presence-psum
        # compaction), so every shard switches to the same rung and the
        # collectives inside each branch stay congruent across the mesh.
        th_loc = jax.lax.switch(
            _rung_index(cfg, st.k), [partial(eval_all, nb) for nb in cfg.rungs])
    return coll.gather_model(th_loc, cfg.n_attrs) + st.pr_correction


def _make_cond_body(cfg: _Cfg, coll, eval_thetas, x, d, w, n, theta_full,
                    core_attrs, core_count):
    """The one greedy core: cond/body shared by both drivers.

    ``eval_thetas(state) -> [A]`` is the injected evaluation strategy (local
    or mesh-collective); everything else — forced core folds, masked
    argmin-with-ties, advance, shrink, history — is identical code.
    """

    def cond(st: SelectionState):
        in_core = st.n_selected < core_count
        greedy = (
            (st.n_selected < cfg.n_attrs)
            & (st.theta_r > theta_full + cfg.tol)
            & (st.n_selected < cfg.max_sel)
        )
        return in_core | greedy

    def body(st: SelectionState):
        forced = st.n_selected < core_count

        def pick_core(st):
            return core_attrs[jnp.minimum(st.n_selected, cfg.n_attrs - 1)]

        def pick_greedy(st):
            thetas = jnp.where(st.remaining, eval_thetas(st), jnp.inf)
            # lowest index within tie_tol of the minimum — the device twin of
            # measures.argmin_with_ties (remaining is index-ordered, so the
            # first in-band slot is the same attribute the host loop picks).
            return jnp.argmax(thetas <= thetas.min() + cfg.tie_tol).astype(jnp.int32)

        best = jax.lax.cond(forced, pick_core, pick_greedy, st)
        x_col = jnp.take(x, best, axis=1)
        new_ids, k_new, theta, g_pure = _advance(
            cfg, coll, st.r_ids, x_col, d, w, st.active, n)
        theta_rec = theta + st.pr_correction   # correction *before* this fold

        if cfg.mode == "spark":
            h1 = st.h1 + dyn_column_terms(x, best, 0)
            h2 = st.h2 + dyn_column_terms(x, best, 7919)
        else:
            h1, h2 = st.h1, st.h2

        if cfg.shrink:
            active = st.active & ~g_pure
            if cfg.delta == "PR":
                shed = jnp.sum(jnp.where(g_pure, w, 0)).astype(jnp.float32)
                pr_corr = st.pr_correction - shed / jnp.asarray(n, jnp.float32)
            else:
                pr_corr = st.pr_correction
        else:
            active, pr_corr = st.active, st.pr_correction

        return SelectionState(
            r_ids=new_ids,
            h1=h1,
            h2=h2,
            active=active,
            remaining=st.remaining.at[best].set(False),
            theta_history=st.theta_history.at[st.n_selected].set(theta_rec),
            order=st.order.at[st.n_selected].set(best),
            k=k_new,
            theta_r=theta_rec,
            pr_correction=pr_corr,
            n_selected=st.n_selected + 1,
        )

    return cond, body


# ---------------------------------------------------------------------------
# public entry points (cached per static config → one compile each)
# ---------------------------------------------------------------------------


def make_engine_step(delta: str, mode: str, backend: str, n_attrs: int,
                     cap: int, m: int, v_max: int, tol: float, tie_tol: float,
                     shrink: bool, max_sel: int, mp_chunk: int = 64,
                     ladder: bool = False, selector: str = "analytic"):
    """One jitted greedy iteration (evaluate → argmin → advance).

    Exposed for inspection/benchmarks; ``make_engine_run`` inlines the same
    body into its while_loop, so a full reduction costs one compile, not two.
    """
    # thin wrapper so defaulted, keyword, and explicit positional calls all
    # share one lru entry, and numpy scalar arguments (np.int32 dims from a
    # Granularity, np.bool_ flags) key identically to their Python values —
    # the single-compile contract (asserted by test_engine_factory_cache_key)
    return _make_engine_step(str(delta), str(mode), str(backend),
                             int(n_attrs), int(cap), int(m), int(v_max),
                             float(tol), float(tie_tol), bool(shrink),
                             int(max_sel), int(mp_chunk), bool(ladder),
                             str(selector))


@lru_cache(maxsize=None)
def _make_engine_step(delta, mode, backend, n_attrs, cap, m, v_max, tol,
                      tie_tol, shrink, max_sel, mp_chunk, ladder, selector):
    # an lru miss here IS a new trace → a new XLA compile at first dispatch
    obs.counter("plar_engine_step_factories_total",
                "distinct single-step engine configs traced").inc()
    cfg = _Cfg(delta, mode, backend, n_attrs, cap, m, v_max, tol, tie_tol,
               shrink, max_sel, mp_chunk, ladder, selector)

    @jax.jit
    def step(st: SelectionState, x, d, w, n, theta_full, core_attrs,
             core_count) -> SelectionState:
        x_t = x.T
        coll = _LocalColl()
        _, body = _make_cond_body(
            cfg, coll, lambda s: _eval_local(cfg, s, x, x_t, d, w, n),
            x, d, w, n, theta_full, core_attrs, core_count)
        return body(st)

    return step


def make_engine_run(delta: str, mode: str, backend: str, n_attrs: int,
                    cap: int, m: int, v_max: int, tol: float, tie_tol: float,
                    shrink: bool, max_sel: int, mp_chunk: int = 64,
                    ladder: bool = False, selector: str = "analytic"):
    """The full reduction as one ``lax.while_loop`` (single-process)."""
    # same key normalization as make_engine_step (one lru entry per logical
    # config regardless of call style or numpy scalar types)
    return _make_engine_run(str(delta), str(mode), str(backend),
                            int(n_attrs), int(cap), int(m), int(v_max),
                            float(tol), float(tie_tol), bool(shrink),
                            int(max_sel), int(mp_chunk), bool(ladder),
                            str(selector))


@lru_cache(maxsize=None)
def _make_engine_run(delta, mode, backend, n_attrs, cap, m, v_max, tol,
                     tie_tol, shrink, max_sel, mp_chunk, ladder, selector):
    # an lru miss here IS a new trace → a new XLA compile at first dispatch
    obs.counter("plar_engine_run_factories_total",
                "distinct while_loop engine configs traced").inc()
    cfg = _Cfg(delta, mode, backend, n_attrs, cap, m, v_max, tol, tie_tol,
               shrink, max_sel, mp_chunk, ladder, selector)

    @jax.jit
    def run(st: SelectionState, x, d, w, n, theta_full, core_attrs,
            core_count) -> SelectionState:
        # The candidate slab transpose is hoisted out of the while_loop: one
        # [A, cap] materialization per run instead of a gather+transpose per
        # iteration (per mp_chunk, per rung branch).
        x_t = x.T
        coll = _LocalColl()
        cond, body = _make_cond_body(
            cfg, coll, lambda s: _eval_local(cfg, s, x, x_t, d, w, n),
            x, d, w, n, theta_full, core_attrs, core_count)
        return jax.lax.while_loop(cond, body, st)

    return run


def _forced_attrs(n_attrs: int, forced) -> jnp.ndarray:
    """The padded ``[max(A,1)]`` forced-selection buffer both entry points
    feed the loop (core attributes and warm-start prefixes alike)."""
    arr = np.zeros((max(n_attrs, 1),), np.int32)
    arr[: len(forced)] = forced
    return jnp.asarray(arr)


def init_state_from_reduct(runner, cap: int, n_attrs: int, valid, x, d, w, n,
                           prefix) -> SelectionState:
    """Seed a :class:`SelectionState` by folding ``prefix`` into fresh state.

    The online-service repair primitive (DESIGN.md §3.7): runs the *same*
    compiled while_loop as the full reduction with the greedy phase disabled
    (``theta_full = +inf`` makes the greedy condition vacuously false), so
    the loop executes exactly ``len(prefix)`` forced folds and exits.  The
    returned state carries the refined ``r_ids``/``k``, the recomputed
    Θ-history prefix (the *validation* signal — each entry is Θ(D|prefix[:i])
    on the current granularity), and ``remaining`` with the prefix cleared —
    ready for :func:`engine_resume`.  ``theta_full`` is a traced operand, so
    seeding adds zero compiles beyond the runner's single trace.
    """
    st = init_state(cap, n_attrs, valid)
    return runner(st, x, d, w, n, jnp.float32(jnp.inf),
                  _forced_attrs(n_attrs, prefix), jnp.int32(len(prefix)))


def engine_resume(runner, st: SelectionState, x, d, w, n, theta_full):
    """Resume the greedy loop from a seeded state (no forced selections).

    The warm-start twin of a cold ``runner`` call: with ``core_count = 0``
    the loop goes straight to greedy iterations from wherever ``st`` left
    off.  Same compiled executable as the cold run and the seed — a warm
    reduction is two dispatches of one trace.
    """
    n_attrs = st.remaining.shape[0]
    return runner(st, x, d, w, n, jnp.float32(theta_full),
                  _forced_attrs(n_attrs, ()), jnp.int32(0))


def run_engine(runner, cap: int, n_attrs: int, valid, x, d, w, n,
               theta_full: float, core, warm_start=None):
    """Init-state → jitted loop → unpack: the device path shared verbatim by
    both drivers (``plar_reduce`` and ``plar_reduce_distributed``).

    With ``warm_start`` (a previously selected prefix; ``core`` must be
    empty) the loop is seeded by :func:`init_state_from_reduct` and resumed
    by :func:`engine_resume` — two dispatches of the same single compile,
    re-folding the prefix as forced selections and running greedy only for
    the remainder.

    Returns ``(reduct, theta_history, iterations, n_evals, per_iteration_s)``
    where ``per_iteration_s`` holds one entry per *executed loop body* —
    ``len(reduct)`` entries, core/warm folds included — each the loop average
    (the engine is a single dispatch, so individual bodies cannot be timed;
    the list sums to the measured loop wall-clock exactly).
    """
    import time

    traces_before = _jit_cache_size(runner)
    t_loop = time.perf_counter()
    with obs.span("engine.dispatch", n_attrs=n_attrs, cap=cap,
                  warm=warm_start is not None) as sp:
        if warm_start is not None:
            assert not core, "warm_start replaces the core prefix"
            forced = list(warm_start)
            st = init_state_from_reduct(
                runner, cap, n_attrs, valid, x, d, w, n, forced)
            fin = jax.block_until_ready(
                engine_resume(runner, st, x, d, w, n, theta_full))
        else:
            forced = list(core)
            st = init_state(cap, n_attrs, valid)
            fin = jax.block_until_ready(
                runner(st, x, d, w, n, jnp.float32(theta_full),
                       _forced_attrs(n_attrs, forced), jnp.int32(len(forced))))
        loop_s = time.perf_counter() - t_loop
        reduct, hist, iters, n_evals = unpack_result(fin, len(forced))
        traces_after = _jit_cache_size(runner)
        compiled = (traces_before is not None
                    and traces_after is not None
                    and traces_after > traces_before)
        sp.set(k=len(reduct), iterations=iters, compiled=compiled)
    obs.counter("plar_engine_runs_total",
                "engine while_loop dispatch sequences completed").inc()
    if compiled:
        obs.counter("plar_engine_compiles_total",
                    "engine dispatches that paid a fresh trace/compile").inc()
    obs.gauge("plar_engine_last_k",
              "reduct size of the most recent engine run").set(len(reduct))
    obs.gauge("plar_engine_last_iterations",
              "greedy iterations of the most recent engine run").set(iters)
    n_bodies = len(reduct)
    per_body = loop_s / n_bodies if n_bodies else 0.0
    if n_bodies:
        obs.histogram("plar_engine_iteration_seconds",
                      "loop-average seconds per executed engine loop body"
                      ).observe(per_body)
    return reduct, hist, iters, n_evals, [per_body] * n_bodies


def _jit_cache_size(runner) -> Optional[int]:
    """Traced-executable count of a jitted callable, when the running jax
    exposes it (``_cache_size``) — lets the dispatch span tell a compile
    from a cache hit.  None when unavailable: never guess."""
    probe = getattr(runner, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


def unpack_result(fin: SelectionState, core_count: int):
    """Host-side unpack: (reduct, theta_history, greedy_iterations, n_evals).

    The single device→host transfer of the whole greedy phase.
    """
    nsel = int(fin.n_selected)
    order = np.asarray(fin.order)[:nsel]
    reduct = [int(a) for a in order]
    hist = [float(t) for t in np.asarray(fin.theta_history)[:nsel]]
    iters = nsel - core_count
    n_attrs = fin.remaining.shape[0]
    # the engine evaluates ALL A candidates each greedy iteration (already-
    # selected ones are masked after the fact — static shapes); report that
    # true count, which is ≥ the host loop's shrinking len(remaining)
    n_evals = iters * n_attrs
    return reduct, hist, iters, n_evals


# ---------------------------------------------------------------------------
# stacked multi-config engine (DESIGN.md §3.8)
# ---------------------------------------------------------------------------
#
# One ``lax.while_loop`` dispatch advances a whole grid of reduction configs
# — (measure, tol, tie_tol, max_features, shrink, forced core, bagged row
# weights) — over ONE shared granularity: the config axis is a leading [C]
# axis on :class:`SelectionState` and the per-config parameters ride along as
# *traced* operands (:class:`EnsembleOperands`), so the whole grid costs one
# compile and every granule/candidate tile is read once per iteration instead
# of once per config.  Per-config measures dispatch through a ``lax.switch``
# over :data:`ENSEMBLE_DELTAS` whose branches run exactly the sequential
# engine's evaluation ops — the byte-identical-per-config contract (asserted
# by tests/test_ensemble.py) rests on that switch executing one branch, not a
# blend.


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EnsembleOperands:
    """Per-config traced parameters of the stacked engine, leading axis [C].

    Everything the sequential engine bakes into its static ``_Cfg`` that can
    instead be a traced operand lives here — which is exactly what collapses
    a C-config grid from C compiles to one:

      delta_idx   [C]          i32   index into ENSEMBLE_DELTAS
      tol         [C]          f32   stopping tolerance
      tie_tol     [C]          f32   argmin tie band
      max_sel     [C]          i32   max_features (n_attrs when unbounded)
      shrink      [C]          bool  FSPA universe shrinking
      theta_full  [C]          f32   Θ(D|C) stopping target (per-config w!)
      n           [C]          i32   total row weight |U|
      w           [C, cap]     i32   granule weights (bagged resample seam)
      core_attrs  [C, max(A,1)] i32  forced-selection prefix, padded
      core_count  [C]          i32   number of forced selections
    """

    delta_idx: jnp.ndarray
    tol: jnp.ndarray
    tie_tol: jnp.ndarray
    max_sel: jnp.ndarray
    shrink: jnp.ndarray
    theta_full: jnp.ndarray
    n: jnp.ndarray
    w: jnp.ndarray
    core_attrs: jnp.ndarray
    core_count: jnp.ndarray

    def tree_flatten(self):
        return (
            self.delta_idx, self.tol, self.tie_tol, self.max_sel, self.shrink,
            self.theta_full, self.n, self.w, self.core_attrs, self.core_count,
        ), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_cfgs(self) -> int:
        return self.delta_idx.shape[0]


@dataclasses.dataclass(frozen=True)
class _EnsCfg:
    """Static trace-time configuration of the stacked engine.

    Deliberately *smaller* than ``_Cfg``: everything per-config moved into
    :class:`EnsembleOperands`, so the compile cache keys only on shapes and
    the shared evaluation strategy.
    """

    mode: str            # "incremental" | "spark"
    backend: str         # ENSEMBLE_BACKENDS
    n_cfgs: int
    n_attrs: int
    cap: int
    m: int
    v_max: int
    mp_chunk: int
    ladder: bool = False
    selector: str = "heuristic"

    @property
    def n_bins(self) -> int:
        return self.cap * self.v_max

    @property
    def rungs(self):
        return ladder_rungs(self.n_bins, selector=self.selector,
                            g=self.cap, m=self.m)


def _theta_switch(delta_idx, cont, n):
    """Θ(cont) under a *traced* measure index: one-branch lax.switch whose
    branches are exactly ``measures.evaluate`` per measure — the selected
    branch runs the same ops as the sequential engine, so bits match."""
    return jax.lax.switch(
        delta_idx,
        [partial(measures.evaluate, dd) for dd in ENSEMBLE_DELTAS], cont, n)


def _sweep_theta_switch(delta_idx, cont, n):
    """The sweep epilogue under a traced measure index: tile-ordered θ'
    accumulation (plan.theta_tiled_raw) + scale, per branch — the §5.3
    structure whose bitwise rung invariance lets the stacked ladder share
    one rung across configs."""

    def mk(dd):
        def branch(cont, n):
            return measures.theta_scale(dd, theta_tiled_raw(dd, cont), n)

        return branch

    return jax.lax.switch(
        delta_idx, [mk(dd) for dd in ENSEMBLE_DELTAS], cont, n)


def _eval_ensemble_one(cfg: _EnsCfg, x, x_t, d, nb, st_c, w_c, n_c, delta_idx):
    """One config's candidate evaluation Θ(D|R∪{a}) for every a — the
    ensemble twin of :func:`_eval_local`, vmapped over the config axis by
    the runner.  Mirrors the sequential evaluation op-for-op (same
    contingency path, same chunking) with the measure dispatched through
    the one-branch switch."""
    cols = jnp.arange(cfg.n_attrs, dtype=jnp.int32)
    if cfg.mode == "spark":
        # paper-faithful re-key per candidate; the ladder does not apply
        # (sort-ranked ids are bounded by the live-granule count, not K·V)
        def one(col):
            t1 = dyn_column_terms(x, col, 0)
            t2 = dyn_column_terms(x, col, 7919)
            ids, _k = ids_by_sort([st_c.h2 + t2, st_c.h1 + t1], st_c.active)
            cont = contingency_from_ids(
                ids, d, w_c, st_c.active, n_bins=cfg.cap, m=cfg.m)
            return _theta_switch(delta_idx, cont, n_c)

        return jax.lax.map(one, cols) + st_c.pr_correction

    def chunk(cc):
        x_cand = jnp.take(x_t, cc, axis=0)                     # [nc, cap]
        if cfg.backend == "sweep_xla":
            cont = sweep_contingency(
                x_cand, st_c.r_ids, d, w_c, st_c.active, v_max=cfg.v_max,
                n_bins=nb, m=cfg.m)
            return _sweep_theta_switch(delta_idx, cont, n_c)
        packed = st_c.r_ids[None, :] * cfg.v_max + x_cand
        cont = candidate_contingency(
            packed, d, w_c, st_c.active, n_bins=nb, m=cfg.m,
            backend=cfg.backend)
        return _theta_switch(delta_idx, cont, n_c)

    # same mp_chunk grid as _eval_local: per-candidate values are
    # independent, so chunking never changes bits
    nc = min(cfg.mp_chunk, cfg.n_attrs)
    a_pad = -(-cfg.n_attrs // nc) * nc
    if a_pad == nc:
        return chunk(cols) + st_c.pr_correction
    grid = (jnp.arange(a_pad, dtype=jnp.int32) % cfg.n_attrs).reshape(-1, nc)
    return (jax.lax.map(chunk, grid).reshape(-1)[: cfg.n_attrs]
            + st_c.pr_correction)


def make_ensemble_run(mode: str, backend: str, n_cfgs: int, n_attrs: int,
                      cap: int, m: int, v_max: int, mp_chunk: int = 64,
                      ladder: bool = False, selector: str = "analytic"):
    """The whole config grid as one ``lax.while_loop`` (single compile).

    Returns ``run(st_stack, x, d, ops) -> st_stack`` where every
    :class:`SelectionState` leaf carries a leading ``[n_cfgs]`` axis and
    ``ops`` is the :class:`EnsembleOperands` stack.  Same key normalization
    as :func:`make_engine_run` (one lru entry per logical config).
    """
    if backend not in ENSEMBLE_BACKENDS:
        raise ValueError(
            f"ensemble engine does not support backend={backend!r} "
            f"(one of: {', '.join(ENSEMBLE_BACKENDS)})")
    if ladder and backend != "sweep_xla":
        raise ValueError(
            "ensemble ladder requires backend='sweep_xla': the stacked loop "
            "shares one rung (max K across configs) per iteration, which is "
            "only bit-safe under the §5.3 sweep rung invariance")
    return _make_ensemble_run(str(mode), str(backend), int(n_cfgs),
                              int(n_attrs), int(cap), int(m), int(v_max),
                              int(mp_chunk), bool(ladder), str(selector))


@lru_cache(maxsize=None)
def _make_ensemble_run(mode, backend, n_cfgs, n_attrs, cap, m, v_max,
                       mp_chunk, ladder, selector):
    # an lru miss here IS a new trace → a new XLA compile at first dispatch
    obs.counter("plar_engine_ensemble_factories_total",
                "distinct stacked-engine configs traced").inc()
    cfg = _EnsCfg(mode, backend, n_cfgs, n_attrs, cap, m, v_max, mp_chunk,
                  ladder, selector)
    coll = _LocalColl()
    pr_idx = ENSEMBLE_DELTAS.index("PR")

    @jax.jit
    def run(st: SelectionState, x, d, ops: EnsembleOperands) -> SelectionState:
        # shared candidate slab, hoisted out of the loop exactly like the
        # sequential runner — and read ONCE per iteration for all configs
        x_t = x.T

        def cond_one(st_c, ops_c):
            # the sequential cond with tol/max_sel as traced operands; the
            # f32 arithmetic theta_full + tol matches the static-Python
            # version bit-for-bit (both are f32 + f32)
            in_core = st_c.n_selected < ops_c.core_count
            greedy = (
                (st_c.n_selected < cfg.n_attrs)
                & (st_c.theta_r > ops_c.theta_full + ops_c.tol)
                & (st_c.n_selected < ops_c.max_sel)
            )
            return in_core | greedy

        def eval_rung(nb, st):
            def one(st_c, w_c, n_c, di):
                return _eval_ensemble_one(
                    cfg, x, x_t, d, nb, st_c, w_c, n_c, di)

            return jax.vmap(one)(st, ops.w, ops.n, ops.delta_idx)  # [C, A]

        def body_one(st_c, ops_c, thetas_c):
            forced = st_c.n_selected < ops_c.core_count

            # sequential pick_core / pick_greedy as a select on precomputed
            # thetas (the grid shares the evaluation, so the lax.cond that
            # skips evaluation during forced folds has nothing left to skip)
            core_pick = ops_c.core_attrs[
                jnp.minimum(st_c.n_selected, cfg.n_attrs - 1)]
            masked = jnp.where(st_c.remaining, thetas_c, jnp.inf)
            greedy_pick = jnp.argmax(
                masked <= masked.min() + ops_c.tie_tol).astype(jnp.int32)
            best = jnp.where(forced, core_pick, greedy_pick)

            x_col = jnp.take(x, best, axis=1)
            new_ids, k_new, theta, g_pure = _advance(
                cfg, coll, st_c.r_ids, x_col, d, ops_c.w, st_c.active,
                ops_c.n, eval_theta=partial(_theta_switch, ops_c.delta_idx))
            theta_rec = theta + st_c.pr_correction

            if cfg.mode == "spark":
                h1 = st_c.h1 + dyn_column_terms(x, best, 0)
                h2 = st_c.h2 + dyn_column_terms(x, best, 7919)
            else:
                h1, h2 = st_c.h1, st_c.h2

            # traced-shrink: a select per config instead of _Cfg branching;
            # shrink=False leaves active/pr_correction exactly unchanged
            active = st_c.active & ~(g_pure & ops_c.shrink)
            shed = jnp.sum(jnp.where(g_pure, ops_c.w, 0)).astype(jnp.float32)
            pr_corr = jnp.where(
                ops_c.shrink & (ops_c.delta_idx == pr_idx),
                st_c.pr_correction - shed / jnp.asarray(ops_c.n, jnp.float32),
                st_c.pr_correction)

            return SelectionState(
                r_ids=new_ids,
                h1=h1,
                h2=h2,
                active=active,
                remaining=st_c.remaining.at[best].set(False),
                theta_history=st_c.theta_history.at[st_c.n_selected].set(
                    theta_rec),
                order=st_c.order.at[st_c.n_selected].set(best),
                k=k_new,
                theta_r=theta_rec,
                pr_correction=pr_corr,
                n_selected=st_c.n_selected + 1,
            )

        def cond(st):
            return jnp.any(jax.vmap(cond_one)(st, ops))

        def body(st):
            go = jax.vmap(cond_one)(st, ops)                    # [C]
            if cfg.mode == "spark" or not cfg.ladder or len(cfg.rungs) == 1:
                thetas = eval_rung(cfg.n_bins, st)
            else:
                # shared rung across the grid: smallest rung covering
                # max_c(K_c)·V, picked OUTSIDE the vmap so the switch stays
                # a one-branch switch (a vmapped switch over per-config
                # rungs would lower to a select executing every branch).
                # Bit-safe only for sweep_xla (factory-enforced): each
                # config's thetas are invariant to any rung ≥ its own K·V.
                thetas = jax.lax.switch(
                    _rung_index(cfg, jnp.max(st.k)),
                    [partial(eval_rung, nb) for nb in cfg.rungs], st)
            new = jax.vmap(body_one)(st, ops, thetas)

            # freeze configs whose cond is already false: conds are monotone
            # (a frozen config stays frozen), so the loop runs max_c(nsel_c)
            # bodies and every config's trajectory is exactly its sequential
            # one
            def gate(old, upd):
                g = go.reshape(go.shape + (1,) * (upd.ndim - 1))
                return jnp.where(g, upd, old)

            return jax.tree_util.tree_map(gate, st, new)

        return jax.lax.while_loop(cond, body, st)

    return run


def init_ensemble_state(cap: int, n_attrs: int, valid, n_cfgs: int) -> SelectionState:
    """Fresh stacked state: :func:`init_state` broadcast to a leading [C]."""
    st = init_state(cap, n_attrs, valid)
    return jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(leaf, (n_cfgs,) + leaf.shape), st)


def run_ensemble(runner, cap: int, n_attrs: int, valid, x, d,
                 ops: EnsembleOperands):
    """Init stacked state → one while_loop dispatch → final stacked state.

    Returns ``(final_state, loop_s)``; unpack per config with
    :func:`unpack_ensemble_result`.
    """
    import time

    traces_before = _jit_cache_size(runner)
    t0 = time.perf_counter()
    with obs.span("engine.dispatch_ensemble", configs=ops.n_cfgs,
                  n_attrs=n_attrs, cap=cap) as sp:
        st = init_ensemble_state(cap, n_attrs, valid, ops.n_cfgs)
        fin = jax.block_until_ready(runner(st, x, d, ops))
        traces_after = _jit_cache_size(runner)
        sp.set(compiled=(traces_before is not None
                         and traces_after is not None
                         and traces_after > traces_before))
    obs.counter("plar_engine_ensemble_runs_total",
                "stacked-engine dispatches completed").inc()
    return fin, time.perf_counter() - t0


def unpack_ensemble_result(fin: SelectionState, core_counts):
    """Stacked final state → per-config (reduct, theta_history, iterations,
    n_evals) — one device→host transfer for the whole grid."""
    order = np.asarray(fin.order)
    hist = np.asarray(fin.theta_history)
    nsel = np.asarray(fin.n_selected)
    n_attrs = fin.remaining.shape[-1]
    out = []
    for c, cc in enumerate(core_counts):
        ns = int(nsel[c])
        reduct = [int(a) for a in order[c, :ns]]
        h = [float(t) for t in hist[c, :ns]]
        iters = ns - int(cc)
        out.append((reduct, h, iters, iters * n_attrs))
    return out
