"""GrC-based initialization: the granularity representation of a decision table.

The paper (PLAR §3.3) converts the decision table ``S = (U, C ∪ D)`` into the
granularity representation ``G^(C∪D) = {(E⃗, |E|)}`` — distinct rows with
multiplicities — once, and caches it in distributed memory.  All later work
(evaluating ``Θ(D|B)`` for candidate subsets ``B``) operates on granules.

TPU/XLA adaptation (static shapes, no host round-trips):

* Rows are fingerprinted with a *linear* polynomial hash
  ``h(row) = Σ_j mix32(x[:, j] ⊕ seed_j) · m_j (mod 2³²)`` with two independent
  seeds.  Linearity lets us add/remove one column's contribution in O(1) — used
  by the attribute-core computation, where the paper re-maps from scratch.
* "unique rows" is a lexsort + adjacent-compare + ``segment_sum`` — the
  reduceByKey of the GrC build.  ``exact=True`` sorts the actual columns
  (collision-free); ``exact=False`` sorts the 64-bit fingerprint pair only
  (collision probability < G²/2⁻⁶⁴, used for very wide tables such as SDSS).
* The output table is padded to a static capacity with a validity mask; ``num``
  carries the live granule count.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

__all__ = [
    "Granularity",
    "build_granularity",
    "build_granularity_streaming",
    "fold_chunk",
    "merge_granularity",
    "with_capacity",
    "next_pow2",
    "column_terms",
    "dyn_column_terms",
    "row_fingerprints",
    "regranulate",
    "pack_ids",
    "compact_ids",
    "project_columns",
]

_GOLDEN = np.uint32(0x9E3779B9)


def _mix32(v: jnp.ndarray) -> jnp.ndarray:
    """SplitMix-style 32-bit finalizer (uint32 in, uint32 out)."""
    v = v.astype(jnp.uint32)
    v = v ^ (v >> 16)
    v = v * jnp.uint32(0x7FEB352D)
    v = v ^ (v >> 15)
    v = v * jnp.uint32(0x846CA68B)
    v = v ^ (v >> 16)
    return v


def _column_seeds(n_cols: int, seed: int) -> np.ndarray:
    """Deterministic per-column (seed, multiplier) pairs, host-side."""
    idx = np.arange(n_cols, dtype=np.uint64)
    mask = np.uint64(0xFFFFFFFF)
    col_seed = (idx * np.uint64(_GOLDEN) + np.uint64(seed) * np.uint64(0x85EBCA6B)) & mask
    mult = (((col_seed ^ (col_seed >> np.uint64(13))) * np.uint64(0xC2B2AE35)) & mask) | np.uint64(1)
    return np.stack([col_seed, mult], axis=0).astype(np.uint32)  # [2, n_cols]


def dyn_column_terms(x: jnp.ndarray, col: jnp.ndarray, seed: int) -> jnp.ndarray:
    """:func:`column_terms` for a *traced* column index (dynamic gather)."""
    seeds = jnp.asarray(_column_seeds(x.shape[1], seed))
    return _mix32(x[:, col].astype(jnp.uint32) ^ seeds[0, col]) * seeds[1, col]


def column_terms(x_col: jnp.ndarray, col_index: int, n_cols: int, seed: int) -> jnp.ndarray:
    """Hash term contributed by one column: mix32(v ⊕ seed_j) · m_j  (uint32).

    ``row_fingerprints(x) == Σ_j column_terms(x[:, j], j)`` — the linear-sketch
    property used to *remove* a column from a fingerprint in O(1).
    """
    seeds = _column_seeds(n_cols, seed)
    cs = jnp.uint32(seeds[0, col_index])
    mult = jnp.uint32(seeds[1, col_index])
    return _mix32(x_col.astype(jnp.uint32) ^ cs) * mult


def row_fingerprints(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    """Linear polynomial fingerprint of each row (uint32), vectorized over columns."""
    n_cols = x.shape[-1]
    seeds = _column_seeds(n_cols, seed)
    cs = jnp.asarray(seeds[0])  # [A]
    mult = jnp.asarray(seeds[1])  # [A]
    terms = _mix32(x.astype(jnp.uint32) ^ cs[None, :]) * mult[None, :]
    return terms.sum(axis=-1, dtype=jnp.uint32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Granularity:
    """Padded granularity representation ``G^(A)`` of a decision table.

    Attributes:
      x:     [cap, A] int32 — representative feature vector of each granule.
      d:     [cap]    int32 — decision label of each granule.
      w:     [cap]    int32 — multiplicity |E| (0 for padding slots).
      valid: [cap]    bool  — slot liveness mask.
      num:   scalar  int32 — number of live granules G.
      n_total: scalar int32 — |U| = Σ w.
    Static metadata (aux): n_attrs, n_dec (m), v_max (max categorical code + 1).
    """

    x: jnp.ndarray
    d: jnp.ndarray
    w: jnp.ndarray
    valid: jnp.ndarray
    num: jnp.ndarray
    n_total: jnp.ndarray
    n_attrs: int
    n_dec: int
    v_max: int

    @property
    def capacity(self) -> int:
        return self.x.shape[0]

    def tree_flatten(self):
        children = (self.x, self.d, self.w, self.valid, self.num, self.n_total)
        aux = (self.n_attrs, self.n_dec, self.v_max)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def _sort_keys(
    x: jnp.ndarray,
    d: jnp.ndarray,
    valid: jnp.ndarray,
    exact: bool,
    seed: int,
):
    """Sort keys grouping equal rows; invalid rows sort to the end."""
    sentinel = jnp.uint32(0xFFFFFFFF)
    h1 = jnp.where(valid, row_fingerprints(x, seed), sentinel)
    h2 = jnp.where(valid, row_fingerprints(x, seed + 7919), sentinel)
    du = jnp.where(valid, d.astype(jnp.uint32), sentinel)
    if exact:
        # Primary: fingerprints (cheap bucketing); within buckets, the actual
        # columns break hash collisions, making the grouping collision-free.
        cols = [jnp.where(valid, x[:, j].astype(jnp.uint32), sentinel) for j in range(x.shape[1])]
        keys = tuple(cols[::-1]) + (du, h2, h1)  # last key = primary
    else:
        keys = (du, h2, h1)
    order = jnp.lexsort(keys)
    return order, (h1, h2, du)


def _boundaries(
    x_s: jnp.ndarray,
    d_s: jnp.ndarray,
    valid_s: jnp.ndarray,
    hashes_s: Sequence[jnp.ndarray],
    exact: bool,
) -> jnp.ndarray:
    if exact:
        neq = (x_s[1:] != x_s[:-1]).any(axis=-1) | (d_s[1:] != d_s[:-1])
    else:
        neq = jnp.zeros(x_s.shape[0] - 1, dtype=bool)
        for h in hashes_s:
            neq = neq | (h[1:] != h[:-1])
    first = jnp.ones((1,), dtype=bool)
    b = jnp.concatenate([first, neq])
    return b & valid_s


@partial(jax.jit, static_argnames=("n_dec", "v_max", "exact", "seed", "capacity"))
def build_granularity(
    x: jnp.ndarray,
    d: jnp.ndarray,
    *,
    n_dec: int,
    v_max: int,
    w: Optional[jnp.ndarray] = None,
    valid: Optional[jnp.ndarray] = None,
    exact: bool = True,
    seed: int = 0,
    capacity: Optional[int] = None,
) -> Granularity:
    """GrC initialization: build ``G^(C∪D)`` from (possibly pre-weighted) rows.

    Accepting input weights makes this the shard-merge step too: re-granulating
    a concatenation of per-shard granule tables merges duplicate keys exactly
    (the reduceByKey of the distributed build).
    """
    n, n_attrs = x.shape
    cap = capacity or n
    if w is None:
        w = jnp.ones((n,), dtype=jnp.int32)
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    w = jnp.where(valid, w, 0)

    order, _ = _sort_keys(x, d, valid, exact, seed)
    x_s, d_s, w_s, valid_s = x[order], d[order], w[order], valid[order]
    h1s = row_fingerprints(x_s, seed)
    h2s = row_fingerprints(x_s, seed + 7919)
    b = _boundaries(x_s, d_s, valid_s, (h1s, h2s, d_s.astype(jnp.uint32)), exact)

    ids = jnp.cumsum(b.astype(jnp.int32)) - 1  # [-1 for leading invalid-only case]
    ids = jnp.clip(ids, 0, cap - 1)
    num = b.sum().astype(jnp.int32)

    # Invalid (padding) rows scatter out of bounds → dropped, never clipped
    # into the last live segment where their zero rows would overwrite its
    # representative (they sort after every valid row, so they'd all land
    # on id num-1 otherwise).
    ids_w = jnp.where(valid_s, ids, cap)
    w_g = jax.ops.segment_sum(w_s, ids_w, num_segments=cap)
    # Representative rows: every row in a segment shares the key, any write wins.
    x_g = jnp.zeros((cap, n_attrs), x.dtype).at[ids_w].set(x_s)
    d_g = jnp.zeros((cap,), d.dtype).at[ids_w].set(d_s)
    valid_g = jnp.arange(cap) < num

    return Granularity(
        x=x_g,
        d=d_g,
        w=jnp.where(valid_g, w_g, 0),
        valid=valid_g,
        num=num,
        n_total=w.sum().astype(jnp.int32),
        n_attrs=n_attrs,
        n_dec=n_dec,
        v_max=v_max,
    )


def next_pow2(v: int) -> int:
    """Smallest power of two ≥ v (1 for v ≤ 1)."""
    return 1 << max(0, (int(v) - 1)).bit_length()


def with_capacity(gran: Granularity, capacity: int) -> Granularity:
    """Re-pad a *front-packed* granularity (live slots first, the layout
    :func:`build_granularity` emits) to a new static capacity.

    Shrinking below the live count would silently drop granules, so it
    raises; growing appends zero-weight padding.  One host sync on ``num``
    when shrinking — the Spark analogue is the driver's ``count()`` action.
    """
    cap = gran.capacity
    if capacity == cap:
        return gran
    if capacity < cap:
        if int(gran.num) > capacity:
            raise ValueError(
                f"capacity {capacity} < live granule count {int(gran.num)}")
        if int(gran.valid[:capacity].sum()) != int(gran.num):
            raise ValueError(
                "granularity is not front-packed: live slots extend past the "
                f"requested capacity {capacity}")
        x = gran.x[:capacity]
        d = gran.d[:capacity]
        w = gran.w[:capacity]
        valid = gran.valid[:capacity]
    else:
        pad = capacity - cap
        x = jnp.concatenate([gran.x, jnp.zeros((pad, gran.n_attrs), gran.x.dtype)])
        d = jnp.concatenate([gran.d, jnp.zeros((pad,), gran.d.dtype)])
        w = jnp.concatenate([gran.w, jnp.zeros((pad,), gran.w.dtype)])
        valid = jnp.concatenate([gran.valid, jnp.zeros((pad,), bool)])
    return Granularity(
        x=x, d=d, w=w, valid=valid, num=gran.num, n_total=gran.n_total,
        n_attrs=gran.n_attrs, n_dec=gran.n_dec, v_max=gran.v_max,
    )


def merge_granularity(a: Granularity, b: Granularity, *, exact: bool = True,
                      seed: int = 0, capacity: Optional[int] = None) -> Granularity:
    """Monoid merge: ``G^(A∪B) = G^(A) ⊕ G^(B)`` — the chunked reduceByKey.

    Concatenates the two padded tables and re-granulates with the input
    weights (concat → sort → adjacent-compare → ``segment_sum``), so
    duplicate keys across the operands merge weight-additively.  The merge is
    associative and commutative up to padding: the output's live prefix is
    the *globally sorted* distinct-key table, independent of operand order
    or how rows were split between operands.

    Capacity-doubling policy: the result capacity starts at
    ``next_pow2(max(capacity or 0, a.capacity, b.capacity))`` and doubles
    (via ``next_pow2`` of the true distinct count) whenever the live keys
    overflow it.  The overflow check is one host sync of ``num`` — ``num``
    counts sort boundaries *before* the scatter clips, so a clipped build is
    always detected and rebuilt; capacities stay powers of two so the
    streaming fold compiles O(log G) variants, not one per merge.
    """
    if (a.n_attrs, a.n_dec, a.v_max) != (b.n_attrs, b.n_dec, b.v_max):
        raise ValueError(
            "merge_granularity operands disagree on static metadata: "
            f"{(a.n_attrs, a.n_dec, a.v_max)} vs {(b.n_attrs, b.n_dec, b.v_max)}")
    x = jnp.concatenate([a.x, b.x])
    d = jnp.concatenate([a.d, b.d])
    w = jnp.concatenate([a.w, b.w])
    valid = jnp.concatenate([a.valid, b.valid])
    cap = next_pow2(max(capacity or 1, a.capacity, b.capacity))
    while True:
        g = build_granularity(
            x, d, n_dec=a.n_dec, v_max=a.v_max, w=w, valid=valid,
            exact=exact, seed=seed, capacity=cap,
        )
        num = int(g.num)
        if num <= cap:
            return g
        cap = next_pow2(num)


def build_granularity_streaming(
    chunks,
    *,
    n_dec: int,
    v_max: int,
    exact: bool = True,
    seed: int = 0,
) -> Granularity:
    """GrC initialization without the whole table: fold :func:`merge_granularity`
    over an iterable of ``(x, d)`` row chunks.

    Each chunk is granulated at its own ``next_pow2`` capacity and merged
    into the accumulator, so peak memory is O(chunk + accumulator capacity)
    — the decision table never exists whole.  Because the merge is a monoid
    and the final fold step re-sorts the full distinct-key set, the live
    prefix of the result is *element-wise identical* to a monolithic
    :func:`build_granularity` over the concatenated rows (only the padded
    capacity may differ); `tests/test_streaming.py` asserts this per
    chunk size.
    """
    acc: Optional[Granularity] = None
    for xc, dc in chunks:
        acc = fold_chunk(acc, xc, dc, n_dec=n_dec, v_max=v_max, exact=exact,
                         seed=seed)
    if acc is None:
        raise ValueError("build_granularity_streaming: no non-empty chunks")
    return acc


def fold_chunk(acc: Optional[Granularity], xc, dc, *, n_dec: int, v_max: int,
               exact: bool = True, seed: int = 0) -> Optional[Granularity]:
    """One step of the streaming fold: granulate a row chunk and merge it.

    The single home of the capacity/shrink policy, shared by the
    single-process and per-data-shard (``distributed``) folds: both operands
    shrink to their live counts before the merge — on redundant tables a
    chunk's granularity is far smaller than the chunk, and the merge sort
    should pay for live keys, not padding.  The host syncs are the per-merge
    count() the policy already requires.
    """
    xc = jnp.asarray(xc, jnp.int32)
    dc = jnp.asarray(dc, jnp.int32)
    if xc.shape[0] == 0:
        return acc
    with obs.span("pipeline.fold_chunk", rows=int(xc.shape[0]),
                  fresh=acc is None) as sp:
        g = build_granularity(
            xc, dc, n_dec=n_dec, v_max=v_max, exact=exact, seed=seed,
            capacity=next_pow2(xc.shape[0]),
        )
        g = with_capacity(g, next_pow2(max(int(g.num), 1)))
        if acc is None:
            sp.set(granules=int(g.num))
            return g
        acc = merge_granularity(acc, g, exact=exact, seed=seed)
        acc = with_capacity(acc, next_pow2(max(int(acc.num), 1)))
        sp.set(granules=int(acc.num))
    return acc


def regranulate(gran: Granularity, cols: jnp.ndarray, *, exact: bool = True, seed: int = 0) -> Granularity:
    """Coarsen ``G^(C∪D)`` onto the column subset ``cols`` (Corollary 3.3).

    ``cols`` is a static index array; the result's ``x`` holds only those columns.
    """
    x_sub = gran.x[:, cols]
    return build_granularity(
        x_sub,
        gran.d,
        n_dec=gran.n_dec,
        v_max=gran.v_max,
        w=gran.w,
        valid=gran.valid,
        exact=exact,
        seed=seed,
        capacity=gran.capacity,
    )


def project_columns(gran: Granularity, cols: Sequence[int]) -> Granularity:
    """Alias of :func:`regranulate` taking a Python column list."""
    return regranulate(gran, jnp.asarray(list(cols), dtype=jnp.int32))


def pack_ids(r_ids: jnp.ndarray, x_col: jnp.ndarray, v_max: int) -> jnp.ndarray:
    """Refine class ids with one attribute: ``p = r·V + v``  (Corollary 3.4).

    Exact: two granules share ``p`` iff they share both the current class and
    the candidate attribute value.  Range: ``[0, K·V)``.
    """
    return r_ids * v_max + x_col


def presence_bitmap(p: jnp.ndarray, valid: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """0/1 bitmap of which packed ids occur among valid slots (int32 [n_bins])."""
    p_safe = jnp.where(valid, p, 0)
    return jnp.zeros((n_bins,), jnp.int32).at[p_safe].max(valid.astype(jnp.int32))


def ids_from_presence(presence: jnp.ndarray, p: jnp.ndarray, valid: jnp.ndarray):
    """Dense renumbering given a (possibly psum-merged) presence bitmap."""
    presence = (presence > 0).astype(jnp.int32)
    rank = jnp.cumsum(presence) - presence  # exclusive prefix count
    p_safe = jnp.where(valid, p, 0)
    new_ids = jnp.where(valid, rank[p_safe], 0)
    return new_ids, presence.sum()


@partial(jax.jit, static_argnames=("n_bins",))
def compact_ids(p: jnp.ndarray, valid: jnp.ndarray, n_bins: int):
    """Renumber sparse packed ids to dense ``[0, K_new)`` via presence bitmap.

    Sort-free: presence = scatter-max of validity, rank = cumsum.  The bitmap
    commutes with ``psum`` over data shards, so all shards agree on the global
    numbering without a gather (§3.1 of DESIGN.md).
    """
    presence = presence_bitmap(p, valid, n_bins)
    new_ids, k_new = ids_from_presence(presence, p, valid)
    return new_ids, k_new, presence
