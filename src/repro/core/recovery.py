"""Shard lineage + re-fold recovery: the RDD resilience story done natively.

The paper's PLAR framework gets fault tolerance for free from Spark: a lost
RDD partition is *recomputed from its lineage* — the recorded chain of
deterministic transformations that produced it — instead of restarting the
job (arXiv 1610.01807 §IV).  This module is the native equivalent for the
GrC granularity build (DESIGN.md §3.10):

* :class:`ShardLineage` records, per data shard, exactly which
  ``GranuleSource`` chunk ranges folded into it.  Because a conforming
  source is a pure function of ``(seed, step)`` (data/pipeline.py), the
  lineage is a complete recipe: no raw rows need to be retained.
* :func:`build_sharded` is the lineage-recording twin of the mesh driver's
  per-shard streaming fold (core/distributed.py): chunk ``i`` is sliced
  ``[s·n/S, (s+1)·n/S)`` per shard and folded through the §3.6 monoid
  merge, and the slice bounds are recorded as the shard's lineage.
* :func:`refold_shard` replays ONE shard's lineage — the same
  ``fold_chunk`` calls on the same rows, hitting the same jitted builds —
  so the recovered shard granularity is **bitwise identical** to the lost
  one, and re-merging it with the survivors reproduces the unfailed merged
  granularity (and therefore byte-identical downstream reducts and Θ
  histories; tests/test_recovery.py).

Recovery cost model: a shard death costs ``O(rows/S)`` re-fold work plus
one (S-way) re-merge, versus ``O(rows)`` for a from-scratch rebuild — the
re-fold-one-shard ≪ full-rebuild gap measured in benchmarks/chaos_bench.py.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

from .granularity import (
    Granularity,
    fold_chunk,
    merge_granularity,
    next_pow2,
    with_capacity,
)

__all__ = [
    "ChunkSlice",
    "ShardLineage",
    "ShardedBuild",
    "build_sharded",
    "refold_shard",
    "merge_shards",
    "recover",
]


@dataclasses.dataclass(frozen=True)
class ChunkSlice:
    """Rows ``[lo, hi)`` of ``source.chunk(step, chunk_rows)``."""

    step: int
    lo: int
    hi: int


@dataclasses.dataclass(frozen=True)
class ShardLineage:
    """The complete, replayable recipe for one data shard's granularity.

    ``slices`` lists the chunk ranges (in fold order) that produced the
    shard; the remaining fields pin the fold's static knobs so a replay
    compiles and executes the *same* jitted builds.  Serializes to plain
    JSON (:meth:`to_dict`) so checkpoints can persist it as metadata.
    """

    shard_index: int
    n_shards: int
    chunk_rows: int
    n_dec: int
    v_max: int
    exact: bool
    slices: Tuple[ChunkSlice, ...]

    def to_dict(self) -> dict:
        return {
            "shard_index": self.shard_index,
            "n_shards": self.n_shards,
            "chunk_rows": self.chunk_rows,
            "n_dec": self.n_dec,
            "v_max": self.v_max,
            "exact": self.exact,
            "slices": [[s.step, s.lo, s.hi] for s in self.slices],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ShardLineage":
        return cls(
            shard_index=int(d["shard_index"]),
            n_shards=int(d["n_shards"]),
            chunk_rows=int(d["chunk_rows"]),
            n_dec=int(d["n_dec"]),
            v_max=int(d["v_max"]),
            exact=bool(d["exact"]),
            slices=tuple(ChunkSlice(int(a), int(b), int(c))
                         for a, b, c in d["slices"]),
        )


@dataclasses.dataclass
class ShardedBuild:
    """A lineage-tracked sharded granularity build.

    ``shards[s]`` is shard ``s``'s granularity (``None`` marks a *lost*
    shard — dropped by a fault); ``lineages[s]`` is its replay recipe;
    ``merged`` is the global granularity (the reduction input).
    """

    shards: List[Optional[Granularity]]
    lineages: List[ShardLineage]
    merged: Granularity

    @property
    def n_shards(self) -> int:
        return len(self.lineages)

    @property
    def lost(self) -> List[int]:
        return [s for s, g in enumerate(self.shards) if g is None]

    def drop(self, shard_index: int) -> None:
        """Simulate shard loss (a died host / evicted device buffer)."""
        if not 0 <= shard_index < len(self.shards):
            raise ValueError(
                f"shard {shard_index} out of range [0, {len(self.shards)})")
        self.shards[shard_index] = None


def _shrink(g: Granularity) -> Granularity:
    """The reduction drivers' capacity policy (next_pow2 of live, floor 16)
    so a merged-from-shards granularity lands on the same static shapes —
    and therefore the same engine compile — as any other build path."""
    return with_capacity(g, next_pow2(max(int(g.num), 16)))


def build_sharded(source, n_shards: int, *, chunk_rows: int = 65536,
                  exact: bool = True, fault_plan=None) -> ShardedBuild:
    """Streaming sharded GrC build with lineage recording.

    Mirrors the mesh driver's fold exactly (chunks iterate on the outside,
    shard ``s`` folds rows ``[s·n/S, (s+1)·n/S)`` of every chunk), but each
    shard additionally records its :class:`ChunkSlice` list.  A
    ``fault_plan`` with ``shard_drop`` faults drops the indicated shard
    *after* the fold — the moment a real host would die holding its
    granularity — leaving its lineage behind for :func:`recover`.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be ≥ 1, got {n_shards}")
    accs: List[Optional[Granularity]] = [None] * n_shards
    slices: List[List[ChunkSlice]] = [[] for _ in range(n_shards)]
    with obs.span("recovery.build_sharded", n_shards=n_shards,
                  chunks=source.n_chunks(chunk_rows)):
        _build_folds(source, n_shards, chunk_rows, exact, accs, slices)
    if any(g is None for g in accs):
        raise ValueError("source yielded no rows for at least one data shard")
    lineages = [
        ShardLineage(shard_index=s, n_shards=n_shards, chunk_rows=chunk_rows,
                     n_dec=source.n_dec, v_max=source.v_max, exact=exact,
                     slices=tuple(slices[s]))
        for s in range(n_shards)
    ]
    merged = merge_shards(accs, exact=exact)
    build = ShardedBuild(shards=accs, lineages=lineages, merged=merged)
    if fault_plan is not None:
        spec = fault_plan.fire("shard_drop")
        if spec is not None:
            build.drop(spec.arg if spec.arg is not None else 0)
    return build


def _build_folds(source, n_shards: int, chunk_rows: int, exact: bool,
                 accs: List[Optional[Granularity]],
                 slices: List[List[ChunkSlice]]) -> None:
    for i in range(source.n_chunks(chunk_rows)):
        xc, dc = source.chunk(i, chunk_rows)
        n = xc.shape[0]
        for s in range(n_shards):
            lo, hi = s * n // n_shards, (s + 1) * n // n_shards
            if hi > lo:
                slices[s].append(ChunkSlice(i, lo, hi))
                accs[s] = fold_chunk(accs[s], xc[lo:hi], dc[lo:hi],
                                     n_dec=source.n_dec, v_max=source.v_max,
                                     exact=exact)


def refold_shard(source, lineage: ShardLineage) -> Granularity:
    """Replay one shard's lineage: re-fold exactly the recorded chunk
    ranges.  Pure-``(seed, step)`` sources re-materialize the same rows, the
    fold hits the same jitted builds with the same static shapes, so the
    result is bitwise identical to the lost shard's granularity."""
    acc: Optional[Granularity] = None
    with obs.span("recovery.refold_shard", shard=lineage.shard_index,
                  slices=len(lineage.slices)):
        for sl in lineage.slices:
            xc, dc = source.chunk(sl.step, lineage.chunk_rows)
            acc = fold_chunk(acc, xc[sl.lo:sl.hi], dc[sl.lo:sl.hi],
                             n_dec=lineage.n_dec, v_max=lineage.v_max,
                             exact=lineage.exact)
    obs.counter("plar_recovery_refolds_total",
                "shard lineages replayed by refold_shard").inc()
    if acc is None:
        raise ValueError(
            f"shard {lineage.shard_index} lineage is empty — nothing to refold")
    return acc


def merge_shards(shards: Sequence[Granularity], *,
                 exact: bool = True) -> Granularity:
    """Fold the per-shard granularities into the global one (left fold of
    the §3.6 monoid merge) and land on the drivers' capacity policy.  The
    merge's final re-sort makes the live prefix the globally sorted
    distinct-key table — independent of how rows were sharded — so the
    result is element-wise identical to a monolithic build's live prefix."""
    if not shards or any(g is None for g in shards):
        raise ValueError("merge_shards requires every shard present "
                         "(recover lost shards first)")
    acc = shards[0]
    for g in shards[1:]:
        acc = merge_granularity(acc, g, exact=exact)
    return _shrink(acc)


def recover(build: ShardedBuild, source, *, fault_plan=None) -> List[int]:
    """Rebuild every lost shard from its lineage and re-merge, in place.

    Returns the list of recovered shard indices.  Only the lost shards are
    re-folded — survivors are reused as-is — so recovery costs
    ``O(lost_rows + merge)``, not a full rebuild.  The recovered ``merged``
    granularity is bitwise identical to the unfailed build's (the refold is
    a deterministic replay; asserted in tests/test_recovery.py), so every
    downstream reduct and Θ history is byte-identical too.

    A ``fault_plan`` with further ``shard_drop`` faults can kill a shard
    *during* recovery (the re-folded replacement is dropped as it lands);
    the loop re-checks and re-folds until no shard is lost, so cascading
    failures converge as long as the plan is finite.
    """
    recovered: List[int] = []
    with obs.span("recovery.recover", lost=len(build.lost)) as sp:
        while build.lost:
            for s in list(build.lost):
                g = refold_shard(source, build.lineages[s])
                build.shards[s] = g
                recovered.append(s)
                if fault_plan is not None:
                    spec = fault_plan.fire("shard_drop")
                    if spec is not None:
                        build.drop(spec.arg if spec.arg is not None else s)
        build.merged = merge_shards(build.shards,
                                    exact=build.lineages[0].exact)
        sp.set(recovered=len(recovered))
    obs.counter("plar_recovery_recovers_total",
                "recover() calls that re-merged a sharded build").inc()
    return recovered
