r"""The four significance measures (PR, SCE, LCE, CCE) of PLAR Table 1/2.

Every measure factors over equivalence classes (paper §3.2):

    Θ(D|B) = Σ_i θ(S_i),     S_i = (E_i, D)

and every θ needs only the *contingency row* of the class: the counts
``|D_ij| = |E_i ∩ D_j|`` (and their sum ``|E_i|``).  This module computes θ/Θ
from a contingency table ``cont[..., K, m]`` (float32 counts, padding rows are
all-zero and contribute exactly 0 to every measure).

Sign convention (paper, below Table 1): ``Θ_PR(D|B) ≝ -γ_B(D)``, so for all
four measures *smaller Θ is better* and both significances are non-negative:

    Sig_inner(a, B) = Θ(D|B\{a}) - Θ(D|B)
    Sig_outer(a, B) = Θ(D|B)     - Θ(D|B∪{a})
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

__all__ = [
    "MEASURES", "RAW_ROWS", "theta_rows", "theta_scale", "evaluate",
    "sig_inner", "sig_outer", "argmin_with_ties", "f32_threshold",
]


def _row_sums(cont: jnp.ndarray) -> jnp.ndarray:
    return cont.sum(axis=-1)


# Every measure factors as  θ(S_i) = scale(n) · θ'(row_i)  with θ' depending
# on the counts only.  The split is load-bearing: the fused Pallas kernel
# (DESIGN.md §5.2) runs θ' as its epilogue with no scalar operands, and the
# fused distributed schedule psums raw partials before scaling (linearity).
# θ' of an all-zero row is exactly 0 for all four measures.


def _rows_pr(cont: jnp.ndarray) -> jnp.ndarray:
    """θ'_PR = |E_i|·1[|E_i/D|=1]  (class is pure → counts toward POS)."""
    e = _row_sums(cont)
    pure = (cont.max(axis=-1) == e) & (e > 0)
    return e * pure.astype(cont.dtype)


def _rows_sce(cont: jnp.ndarray) -> jnp.ndarray:
    """θ'_SCE = Σ_j |D_ij|·log(|D_ij|/|E_i|), with 0·log0 = 0."""
    e = _row_sums(cont)
    safe_c = jnp.where(cont > 0, cont, 1.0)
    safe_e = jnp.where(e > 0, e, 1.0)
    logs = jnp.log(safe_c) - jnp.log(safe_e)[..., None]
    return jnp.where(cont > 0, cont * logs, 0.0).sum(axis=-1)


def _rows_lce(cont: jnp.ndarray) -> jnp.ndarray:
    """θ'_LCE = Σ_j |D_ij|·(|E_i| - |D_ij|)."""
    e = _row_sums(cont)
    return (cont * (e[..., None] - cont)).sum(axis=-1)


def _rows_cce(cont: jnp.ndarray) -> jnp.ndarray:
    """θ'_CCE = |E_i|²(|E_i|-1) - Σ_j |D_ij|²(|D_ij|-1).

    Follows Definition 2.9 literally: (|E|/n)·C²_|E|/C²_n = e²(e-1)/(n²(n-1))
    after scaling.  (The paper's Table 2 denominator ``|U|·C²_|U|`` is 2×
    this — a factor that cancels in all significance comparisons; we keep the
    Def-2.9 scale so the brute-force oracle and the decomposed path agree
    bit-for-bit.)
    """
    e = _row_sums(cont)
    pos = e * e * jnp.maximum(e - 1.0, 0.0)
    neg = (cont * cont * jnp.maximum(cont - 1.0, 0.0)).sum(axis=-1)
    return pos - neg


RAW_ROWS: Dict[str, Callable[[jnp.ndarray], jnp.ndarray]] = {
    "PR": _rows_pr,
    "SCE": _rows_sce,
    "LCE": _rows_lce,
    "CCE": _rows_cce,
}


def theta_scale(delta: str, raw: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Normalize unnormalized θ' values: the sign/|U| factor of each measure.

    Linear in ``raw``, so it commutes with every summation — per-row, per
    bin-tile, and per-shard raw partials may be summed/psum'd first and
    scaled once.
    """
    n = jnp.asarray(n, jnp.float32)
    if delta in ("PR", "SCE"):
        return -raw / n
    if delta == "LCE":
        return raw / (n * n)
    if delta == "CCE":
        return raw / jnp.maximum(n * n * (n - 1.0), 1.0)
    raise ValueError(f"unknown measure: {delta}")


def _make_theta(delta: str):
    def theta(cont: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
        return theta_scale(delta, RAW_ROWS[delta](cont), n)

    theta.__name__ = f"_theta_{delta.lower()}"
    theta.__doc__ = f"θ_{delta}(S_i) = theta_scale({delta!r}, θ'_{delta}, n)."
    return theta


MEASURES: Dict[str, Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]] = {
    delta: _make_theta(delta) for delta in RAW_ROWS
}


def theta_rows(delta: str, cont: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Per-class sub-evaluation θ(S_i): cont [..., K, m] → [..., K]."""
    cont = cont.astype(jnp.float32)
    n = jnp.asarray(n, jnp.float32)
    return MEASURES[delta](cont, n)


def evaluate(delta: str, cont: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Θ(D|B) = Σ_i θ(S_i): cont [..., K, m] → [...] (the paper's sum() action).

    PR is computed as a single integer-exact count sum followed by one
    division, so Θ_PR is bit-identical across summation orders (paths/shards)
    whenever |U| < 2²⁴ — which makes tie-breaking deterministic.
    """
    if delta == "PR":
        cont = cont.astype(jnp.float32)
        n = jnp.asarray(n, jnp.float32)
        e = cont.sum(axis=-1)
        pure = (cont.max(axis=-1) == e) & (e > 0)
        pos = (e * pure.astype(cont.dtype)).sum(axis=-1)
        return -pos / n
    return theta_rows(delta, cont, n).sum(axis=-1)


def f32_threshold(base, tol) -> float:
    """``base + tol`` rounded exactly as the device engine's f32 arithmetic.

    Every host-side comparison that must agree with an in-loop f32 compare
    (the argmin tie band, both drivers' stopping thresholds) goes through
    this one helper: the engine='host' vs 'device' bit-identical contract
    rests on the threshold arithmetic matching, so it must not be re-derived
    ad hoc at call sites.
    """
    import numpy as np

    return float(np.float32(np.float32(base) + np.float32(tol)))


def argmin_with_ties(values, tol: float = 1e-5) -> int:
    """Lowest index whose value is within ``tol`` of the minimum.

    Greedy selection must break Θ ties identically across float32 summation
    orders (incremental vs spark vs distributed) and vs the float64 oracle;
    a tolerance band + lowest-index rule does that.  The band edge
    ``min + tol`` is :func:`f32_threshold` to mirror the device engine's
    in-loop argmin bit-for-bit (engine.py pick_greedy): the candidate values
    are f32-representable on every path, so equal thresholds ⇒ equal bands.
    """
    import numpy as np

    v = np.asarray(values, np.float64)
    return int(np.nonzero(v <= f32_threshold(v.min(), tol))[0][0])


def sig_inner(theta_without: jnp.ndarray, theta_with: jnp.ndarray) -> jnp.ndarray:
    r"""Sig^inner = Θ(D|B\{a}) - Θ(D|B)."""
    return theta_without - theta_with


def sig_outer(theta_base: jnp.ndarray, theta_added: jnp.ndarray) -> jnp.ndarray:
    """Sig^outer = Θ(D|B) - Θ(D|B∪{a})."""
    return theta_base - theta_added
