"""Brute-force numpy oracle for the four measures, straight from the paper.

Implements Definitions 2.3–2.10 literally (explicit partitions as Python sets
of row indices), with none of the GrC/decomposition machinery.  Tests validate
every optimized path against this.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["partition", "theta_oracle", "reduct_oracle"]


def partition(x: np.ndarray, cols: Sequence[int]) -> List[np.ndarray]:
    """U/B as a list of row-index arrays (equivalence classes)."""
    if len(cols) == 0:
        return [np.arange(x.shape[0])]
    keys: Dict[Tuple, List[int]] = {}
    for i, row in enumerate(x[:, list(cols)]):
        keys.setdefault(tuple(row.tolist()), []).append(i)
    return [np.asarray(v) for v in keys.values()]


def theta_oracle(delta: str, x: np.ndarray, d: np.ndarray, cols: Sequence[int]) -> float:
    """Θ(D|B) from the raw definitions (Table 1, with Θ_PR = -γ)."""
    n = x.shape[0]
    classes = partition(x, cols)
    dec_values = np.unique(d)

    if delta == "PR":
        pos = 0
        for e in classes:
            if len(np.unique(d[e])) == 1:
                pos += len(e)
        return -pos / n

    total = 0.0
    for e in classes:
        ei = len(e)
        counts = np.asarray([(d[e] == dv).sum() for dv in dec_values], np.float64)
        if delta == "SCE":
            p_e = ei / n
            for c in counts:
                if c > 0:
                    total += -p_e * (c / ei) * math.log(c / ei)
        elif delta == "LCE":
            for c in counts:
                total += (c / n) * ((ei - c) / n)
        elif delta == "CCE":
            c2u = n * (n - 1) / 2.0
            term = (ei / n) * (ei * (ei - 1) / 2.0) / c2u
            for c in counts:
                term -= (c / n) * (c * (c - 1) / 2.0) / c2u
            total += term
        else:
            raise ValueError(delta)
    return float(total)


def reduct_oracle(
    delta: str,
    x: np.ndarray,
    d: np.ndarray,
    *,
    eps: float = 0.0,
    tol: float = 1e-6,
    tie_tol: float = 1e-5,
    compute_core: bool = True,
) -> List[int]:
    """Algorithm 1, literally: core via inner sig, then greedy argmin Θ.

    Uses the same tolerance-band lowest-index tie-breaking as the optimized
    implementation (see ``measures.argmin_with_ties``).
    """
    a_all = list(range(x.shape[1]))
    theta_c = theta_oracle(delta, x, d, a_all)
    core = []
    if compute_core:
        for a in a_all:
            rest = [b for b in a_all if b != a]
            if theta_oracle(delta, x, d, rest) - theta_c > eps + tie_tol:
                core.append(a)
    reduct = list(core)
    theta_r = theta_oracle(delta, x, d, reduct) if reduct else float("inf")
    while theta_r > theta_c + tol:
        remaining = [a for a in a_all if a not in reduct]
        if not remaining:
            break
        vals = np.asarray([theta_oracle(delta, x, d, reduct + [a]) for a in remaining])
        best = int(np.nonzero(vals <= vals.min() + tie_tol)[0][0])
        reduct.append(remaining[best])
        theta_r = theta_oracle(delta, x, d, reduct)
    return reduct
