"""PLAR core: GrC granularity representation + unified evaluation + reduction."""
from .engine import (
    DEVICE_BACKENDS,
    SelectionState,
    init_state,
    make_engine_run,
    make_engine_step,
)
from .granularity import (
    Granularity,
    build_granularity,
    compact_ids,
    pack_ids,
    presence_bitmap,
    ids_from_presence,
    regranulate,
    row_fingerprints,
)
from .measures import MEASURES, evaluate, sig_inner, sig_outer, theta_rows
from .plan import (
    candidate_contingency,
    candidate_theta,
    contingency_from_ids,
    ids_by_sort,
    subset_ids,
)
from .reduction import (
    ReductionResult,
    fspa_reduce,
    har_reduce,
    plar_reduce,
    raw_granularity,
)

__all__ = [
    "SelectionState",
    "init_state",
    "make_engine_step",
    "make_engine_run",
    "DEVICE_BACKENDS",
    "Granularity",
    "build_granularity",
    "regranulate",
    "pack_ids",
    "compact_ids",
    "presence_bitmap",
    "ids_from_presence",
    "row_fingerprints",
    "MEASURES",
    "evaluate",
    "theta_rows",
    "sig_inner",
    "sig_outer",
    "candidate_contingency",
    "candidate_theta",
    "contingency_from_ids",
    "ids_by_sort",
    "subset_ids",
    "ReductionResult",
    "plar_reduce",
    "har_reduce",
    "fspa_reduce",
    "raw_granularity",
]
