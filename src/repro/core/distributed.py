"""Distributed PLAR: the paper's MDP (model + data parallelism) on a mesh.

Mapping (DESIGN.md §2) — this *is* the paper's architecture, re-expressed:

    Spark construct                     mesh construct
    -----------------------------------------------------------------
    RDD granule partitions, .cache()    granule arrays sharded over ('pod','data'), HBM-resident
    MP process pool over candidates     candidate axis sharded over 'model'
    map (re-key onto B∪D)               packed ids  p = r·V + x[:,a]   (local)
    reduceByKey                         per-shard contingency + psum over data axes
    driver sum()                        θ rows summed on-shard (redundantly, post-psum)
    driver argmax                       host argmin over the gathered [A] thetas

Three collective schedules for the contingency merge (the §Perf knob):

* ``all_reduce``      — paper-faithful DP: every data shard psums the full
  ``[nc_loc, K·V, m]`` contingency, then reduces θ locally.
* ``reduce_scatter``  — beyond-paper: each shard reduces θ over its *slice*
  of contingency rows (θ is row-separable, Eq. 8!) and a scalar psum merges.
  Halves collective bytes and distributes the θ flops; exact because
  Θ(D|B) = Σ_i θ(S_i) commutes with row partitioning.
* ``fused``           — beyond-paper (DESIGN.md §5.2): the driver re-shards
  granules between iterations so every *current class* lives on one data
  shard.  Then every packed key ``p = r·V + v`` — for every candidate — is
  shard-local, each contingency row is complete on exactly one shard, and a
  shard's fused contingency→Θ partial (θ of a row absent from the shard is
  exactly 0) psums to the exact Θ[c]: cross-device payload O(nc·K·m) → O(nc).
  Iterations whose class sizes don't pack into the per-shard capacity (e.g.
  the first ones, where few large classes exist but K — and so the payload —
  is still small) fall back to ``all_reduce`` transparently.

Correctness notes:
* Per-shard granularity tables may hold duplicate keys across shards — the
  contingency sum is key-additive, so dedup is an optional memory
  optimization (``dedup_granules``), never a correctness requirement.  The
  same property licenses *streaming* ingestion (``source=``): each shard
  folds its slice of every chunk through the granularity monoid merge
  (DESIGN.md §3.6) instead of staging the sharded full table.
* Id compaction uses the presence-bitmap/psum construction whose
  shard-consistency is proven by ``test_compact_ids_commute_with_merge``.
* The attribute core (one-time, paper lines 3–8) is computed on gathered
  granule tables — G ≪ N after GrC init; the greedy hot loop is fully
  distributed.
"""
from __future__ import annotations

import dataclasses
import time
from functools import lru_cache, partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import measures
from ..distributed.api import shard_map
from .engine import (
    SelectionState,
    _Cfg,
    _MeshColl,
    _advance,
    _eval_mesh,
    _make_cond_body,
    _mesh_cand_slab,
    init_state,
    merge_candidate_cont,
    run_engine,
)
from .granularity import (
    Granularity,
    build_granularity,
    fold_chunk,
    next_pow2,
    with_capacity,
)
from .plan import contingency_from_ids, ladder_rungs, rung_for
from .reduction import (
    ReductionResult,
    _check_source_args,
    _core_inner_thetas,
    _materialize,
    _next_pow2,
)


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _n_data_shards(mesh: Mesh) -> int:
    n = 1
    for a in _data_axes(mesh):
        n *= mesh.shape[a]
    return n


def _n_model_shards(mesh: Mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


# ---------------------------------------------------------------------------
# sharded evaluation / advance steps
# ---------------------------------------------------------------------------


def _eval_step(mesh: Mesh, delta: str, n_bins: int, m: int, v_max: int,
               collective: str, *, table_dtype: str = "int32",
               fused_pack: bool = False, backend: str = "segment"):
    """shard_map: candidates over 'model' × granules over data → thetas [A].

    §Perf knobs: ``table_dtype="int8"`` stores the granule table x/d in one
    byte per cell (v_max < 128), quartering the dominant column-read traffic;
    ``fused_pack`` folds the id-packing arithmetic into the per-candidate
    segment expression instead of materializing ``packed [A_loc, G_loc]``;
    ``backend="sweep_xla"`` (DESIGN.md §5.3) is that same fused-pack
    formulation — in this host-dispatched step the candidate set changes
    every iteration, so there is no loop-invariant slab to hoist and the
    column-wise pack is the read-once form.  ``n_bins`` may be any §5.3
    ladder rung ≥ K·V.
    """
    # thin wrapper: defaulted and keyword calls must share one lru entry
    # (the single-compile contract — same normalization as make_engine_run)
    return _eval_step_cached(mesh, delta, n_bins, m, v_max, collective,
                             table_dtype, fused_pack or backend == "sweep_xla")


@lru_cache(maxsize=None)
def _eval_step_cached(mesh, delta, n_bins, m, v_max, collective, table_dtype,
                      fused_pack):
    daxes = _data_axes(mesh)
    nd = _n_data_shards(mesh)

    def local(cand_cols, r_ids, x, d, w, valid, n):
        # cand_cols [A_loc]; r_ids/d/w/valid [G_loc]; x [G_loc, A]
        d32 = d.astype(jnp.int32)
        w_ = jnp.where(valid, w, 0).astype(jnp.float32)

        if collective == "fused":
            # Per-shard fused contingency→Θ partial + scalar psum.  Exact only
            # under the driver's class-grouped placement (module docstring):
            # rows this shard doesn't own are all-zero and contribute θ' = 0.
            # Raw partials are psum'd *before* the single normalization so
            # Θ_PR stays integer-exact across shard counts (tie-breaking
            # determinism, see measures.evaluate).
            from .plan import _theta_fused_xla_raw

            x_cand = jnp.take(x, cand_cols, axis=1).T.astype(jnp.int32)
            packed = r_ids[None, :] * v_max + x_cand          # [A_loc, G_loc]
            raw = _theta_fused_xla_raw(
                delta, packed, d32, w, valid, n_bins=n_bins, m=m)
            return measures.theta_scale(delta, jax.lax.psum(raw, daxes), n)

        if fused_pack:
            def one(col):
                x_col = jnp.take(x, col, axis=1).astype(jnp.int32)
                seg = jnp.where(valid, (r_ids * v_max + x_col) * m + d32,
                                n_bins * m)
                return jax.ops.segment_sum(w_, seg, num_segments=n_bins * m + 1)[:-1]

            cont = jax.vmap(one)(cand_cols).reshape(-1, n_bins, m)
        else:
            x_cand = jnp.take(x, cand_cols, axis=1).T.astype(jnp.int32)
            packed = r_ids[None, :] * v_max + x_cand              # [A_loc, G_loc]

            def one(p):
                seg = jnp.where(valid, p * m + d32, n_bins * m)
                return jax.ops.segment_sum(w_, seg, num_segments=n_bins * m + 1)[:-1]

            cont = jax.vmap(one)(packed).reshape(-1, n_bins, m)   # [A_loc, nb, m]
        # all_reduce / reduce_scatter schedules shared with the device engine
        return merge_candidate_cont(
            delta, cont, n, _MeshColl(daxes, nd, False), collective)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P("model"), P(daxes), P(daxes, None), P(daxes), P(daxes),
                  P(daxes), P()),
        out_specs=P("model"),
        check_vma=False,
    )
    return jax.jit(fn)


@lru_cache(maxsize=None)
def _advance_step(mesh: Mesh, delta: str, n_bins: int, m: int, v_max: int):
    """shard_map: fold the winning attribute into the shared reduction state.

    The pack → presence-psum → rank → contingency body is the engine's
    ``_advance`` with a mesh collective adapter — one copy of the
    shard-consistent compaction logic (DESIGN.md §3.1) for both drivers.
    """
    daxes = _data_axes(mesh)
    nd = _n_data_shards(mesh)
    # only delta/m/v_max and the bin bound matter to _advance; n_bins here is
    # the caller's (possibly bins_for-laddered) bound, always a v_max multiple
    cfg = _Cfg(delta, "incremental", "segment", 0, n_bins // v_max, m, v_max,
               0.0, 0.0, False, 0, 1)

    def local(a_col, r_ids, d, w, valid, n):
        coll = _MeshColl(daxes, nd, False)
        new_ids, k_new, theta, _g_pure = _advance(
            cfg, coll, r_ids, a_col, d, w, valid, n)
        return new_ids, k_new, theta

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(daxes), P(daxes), P(daxes), P(daxes), P(daxes), P()),
        out_specs=(P(daxes), P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)


@lru_cache(maxsize=None)
def _engine_run_mesh(mesh: Mesh, delta: str, n_attrs: int, cap: int, m: int,
                     v_max: int, tol: float, tie_tol: float, collective: str,
                     max_sel: int, backend: str = "segment",
                     ladder: bool = False, selector: str = "analytic"):
    """The device-resident greedy core (engine.py) wrapped in ``shard_map``.

    One jitted while_loop runs the entire reduction: granules stay sharded
    over the data axes, candidates over 'model', and the per-iteration
    contingency merge uses the ``all_reduce``/``reduce_scatter`` collectives
    of :func:`_eval_step` — but with zero host round-trips between
    iterations.  The loop's cond/body are *the same code* the single-process
    driver runs (engine._make_cond_body); only the collective adapter
    differs.  ``n_bins = cap·v_max`` bounds the global packed-id range for
    every iteration, so the loop compiles exactly once.

    The ``fused`` collective is excluded: its class regrouping stages granule
    tables through the host between iterations (module docstring), which is
    fundamentally a host-loop schedule.
    """
    daxes = _data_axes(mesh)
    nd = _n_data_shards(mesh)
    nm = _n_model_shards(mesh)
    has_model = "model" in mesh.axis_names
    # cfg.cap is the *global* capacity: r_ids are globally-dense, so the
    # packed-id bound K·V ≤ cap·V must cover all shards together.  The MP
    # level on the mesh is the 'model' axis itself, so mp_chunk is inert.
    cfg = _Cfg(delta, "incremental", backend, n_attrs, cap, m, v_max,
               tol, tie_tol, False, max_sel, n_attrs, ladder, selector)

    def local(st, x, d, w, n, theta_full, core_attrs, core_count):
        coll = _MeshColl(daxes, nd, has_model)
        # this shard's candidate slab, gathered+transposed once per run —
        # not per iteration (the §5.3 hoist, same as the local engine's x.T)
        x_tl = _mesh_cand_slab(cfg, coll, nm, x)
        cond, body = _make_cond_body(
            cfg, coll,
            lambda s: _eval_mesh(cfg, coll, collective, s, x_tl, d, w, n),
            x, d, w, n, theta_full, core_attrs, core_count)
        return jax.lax.while_loop(cond, body, st)

    state_specs = SelectionState(
        r_ids=P(daxes), h1=P(daxes), h2=P(daxes), active=P(daxes),
        remaining=P(), theta_history=P(), order=P(), k=P(), theta_r=P(),
        pr_correction=P(), n_selected=P())
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(state_specs, P(daxes, None), P(daxes), P(daxes), P(), P(),
                  P(), P()),
        out_specs=state_specs,
        check_vma=False,
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# distributed GrC build
# ---------------------------------------------------------------------------


def shard_decision_table(x: np.ndarray, d: np.ndarray, mesh: Mesh):
    """Place the raw table row-sharded over the data axes (the HDFS load)."""
    nd = _n_data_shards(mesh)
    n, a = x.shape
    n_pad = -(-n // nd) * nd
    xp = np.zeros((n_pad, a), np.int32)
    dp = np.zeros((n_pad,), np.int32)
    vp = np.zeros((n_pad,), bool)
    xp[:n], dp[:n], vp[:n] = x, d, True
    daxes = _data_axes(mesh)
    sh = lambda *spec: NamedSharding(mesh, P(*spec))
    return (
        jax.device_put(xp, sh(daxes, None)),
        jax.device_put(dp, sh(daxes)),
        jax.device_put(vp, sh(daxes)),
    )


def _shard_granularities_to_mesh(shard_grans, mesh: Mesh):
    """Place per-shard granule tables (equal capacities) row-sharded on the mesh."""
    daxes = _data_axes(mesh)
    sh = lambda *spec: NamedSharding(mesh, P(*spec))
    gx = jax.device_put(np.concatenate([np.asarray(g.x) for g in shard_grans]),
                        sh(daxes, None))
    gd = jax.device_put(np.concatenate([np.asarray(g.d) for g in shard_grans]),
                        sh(daxes))
    gw = jax.device_put(np.concatenate([np.asarray(g.w) for g in shard_grans]),
                        sh(daxes))
    gv = jax.device_put(np.concatenate([np.asarray(g.valid) for g in shard_grans]),
                        sh(daxes))
    return gx, gd, gw, gv


def _granularity_from_source(source, mesh: Mesh, *, n_dec: int, v_max: int,
                             chunk_rows: int):
    """Distributed GrC init without the sharded full table resident.

    Each data shard folds its slice of every chunk through the streaming
    monoid merge, so peak host memory is O(chunk + Σ per-shard granularity
    capacity) instead of the full ``(n_rows, n_attrs)`` array that
    ``shard_decision_table`` + ``_grc_build_step`` stage.  Chunks iterate
    on the *outside* — each is materialized exactly once and sliced per
    shard (the ``TokenStream.shard`` partition), not re-generated per shard
    (for a real out-of-core reader that would multiply IO by the shard
    count).  Cross-shard duplicate keys are allowed — the contingency sum
    is key-additive (module docstring) — so shards granulate independently,
    exactly like the per-partition combiner of a Spark reduceByKey.
    """
    nd = _n_data_shards(mesh)
    accs = [None] * nd
    for i in range(source.n_chunks(chunk_rows)):
        xc, dc = source.chunk(i, chunk_rows)
        n = xc.shape[0]
        for s in range(nd):
            lo, hi = s * n // nd, (s + 1) * n // nd
            accs[s] = fold_chunk(accs[s], xc[lo:hi], dc[lo:hi],
                                 n_dec=n_dec, v_max=v_max)
    if any(g is None for g in accs):
        raise ValueError("source yielded no rows for at least one data shard")
    cap_ps = max(next_pow2(max(int(g.num), 16)) for g in accs)
    accs = [with_capacity(g, cap_ps) for g in accs]
    gx, gd, gw, gv = _shard_granularities_to_mesh(accs, mesh)
    n_total = sum(int(g.n_total) for g in accs)
    return gx, gd, gw, gv, n_total


def _granularity_to_mesh(gran: Granularity, mesh: Mesh):
    """Split a prebuilt (host) granularity contiguously over the data shards.

    Live granules are distinct keys, so any row partition of them is a valid
    per-shard granularity table; capacities pad to a common power of two.
    """
    nd = _n_data_shards(mesh)
    live = int(gran.num)
    cap_ps = next_pow2(max(-(-max(live, 1) // nd), 16))
    x, d = np.asarray(gran.x)[:live], np.asarray(gran.d)[:live]
    w, v = np.asarray(gran.w)[:live], np.asarray(gran.valid)[:live]
    shard_grans = []
    for s in range(nd):
        lo, hi = s * live // nd, (s + 1) * live // nd
        g = Granularity(
            x=jnp.asarray(x[lo:hi]), d=jnp.asarray(d[lo:hi]),
            w=jnp.asarray(w[lo:hi]), valid=jnp.asarray(v[lo:hi]),
            num=jnp.int32(hi - lo), n_total=jnp.int32(int(w[lo:hi].sum())),
            n_attrs=gran.n_attrs, n_dec=gran.n_dec, v_max=gran.v_max,
        )
        shard_grans.append(with_capacity(g, cap_ps))
    gx, gd, gw, gv = _shard_granularities_to_mesh(shard_grans, mesh)
    return gx, gd, gw, gv, int(gran.n_total)


@lru_cache(maxsize=None)
def _grc_build_step(mesh: Mesh, n_dec: int, v_max: int, capacity: int):
    """Per-shard GrC initialization (paper lines 1–2).  No cross-shard dedup:
    duplicate keys across shards are weight-additive (module docstring)."""
    daxes = _data_axes(mesh)

    def local(x, d, valid):
        g = build_granularity(
            x, d, n_dec=n_dec, v_max=v_max,
            valid=valid, exact=True, capacity=capacity,
        )
        return g.x, g.d, g.w, g.valid, jax.lax.psum(g.num, daxes), jax.lax.psum(
            g.n_total, daxes)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(daxes, None), P(daxes), P(daxes)),
        out_specs=(P(daxes, None), P(daxes), P(daxes), P(daxes), P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# class-grouped placement for the fused schedule
# ---------------------------------------------------------------------------


def _regroup_by_class(gx, gd, gw, gvalid, r_ids, mesh):
    """Re-shard granules so each current class id lives on one data shard.

    The precondition of the ``fused`` collective (module docstring).  Classes
    are packed onto shards least-loaded-first (LPT); returns the re-placed
    arrays, or ``None`` when some shard would overflow its static capacity —
    the caller then falls back to ``all_reduce`` for that iteration.
    Feasibility is decided from ``r_ids``/``valid`` alone (O(G) gather); the
    full O(G·A) granule table is pulled to the host only when packing
    succeeds.  The Spark analogue is a ``partitionBy`` on the cached RDD, and
    G ≪ N after GrC init.  (A production mesh implementation would use a
    ragged all-to-all keyed on the class id instead of staging through the
    host.)
    """
    nd = _n_data_shards(mesh)
    if nd == 1:
        # One data shard: class grouping holds trivially, nothing to move.
        return gx, gd, gw, gvalid, r_ids
    daxes = _data_axes(mesh)
    cap = gx.shape[0]
    cps = cap // nd
    vh, rh = np.asarray(gvalid), np.asarray(r_ids)
    live = np.nonzero(vh)[0]
    classes, inverse, counts = np.unique(
        rh[live], return_inverse=True, return_counts=True)

    loads = np.zeros(nd, np.int64)
    assign = np.empty(len(classes), np.int64)
    for ci in np.argsort(-counts):
        s = int(np.argmin(loads))
        if loads[s] + counts[ci] > cps:
            return None
        assign[ci] = s
        loads[s] += counts[ci]

    xh, dh, wh = np.asarray(gx), np.asarray(gd), np.asarray(gw)
    nx = np.zeros_like(xh)
    nd_ = np.zeros_like(dh)
    nw = np.zeros_like(wh)
    nv = np.zeros_like(vh)
    nr = np.zeros_like(rh)
    offsets = np.arange(nd) * cps
    for s in range(nd):
        rows = live[assign[inverse] == s]
        sl = slice(offsets[s], offsets[s] + len(rows))
        nx[sl], nd_[sl], nw[sl], nr[sl] = xh[rows], dh[rows], wh[rows], rh[rows]
        nv[sl] = True

    sh = lambda *spec: NamedSharding(mesh, P(*spec))
    return (
        jax.device_put(nx, sh(daxes, None)),
        jax.device_put(nd_, sh(daxes)),
        jax.device_put(nw, sh(daxes)),
        jax.device_put(nv, sh(daxes)),
        jax.device_put(nr, sh(daxes)),
    )


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def plar_reduce_distributed(
    x=None,
    d=None,
    mesh: Optional[Mesh] = None,
    *,
    source=None,                        # Granularity | GranuleSource (alt. to x, d)
    chunk_rows: int = 65536,            # streaming-ingestion chunk size
    delta: str = "PR",
    n_dec: Optional[int] = None,
    v_max: Optional[int] = None,
    eps: float = 0.0,
    tol: float = 1e-6,
    tie_tol: float = 1e-5,
    max_features: Optional[int] = None,
    collective: str = "all_reduce",     # | "reduce_scatter" | "fused" (§Perf)
    backend: str = "segment",           # | "sweep_xla" (read-once slab, §5.3)
    ladder: bool = False,               # K-adaptive bin ladder (§5.3)
    selector: str = "analytic",         # tile/rung selection mode
    compute_core: bool = True,
    grc_init: bool = True,
    engine: str = "auto",               # "device" while_loop | "host" legacy loop
) -> ReductionResult:
    """PLAR Algorithm 2 on a ('pod','data','model') mesh.  See module doc."""
    t0 = time.perf_counter()
    if collective not in ("all_reduce", "reduce_scatter", "fused"):
        raise ValueError(
            f"unknown collective: {collective!r} "
            "(one of: all_reduce, reduce_scatter, fused)")
    if backend not in ("segment", "sweep_xla"):
        raise ValueError(
            f"unknown mesh Θ backend: {backend!r} (one of: segment, "
            "sweep_xla — the Pallas/interpret backends are single-process)")
    if engine not in ("auto", "host", "device"):
        raise ValueError(
            f"unknown engine: {engine!r} (one of: auto, host, device)")
    from repro.kernels.contingency.autotune import SELECTOR_MODES
    if selector not in SELECTOR_MODES:
        raise ValueError(
            f"unknown selector: {selector!r} "
            f"(one of: {', '.join(SELECTOR_MODES)})")
    if engine == "device" and collective == "fused":
        raise ValueError(
            "engine='device' cannot run the 'fused' collective: its class "
            "regrouping stages granules through the host between iterations; "
            "use engine='host'")
    if collective == "fused" and backend != "segment":
        raise ValueError(
            "collective='fused' has its own fused contingency→Θ schedule; "
            "backend must stay 'segment'")
    if engine == "auto":
        engine = "host" if collective == "fused" else "device"
    if mesh is None:
        raise ValueError("mesh is required")
    _check_source_args(x, d, source)
    nd = _n_data_shards(mesh)
    nm = _n_model_shards(mesh)

    if source is not None:
        # declared source metadata is authoritative on every ingestion path
        # (never re-inferred from whichever classes/values happened to
        # realize) — both Granularity and GranuleSource carry these fields
        n_dec = source.n_dec if n_dec is None else n_dec
        v_max = source.v_max if v_max is None else v_max

    if source is not None and not isinstance(source, Granularity) and not grc_init:
        # HAR cost model (every raw row a granule) has nothing to stream
        # into — materialize, same thin adapter as resolve_granularity.
        x, d = _materialize(source, chunk_rows)
        source = None

    # --- GrC initialization (distributed, cached in device memory) ---
    if isinstance(source, Granularity):
        A = source.n_attrs
        gx, gd, gw, gvalid, n_rows = _granularity_to_mesh(source, mesh)
    elif source is not None:
        A, n_rows = source.n_attrs, source.n_rows
        gx, gd, gw, gvalid, _ = _granularity_from_source(
            source, mesh, n_dec=n_dec, v_max=v_max, chunk_rows=chunk_rows)
    else:
        x = np.asarray(x, np.int32)
        d = np.asarray(d, np.int32)
        if n_dec is None:
            n_dec = int(d.max()) + 1
        if v_max is None:
            v_max = int(x.max()) + 1
        n_rows, A = x.shape
        xs, ds, vs = shard_decision_table(x, d, mesh)
        cap_per_shard = xs.shape[0] // nd
        if grc_init:
            build = _grc_build_step(mesh, n_dec, v_max, cap_per_shard)
            gx, gd, gw, gvalid, _g_num, _n_total = build(xs, ds, vs)
        else:
            gx, gd = xs, ds
            gw = jax.device_put(
                np.ones((xs.shape[0],), np.int32),
                NamedSharding(mesh, P(_data_axes(mesh))))
            gvalid = vs
    n = jnp.float32(n_rows)

    cap = gx.shape[0]
    daxes = _data_axes(mesh)
    sh = lambda *spec: NamedSharding(mesh, P(*spec))

    # --- Θ(D|C) (stop target) + core, on gathered granules (one-time) ---
    gx_h = np.asarray(gx)
    gd_h = np.asarray(gd)
    gw_h = np.asarray(gw)
    gv_h = np.asarray(gvalid)
    gran_h = Granularity(
        x=jnp.asarray(gx_h), d=jnp.asarray(gd_h), w=jnp.asarray(gw_h),
        valid=jnp.asarray(gv_h), num=jnp.int32(int(gv_h.sum())),
        n_total=jnp.int32(n_rows), n_attrs=A, n_dec=n_dec, v_max=v_max,
    )
    from .plan import subset_ids
    ids_c, _ = subset_ids(gran_h, jnp.arange(A, dtype=jnp.int32), exact=True)
    cont_c = contingency_from_ids(ids_c, gran_h.d, gran_h.w, gran_h.valid,
                                  n_bins=cap, m=n_dec)
    theta_full = float(measures.evaluate(delta, cont_c, n))

    core: List[int] = []
    n_evals = 0
    if compute_core:
        inner = _core_inner_thetas(gran_h, delta, exact=True)
        core = [int(a) for a in range(A) if inner[a] - theta_full > eps + tie_tol]
        n_evals += A

    if engine == "device":
        # One shard_map(while_loop) call runs the whole reduction on device;
        # jit places the replicated state leaves per the in_specs.
        max_sel = int(max_features) if max_features is not None else A
        runner = _engine_run_mesh(
            mesh, delta, A, cap, n_dec, v_max, float(tol), float(tie_tol),
            collective, max_sel, backend, bool(ladder), str(selector))
        reduct, theta_hist, iterations, ev, per_iter = run_engine(
            runner, cap, A, gvalid, gx, gd, gw, n, theta_full, core)
        return ReductionResult(
            reduct=reduct,
            core=core,
            theta_full=theta_full,
            theta_history=theta_hist,
            iterations=iterations,
            n_evaluations=n_evals + ev,
            elapsed_s=time.perf_counter() - t0,
            per_iteration_s=per_iter,
        )

    # --- distributed greedy loop state (engine == "host") ---
    r_ids = jax.device_put(np.zeros((cap,), np.int32), sh(daxes))
    k = 1
    reduct: List[int] = []
    theta_hist: List[float] = []
    per_iter_s: List[float] = []

    # Same (cap, m)-only pruning as the single-process drivers — the mesh
    # host loop lands on the identical rung set (§5.3 byte parity).
    rungs = ladder_rungs(cap * v_max, selector=selector, g=cap, m=n_dec)

    def adv_bins_for(k_):
        # The advance bound is ladder-independent (the §5.3 ladder shrinks
        # only the candidate evaluation), so theta histories are identical
        # with the ladder on or off.
        return _next_pow2(max(k_, 1)) * v_max

    def bins_for(k_):
        # Candidate-eval bound.  Ladder on: snap to the §5.3 rungs — every
        # rung is divisible by the (pow2) data-shard count, so
        # reduce_scatter keeps tiling at every K.  Ladder off: the legacy
        # pow2(k)·V bound.
        if ladder:
            return rung_for(k_, v_max, rungs)
        return adv_bins_for(k_)

    for a in core:
        adv = _advance_step(mesh, delta, adv_bins_for(k), n_dec, v_max)
        a_col = jnp.take(gx, a, axis=1)
        r_ids, k_new, theta_r = adv(a_col, r_ids, gd, gw, gvalid, n)
        k = int(k_new)
        reduct.append(a)
        theta_hist.append(float(theta_r))

    theta_r = theta_hist[-1] if theta_hist else float("inf")
    remaining = [a for a in range(A) if a not in reduct]
    iterations = 0
    # f32-mirrored stop threshold: same iteration count as the device cond
    stop_thresh = measures.f32_threshold(theta_full, tol)

    while remaining and theta_r > stop_thresh:
        if max_features is not None and len(reduct) >= max_features:
            break
        it0 = time.perf_counter()
        n_bins = bins_for(k)
        # candidate axis padded to the model-shard multiple (the MP level)
        a_pad = -(-len(remaining) // nm) * nm
        cand = np.full((a_pad,), remaining[-1], np.int32)
        cand[: len(remaining)] = remaining
        cand_dev = jax.device_put(cand, sh("model"))

        iter_collective = collective
        if collective == "fused":
            regrouped = _regroup_by_class(gx, gd, gw, gvalid, r_ids, mesh)
            if regrouped is None:
                # Classes too large to pack (early iterations) — K is small
                # then, so the all_reduce payload O(nc·K·m) is still cheap.
                iter_collective = "all_reduce"
            else:
                gx, gd, gw, gvalid, r_ids = regrouped

        ev = _eval_step(mesh, delta, n_bins, n_dec, v_max, iter_collective,
                        backend=backend)
        thetas = np.asarray(ev(cand_dev, r_ids, gx, gd, gw, gvalid, n), np.float64)
        thetas = thetas[: len(remaining)]
        n_evals += len(remaining)

        best = measures.argmin_with_ties(thetas, tie_tol)
        a_opt = remaining[best]

        adv = _advance_step(mesh, delta, adv_bins_for(k), n_dec, v_max)
        a_col = jnp.take(gx, a_opt, axis=1)
        r_ids, k_new, theta_new = adv(a_col, r_ids, gd, gw, gvalid, n)
        k = int(k_new)
        theta_r = float(theta_new)
        reduct.append(a_opt)
        remaining.remove(a_opt)
        theta_hist.append(theta_r)
        iterations += 1
        per_iter_s.append(time.perf_counter() - it0)

    return ReductionResult(
        reduct=reduct,
        core=core,
        theta_full=theta_full,
        theta_history=theta_hist,
        iterations=iterations,
        n_evaluations=n_evals,
        elapsed_s=time.perf_counter() - t0,
        per_iteration_s=per_iter_s,
    )
