"""Evaluation planning: how Θ(D|B) gets computed for batches of candidates.

Two modes (kept separate so §Perf can report the paper-faithful baseline and
the beyond-paper optimized version independently):

* ``spark`` — the direct transliteration of PLAR Algorithm 2: each candidate
  re-keys every granule from scratch (``map``) and groups by sorted key
  (``reduceByKey``).  Cost per candidate per iteration: O(G log G) sort.
* ``incremental`` — beyond-paper: exact class ids of ``U/R`` are maintained
  across iterations, so evaluating ``R ∪ {a}`` is a *pack* (``r·V + v``, O(G))
  followed by a contingency reduction into ``K·V`` exact bins — no sort in the
  loop, and the reduction is a one-hot contraction the MXU executes natively.

Contingency backends (all bit-equivalent, asserted by tests):

* ``segment`` — ``jax.ops.segment_sum`` (best on CPU; XLA scatter-add on TPU).
* ``onehot``  — chunked one-hot matmul (the MXU strategy expressed in XLA).
* ``pallas``  — the Pallas contingency kernel (``repro.kernels.contingency``).

Θ backends (:func:`candidate_theta`, DESIGN.md §5.2) additionally fold the
measure's θ row-reduction into the contingency accumulation so the
``[nc, K, M]`` tensor is never materialized in HBM:

* ``fused``     — the fused contingency→Θ Pallas kernel.
* ``fused_xla`` — the same schedule expressed in XLA: scan over bin tiles,
  θ per finished tile, scalar accumulation (rows = bins, so every tile holds
  complete rows — the property that makes the fusion exact).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from . import measures
from .granularity import Granularity, row_fingerprints

__all__ = [
    "ids_by_sort",
    "subset_ids",
    "candidate_contingency",
    "candidate_theta",
    "contingency_from_ids",
    "theta_for_ids",
]


def ids_by_sort(keys: Sequence[jnp.ndarray], valid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact dense ids for arbitrary sort keys (the reduceByKey grouping).

    ``keys[-1]`` is the primary sort key.  Returns ids in *original* order and
    the number of distinct keys K.  Invalid slots get id 0 and do not count.
    """
    n = valid.shape[0]
    sentineled = []
    for k in keys:
        ku = k.astype(jnp.uint32)
        sentineled.append(jnp.where(valid, ku, jnp.uint32(0xFFFFFFFF)))
    order = jnp.lexsort(tuple(sentineled))
    valid_s = valid[order]
    neq = jnp.zeros((n - 1,), bool)
    for k in sentineled:
        ks = k[order]
        neq = neq | (ks[1:] != ks[:-1])
    b = jnp.concatenate([jnp.ones((1,), bool), neq]) & valid_s
    ids_sorted = jnp.cumsum(b.astype(jnp.int32)) - 1
    ids_sorted = jnp.maximum(ids_sorted, 0)
    ids = jnp.zeros((n,), jnp.int32).at[order].set(jnp.where(valid_s, ids_sorted, 0))
    return ids, b.sum().astype(jnp.int32)


def subset_ids(gran: Granularity, cols: jnp.ndarray, *, exact: bool, seed: int = 0):
    """Class ids of ``U/B`` for the column subset B (dynamic index array)."""
    x_sub = gran.x[:, cols]
    if exact:
        keys = [x_sub[:, j] for j in range(x_sub.shape[1])][::-1]
    else:
        keys = [row_fingerprints(x_sub, seed + 7919), row_fingerprints(x_sub, seed)]
    return ids_by_sort(keys, gran.valid)


# ---------------------------------------------------------------------------
# Contingency backends: packed ids [nc, G] → counts [nc, n_bins, m]
# ---------------------------------------------------------------------------


def _cont_segment(packed, d, w, valid, n_bins, m):
    w_ = jnp.where(valid, w, 0).astype(jnp.float32)

    def one(p):
        seg = jnp.where(valid, p * m + d, n_bins * m)  # padding → dropped bin
        return jax.ops.segment_sum(w_, seg, num_segments=n_bins * m + 1)[:-1].reshape(n_bins, m)

    return jax.vmap(one)(packed)


def _cont_onehot(packed, d, w, valid, n_bins, m, *, bin_chunk: int = 512):
    """One-hot contraction, chunked over bins — mirrors the TPU MXU strategy."""
    w_ = jnp.where(valid, w, 0).astype(jnp.float32)
    wd = w_[:, None] * jax.nn.one_hot(d, m, dtype=jnp.float32)  # [G, m]
    n_chunks = -(-n_bins // bin_chunk)
    pad_bins = n_chunks * bin_chunk

    def chunk(c, _):
        base = c * bin_chunk
        bins = base + jnp.arange(bin_chunk)
        onehot = (packed[:, :, None] == bins[None, None, :]).astype(jnp.float32)  # [nc, G, BK]
        return c + 1, jnp.einsum("cgk,gm->ckm", onehot, wd)

    _, chunks = jax.lax.scan(chunk, 0, None, length=n_chunks)  # [n_chunks, nc, BK, m]
    cont = jnp.moveaxis(chunks, 0, 1).reshape(packed.shape[0], pad_bins, m)
    return cont[:, :n_bins, :]


def _cont_pallas(packed, d, w, valid, n_bins, m, *, interpret: bool):
    from repro.kernels.contingency.ops import contingency as _kernel

    w_ = jnp.where(valid, w, 0).astype(jnp.float32)
    return _kernel(packed, d, w_, n_bins=n_bins, n_dec=m, interpret=interpret)


@partial(jax.jit, static_argnames=("n_bins", "m", "backend", "interpret"))
def candidate_contingency(
    packed: jnp.ndarray,
    d: jnp.ndarray,
    w: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    n_bins: int,
    m: int,
    backend: str = "segment",
    interpret: bool = True,
) -> jnp.ndarray:
    """counts[c, k, j] = Σ_g w_g · 1[packed[c,g] = k] · 1[d_g = j].

    The paper's REDUCE phase for a *batch* of candidates at once (MP × DP).
    """
    if backend == "segment":
        return _cont_segment(packed, d, w, valid, n_bins, m)
    if backend == "onehot":
        return _cont_onehot(packed, d, w, valid, n_bins, m)
    if backend == "pallas":
        return _cont_pallas(packed, d, w, valid, n_bins, m, interpret=interpret)
    raise ValueError(f"unknown contingency backend: {backend}")


def _theta_fused_xla_raw(delta, packed, d, w, valid, *, n_bins, m, bin_chunk: int = 256):
    """XLA rendition of the fused kernel's schedule (DESIGN.md §5.2).

    Rows of the contingency table are bins, so a bin tile always holds
    *complete* rows — the unnormalized θ' can be applied per tile and the
    [nc, K, M] tensor is reduced to a scalar per candidate inside the scan
    carry.  This is what the Pallas kernel does on TPU, expressed for
    backends without Pallas support.

    Returns *raw* partials: like the Pallas kernel, normalization stays with
    the caller so raw sums/psums happen first and the measure's division
    happens exactly once — keeping Θ_PR integer-exact across tilings and
    shards (the determinism note in ``measures.evaluate``).
    """
    w_ = jnp.where(valid, w, 0).astype(jnp.float32)
    wd = w_[:, None] * jax.nn.one_hot(d, m, dtype=jnp.float32)  # [G, m]
    n_chunks = -(-n_bins // bin_chunk)

    def chunk(carry, c):
        bins = c * bin_chunk + jnp.arange(bin_chunk)
        onehot = (packed[:, :, None] == bins[None, None, :]).astype(jnp.float32)
        tile = jnp.einsum("cgk,gm->ckm", onehot, wd)          # [nc, BK, m]
        return carry + measures.RAW_ROWS[delta](tile).sum(-1), None

    # Bins ≥ n_bins never occur in `packed`, so overhang tiles hold all-zero
    # rows with θ' = 0 — no unpadding needed.
    raw, _ = jax.lax.scan(
        chunk, jnp.zeros((packed.shape[0],), jnp.float32),
        jnp.arange(n_chunks))
    return raw


def _theta_fused_xla(delta, packed, d, w, valid, n, *, n_bins, m, bin_chunk: int = 256):
    """Normalized Θ via the fused XLA schedule (single-process path)."""
    raw = _theta_fused_xla_raw(
        delta, packed, d, w, valid, n_bins=n_bins, m=m, bin_chunk=bin_chunk)
    return measures.theta_scale(delta, raw, n)


@partial(jax.jit, static_argnames=("delta", "n_bins", "m", "backend", "interpret"))
def candidate_theta(
    delta: str,
    packed: jnp.ndarray,
    d: jnp.ndarray,
    w: jnp.ndarray,
    valid: jnp.ndarray,
    n,
    *,
    n_bins: int,
    m: int,
    backend: str = "segment",
    interpret: bool = True,
) -> jnp.ndarray:
    """Θ(D|B∪{a})[c] for a batch of candidates — the full MAP+REDUCE+sum.

    ``segment``/``onehot``/``pallas`` materialize the contingency and reduce
    it with :func:`repro.core.measures.evaluate`; ``fused``/``fused_xla`` fold
    the θ epilogue into the accumulation (DESIGN.md §5.2) and never build the
    [nc, K, M] tensor.
    """
    if backend == "fused":
        from repro.kernels.contingency.ops import fused_theta

        w_ = jnp.where(valid, w, 0).astype(jnp.float32)
        return fused_theta(
            packed, d, w_, n, delta=delta, n_bins=n_bins, n_dec=m,
            interpret=interpret)
    if backend == "fused_xla":
        return _theta_fused_xla(delta, packed, d, w, valid, n, n_bins=n_bins, m=m)
    if backend not in ("segment", "onehot", "pallas"):
        raise ValueError(
            f"unknown Θ backend: {backend!r} "
            "(one of: segment, onehot, pallas, fused, fused_xla)")
    cont = candidate_contingency(
        packed, d, w, valid, n_bins=n_bins, m=m, backend=backend,
        interpret=interpret)
    return measures.evaluate(delta, cont, n)


def contingency_from_ids(ids, d, w, valid, *, n_bins: int, m: int) -> jnp.ndarray:
    """Single-subset contingency [n_bins, m] (used for Θ(D|R), Θ(D|C), core)."""
    return candidate_contingency(ids[None, :], d, w, valid, n_bins=n_bins, m=m)[0]


def theta_for_ids(delta: str, ids, gran: Granularity, *, n_bins: int):
    """Θ(D|B) given exact class ids of U/B."""
    cont = contingency_from_ids(ids, gran.d, gran.w, gran.valid, n_bins=n_bins, m=gran.n_dec)
    return measures.evaluate(delta, cont, gran.n_total)
