"""Evaluation planning: how Θ(D|B) gets computed for batches of candidates.

Two modes (kept separate so §Perf can report the paper-faithful baseline and
the beyond-paper optimized version independently):

* ``spark`` — the direct transliteration of PLAR Algorithm 2: each candidate
  re-keys every granule from scratch (``map``) and groups by sorted key
  (``reduceByKey``).  Cost per candidate per iteration: O(G log G) sort.
* ``incremental`` — beyond-paper: exact class ids of ``U/R`` are maintained
  across iterations, so evaluating ``R ∪ {a}`` is a *pack* (``r·V + v``, O(G))
  followed by a contingency reduction into ``K·V`` exact bins — no sort in the
  loop, and the reduction is a one-hot contraction the MXU executes natively.

Contingency backends (all bit-equivalent, asserted by tests):

* ``segment`` — ``jax.ops.segment_sum`` (best on CPU; XLA scatter-add on TPU).
* ``onehot``  — chunked one-hot matmul (the MXU strategy expressed in XLA).
* ``pallas``  — the Pallas contingency kernel (``repro.kernels.contingency``).

Θ backends (:func:`candidate_theta`, DESIGN.md §5.2) additionally fold the
measure's θ row-reduction into the contingency accumulation so the
``[nc, K, M]`` tensor is never materialized in HBM:

* ``fused``     — the fused contingency→Θ Pallas kernel.
* ``fused_xla`` — the same schedule expressed in XLA: scan over bin tiles,
  θ per finished tile, scalar accumulation (rows = bins, so every tile holds
  complete rows — the property that makes the fusion exact).

Sweep backends (DESIGN.md §5.3) take the *read-once slab* operand form —
a pre-transposed candidate slab ``x_t [nc, G]`` plus the shared class ids
``r_ids [G]`` — and fold the id-packing ``p = r·V + v`` into the reduction,
so ``packed [nc, G]`` never exists as its own buffer:

* ``sweep``     — the multi-candidate Pallas kernel
  (``kernels/contingency/sweep.py``): each granule tile is loaded once and
  reused across a block of candidates.
* ``sweep_xla`` — the host/XLA twin: fused-pack segment contingency + the
  kernel's tile-ordered θ epilogue (:func:`_theta_tiled_raw`), whose
  sequential per-tile accumulation is what gives the §5.3 bin ladder its
  bitwise ladder-on == ladder-off guarantee.

The **bin ladder** (:func:`ladder_rungs`) supplies the static bucket sizes
the drivers select from per iteration: pow2 multiples of the 256-bin tile up
to the run's static bound ``cap·v_max`` (itself always the top rung).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import measures
from .granularity import Granularity, row_fingerprints

__all__ = [
    "ids_by_sort",
    "subset_ids",
    "candidate_contingency",
    "candidate_theta",
    "contingency_from_ids",
    "theta_for_ids",
    "ladder_rungs",
    "rung_for",
    "theta_tiled_raw",
    "LADDER_TILE",
    "SWEEP_BACKENDS",
]

# Bin-tile width of the ladder/sweep schedules (DESIGN.md §5.3): matches the
# fused kernels' 256-bin tile so every rung is a whole number of θ tiles.
LADDER_TILE = 256


def ladder_rungs(n_bins: int, tile: int = LADDER_TILE, *,
                 selector: str = "heuristic",
                 g: Optional[int] = None,
                 m: Optional[int] = None) -> Tuple[int, ...]:
    """Static bin-bucket ladder for K-adaptive evaluation (DESIGN.md §5.3).

    Ascending pow2 multiples of ``tile`` strictly below ``n_bins``, closed by
    ``n_bins`` itself (the run's exact static bound ``cap·v_max``).  Rung
    properties the drivers rely on:

    * every rung below the top is ``tile·2^i`` — a power-of-two multiple of
      the 256-bin θ tile, so it is divisible by any pow2 data-shard count
      ≤ 256; the top rung ``cap·v_max`` is divisible by the data-shard
      count on the mesh because ``cap = nd·cap_per_shard`` there
      (``reduce_scatter`` keeps tiling at every rung);
    * the top rung is the exact full bound, so selecting "first rung
      ≥ K·v_max" always succeeds (K ≤ cap);
    * a smaller rung's θ tiles are a *prefix* of a larger rung's: rungs
      below the top are whole tile counts, and a top rung that is not
      (non-pow2 ``cap``, or ``cap < tile``) gets its trailing partial tile
      zero-padded by :func:`_theta_tiled_raw` — all-zero rows with θ' = 0,
      so the prefix/bit-parity argument is unaffected.

    ``selector="analytic"`` (with the granule count ``g`` and decision width
    ``m``) additionally prunes the pow2 set by the modeled padding-vs-traffic
    tradeoff (``kernels/contingency/model.prune_ladder_rungs``): a rung
    survives only if it saves a meaningful fraction of the per-iteration eval
    cost — dispatch-bound tables (G ≫ K·V) collapse to few rungs, fewer
    ``lax.switch`` branches.  The pruned set is a subset of the pow2 set
    closed over the exact top rung, so every invariant above is inherited and
    results stay byte-identical (the §5.3 rung-invariance lemma).  Other
    selector values (``heuristic``/``pinned``) keep the full pow2 ladder.
    """
    rungs = []
    b = tile
    while b < n_bins:
        rungs.append(b)
        b *= 2
    rungs.append(n_bins)
    if selector == "analytic" and g is not None and m is not None:
        from repro.kernels.contingency.model import prune_ladder_rungs

        return prune_ladder_rungs(rungs, int(g), int(m))
    return tuple(rungs)


def rung_for(k: int, v_max: int, rungs: Sequence[int]) -> int:
    """Host-side rung selection: smallest rung ≥ K·v_max.

    The host twin of the device engine's ``_rung_index`` — load-bearing for
    host/device ladder parity, so both drivers share this one definition.
    """
    need = max(k, 1) * v_max
    return next(r for r in rungs if r >= need)


def ids_by_sort(keys: Sequence[jnp.ndarray], valid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact dense ids for arbitrary sort keys (the reduceByKey grouping).

    ``keys[-1]`` is the primary sort key.  Returns ids in *original* order and
    the number of distinct keys K.  Invalid slots get id 0 and do not count.
    """
    n = valid.shape[0]
    sentineled = []
    for k in keys:
        ku = k.astype(jnp.uint32)
        sentineled.append(jnp.where(valid, ku, jnp.uint32(0xFFFFFFFF)))
    order = jnp.lexsort(tuple(sentineled))
    valid_s = valid[order]
    neq = jnp.zeros((n - 1,), bool)
    for k in sentineled:
        ks = k[order]
        neq = neq | (ks[1:] != ks[:-1])
    b = jnp.concatenate([jnp.ones((1,), bool), neq]) & valid_s
    ids_sorted = jnp.cumsum(b.astype(jnp.int32)) - 1
    ids_sorted = jnp.maximum(ids_sorted, 0)
    ids = jnp.zeros((n,), jnp.int32).at[order].set(jnp.where(valid_s, ids_sorted, 0))
    return ids, b.sum().astype(jnp.int32)


def subset_ids(gran: Granularity, cols: jnp.ndarray, *, exact: bool, seed: int = 0):
    """Class ids of ``U/B`` for the column subset B (dynamic index array)."""
    x_sub = gran.x[:, cols]
    if exact:
        keys = [x_sub[:, j] for j in range(x_sub.shape[1])][::-1]
    else:
        keys = [row_fingerprints(x_sub, seed + 7919), row_fingerprints(x_sub, seed)]
    return ids_by_sort(keys, gran.valid)


# ---------------------------------------------------------------------------
# Contingency backends: packed ids [nc, G] → counts [nc, n_bins, m]
# ---------------------------------------------------------------------------


def _cont_segment(packed, d, w, valid, n_bins, m):
    w_ = jnp.where(valid, w, 0).astype(jnp.float32)

    def one(p):
        seg = jnp.where(valid, p * m + d, n_bins * m)  # padding → dropped bin
        return jax.ops.segment_sum(w_, seg, num_segments=n_bins * m + 1)[:-1].reshape(n_bins, m)

    return jax.vmap(one)(packed)


def _cont_onehot(packed, d, w, valid, n_bins, m, *, bin_chunk: int = 512):
    """One-hot contraction, chunked over bins — mirrors the TPU MXU strategy."""
    w_ = jnp.where(valid, w, 0).astype(jnp.float32)
    wd = w_[:, None] * jax.nn.one_hot(d, m, dtype=jnp.float32)  # [G, m]
    n_chunks = -(-n_bins // bin_chunk)
    pad_bins = n_chunks * bin_chunk

    def chunk(c, _):
        base = c * bin_chunk
        bins = base + jnp.arange(bin_chunk)
        onehot = (packed[:, :, None] == bins[None, None, :]).astype(jnp.float32)  # [nc, G, BK]
        return c + 1, jnp.einsum("cgk,gm->ckm", onehot, wd)

    _, chunks = jax.lax.scan(chunk, 0, None, length=n_chunks)  # [n_chunks, nc, BK, m]
    cont = jnp.moveaxis(chunks, 0, 1).reshape(packed.shape[0], pad_bins, m)
    return cont[:, :n_bins, :]


def _cont_pallas(packed, d, w, valid, n_bins, m, *, interpret: bool,
                 selector=None):
    from repro.kernels.contingency.ops import contingency as _kernel

    w_ = jnp.where(valid, w, 0).astype(jnp.float32)
    return _kernel(packed, d, w_, n_bins=n_bins, n_dec=m, interpret=interpret,
                   selector=selector)


@partial(jax.jit, static_argnames=("n_bins", "m", "backend", "interpret",
                                   "selector"))
def candidate_contingency(
    packed: jnp.ndarray,
    d: jnp.ndarray,
    w: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    n_bins: int,
    m: int,
    backend: str = "segment",
    interpret: bool = True,
    selector: Optional[str] = None,
) -> jnp.ndarray:
    """counts[c, k, j] = Σ_g w_g · 1[packed[c,g] = k] · 1[d_g = j].

    The paper's REDUCE phase for a *batch* of candidates at once (MP × DP).
    ``selector`` picks the Pallas tile-selection mode (None = analytic
    default); the XLA backends have no tiles and ignore it.
    """
    if backend == "segment":
        return _cont_segment(packed, d, w, valid, n_bins, m)
    if backend == "onehot":
        return _cont_onehot(packed, d, w, valid, n_bins, m)
    if backend == "pallas":
        return _cont_pallas(packed, d, w, valid, n_bins, m,
                            interpret=interpret, selector=selector)
    raise ValueError(f"unknown contingency backend: {backend}")


def _theta_fused_xla_raw(delta, packed, d, w, valid, *, n_bins, m, bin_chunk: int = 256):
    """XLA rendition of the fused kernel's schedule (DESIGN.md §5.2).

    Rows of the contingency table are bins, so a bin tile always holds
    *complete* rows — the unnormalized θ' can be applied per tile and the
    [nc, K, M] tensor is reduced to a scalar per candidate inside the scan
    carry.  This is what the Pallas kernel does on TPU, expressed for
    backends without Pallas support.

    Returns *raw* partials: like the Pallas kernel, normalization stays with
    the caller so raw sums/psums happen first and the measure's division
    happens exactly once — keeping Θ_PR integer-exact across tilings and
    shards (the determinism note in ``measures.evaluate``).
    """
    w_ = jnp.where(valid, w, 0).astype(jnp.float32)
    wd = w_[:, None] * jax.nn.one_hot(d, m, dtype=jnp.float32)  # [G, m]
    n_chunks = -(-n_bins // bin_chunk)

    def chunk(carry, c):
        bins = c * bin_chunk + jnp.arange(bin_chunk)
        onehot = (packed[:, :, None] == bins[None, None, :]).astype(jnp.float32)
        tile = jnp.einsum("cgk,gm->ckm", onehot, wd)          # [nc, BK, m]
        return carry + measures.RAW_ROWS[delta](tile).sum(-1), None

    # Bins ≥ n_bins never occur in `packed`, so overhang tiles hold all-zero
    # rows with θ' = 0 — no unpadding needed.
    raw, _ = jax.lax.scan(
        chunk, jnp.zeros((packed.shape[0],), jnp.float32),
        jnp.arange(n_chunks))
    return raw


def _theta_fused_xla(delta, packed, d, w, valid, n, *, n_bins, m, bin_chunk: int = 256):
    """Normalized Θ via the fused XLA schedule (single-process path)."""
    raw = _theta_fused_xla_raw(
        delta, packed, d, w, valid, n_bins=n_bins, m=m, bin_chunk=bin_chunk)
    return measures.theta_scale(delta, raw, n)


# ---------------------------------------------------------------------------
# sweep backend: read-once candidate slab + tile-ordered θ (DESIGN.md §5.3)
# ---------------------------------------------------------------------------


def sweep_contingency(x_t, r_ids, d, w, valid, *, v_max: int, n_bins: int, m: int):
    """Fused-pack contingency: slab ``x_t [nc, G]`` + shared ``r_ids [G]``.

    The id-packing ``p = r·V + v`` folds into the per-candidate segment
    expression, so ``packed [nc, G]`` is never staged as its own buffer —
    the XLA twin of the sweep kernel's in-register pack.  Counts are
    scatter-adds of integer-valued f32 weights: exact and order-independent
    below 2²⁴, so the result is bit-identical to the ``segment`` backend's
    pack-then-reduce for every bin bound ≥ K·V.
    """
    w_ = jnp.where(valid, w, 0).astype(jnp.float32)
    d32 = d.astype(jnp.int32)

    def one(x_row):
        seg = jnp.where(valid, (r_ids * v_max + x_row) * m + d32, n_bins * m)
        return jax.ops.segment_sum(
            w_, seg, num_segments=n_bins * m + 1)[:-1].reshape(n_bins, m)

    return jax.vmap(one)(x_t)


def _theta_tiled_raw(delta, cont, *, tile: int = LADDER_TILE):
    """Sequential per-tile θ' accumulation over bin tiles — the sweep
    kernel's epilogue order expressed on a materialized contingency.

    ``cont [nc, nb, m]`` is split into ``ceil(nb/tile)`` bin tiles (trailing
    tile zero-padded) and θ' is accumulated tile-by-tile in ascending order
    via a scan carry: a fixed-length within-tile reduction plus a sequential
    chain of f32 scalar adds.  This is the load-bearing structure of the bin
    ladder's bit-parity guarantee (DESIGN.md §5.3): a smaller rung's tiles
    are a prefix of a larger rung's, and every dropped trailing tile holds
    only all-zero rows whose θ' is exactly 0 — adding exact zeros in the
    same order cannot change the f32 value.
    """
    nc, nb, m = cont.shape
    n_tiles = -(-nb // tile)
    if n_tiles * tile != nb:
        cont = jnp.pad(cont, ((0, 0), (0, n_tiles * tile - nb), (0, 0)))
    tiles = jnp.moveaxis(cont.reshape(nc, n_tiles, tile, m), 1, 0)

    def step(carry, tile_cont):
        return carry + measures.RAW_ROWS[delta](tile_cont).sum(-1), None

    raw, _ = jax.lax.scan(step, jnp.zeros((nc,), jnp.float32), tiles)
    return raw


# Public alias: the ensemble engine (core/engine.py) composes the sweep
# epilogue with a per-config measure switch, so the tile-ordered accumulation
# — the structure the §5.3 bitwise rung-invariance rests on — is part of the
# module's contract, not an implementation detail.
theta_tiled_raw = _theta_tiled_raw


def _theta_sweep_xla(delta, x_t, r_ids, d, w, valid, n, *, v_max, n_bins, m):
    """Normalized Θ via the sweep schedule: fused-pack contingency +
    tile-ordered θ epilogue (single-process / per-shard-local path)."""
    cont = sweep_contingency(
        x_t, r_ids, d, w, valid, v_max=v_max, n_bins=n_bins, m=m)
    return measures.theta_scale(delta, _theta_tiled_raw(delta, cont), n)


SWEEP_BACKENDS = ("sweep", "sweep_xla")


@partial(jax.jit, static_argnames=("delta", "n_bins", "m", "backend",
                                   "interpret", "v_max", "selector"))
def candidate_theta(
    delta: str,
    packed: jnp.ndarray,
    d: jnp.ndarray,
    w: jnp.ndarray,
    valid: jnp.ndarray,
    n,
    *,
    n_bins: int,
    m: int,
    backend: str = "segment",
    interpret: bool = True,
    x_t: Optional[jnp.ndarray] = None,
    r_ids: Optional[jnp.ndarray] = None,
    v_max: Optional[int] = None,
    selector: Optional[str] = None,
) -> jnp.ndarray:
    """Θ(D|B∪{a})[c] for a batch of candidates — the full MAP+REDUCE+sum.

    ``segment``/``onehot``/``pallas`` materialize the contingency and reduce
    it with :func:`repro.core.measures.evaluate`; ``fused``/``fused_xla`` fold
    the θ epilogue into the accumulation (DESIGN.md §5.2) and never build the
    [nc, K, M] tensor.

    The sweep backends (DESIGN.md §5.3) take the read-once slab operands
    ``x_t [nc, G]`` + ``r_ids [G]`` + static ``v_max`` instead of ``packed``
    (pass ``packed=None``): the pack is fused into the reduction and θ runs
    as the tile-ordered epilogue, so ``n_bins`` may be any §5.3 ladder rung
    ≥ K·V with bitwise-identical results across rungs.
    """
    if backend in SWEEP_BACKENDS:
        if x_t is None or r_ids is None or v_max is None:
            raise ValueError(
                f"backend={backend!r} takes the slab operand form: pass "
                "x_t=, r_ids=, v_max= (and packed=None)")
        if backend == "sweep":
            from repro.kernels.contingency.ops import sweep_theta

            w_ = jnp.where(valid, w, 0).astype(jnp.float32)
            return sweep_theta(
                x_t, r_ids, d, w_, n, delta=delta, v_max=v_max,
                n_bins=n_bins, n_dec=m, interpret=interpret,
                selector=selector)
        return _theta_sweep_xla(
            delta, x_t, r_ids, d, w, valid, n, v_max=v_max, n_bins=n_bins,
            m=m)
    if backend == "fused":
        from repro.kernels.contingency.ops import fused_theta

        w_ = jnp.where(valid, w, 0).astype(jnp.float32)
        return fused_theta(
            packed, d, w_, n, delta=delta, n_bins=n_bins, n_dec=m,
            interpret=interpret, selector=selector)
    if backend == "fused_xla":
        return _theta_fused_xla(delta, packed, d, w, valid, n, n_bins=n_bins, m=m)
    if backend not in ("segment", "onehot", "pallas"):
        raise ValueError(
            f"unknown Θ backend: {backend!r} "
            "(one of: segment, onehot, pallas, fused, fused_xla, sweep, "
            "sweep_xla)")
    cont = candidate_contingency(
        packed, d, w, valid, n_bins=n_bins, m=m, backend=backend,
        interpret=interpret, selector=selector)
    return measures.evaluate(delta, cont, n)


def contingency_from_ids(ids, d, w, valid, *, n_bins: int, m: int) -> jnp.ndarray:
    """Single-subset contingency [n_bins, m] (used for Θ(D|R), Θ(D|C), core)."""
    return candidate_contingency(ids[None, :], d, w, valid, n_bins=n_bins, m=m)[0]


def theta_for_ids(delta: str, ids, gran: Granularity, *, n_bins: int):
    """Θ(D|B) given exact class ids of U/B."""
    cont = contingency_from_ids(ids, gran.d, gran.w, gran.valid, n_bins=n_bins, m=gran.n_dec)
    return measures.evaluate(delta, cont, gran.n_total)
