from .pipeline import (
    FeatureSelectedStream, GranuleSource, ROW_BLOCK, TabularStream, TokenStream,
    paper_dataset, scaled_paper_dataset,
)

__all__ = [
    "FeatureSelectedStream", "GranuleSource", "ROW_BLOCK", "TabularStream",
    "TokenStream", "paper_dataset", "scaled_paper_dataset",
]
