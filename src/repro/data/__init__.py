from .pipeline import (
    FeatureSelectedStream, TabularStream, TokenStream,
    paper_dataset, scaled_paper_dataset,
)

__all__ = [
    "FeatureSelectedStream", "TabularStream", "TokenStream",
    "paper_dataset", "scaled_paper_dataset",
]
