"""Data pipeline: deterministic, shardable, restart-safe streams.

Every batch is a pure function of ``(seed, step)`` — no iterator state.
That single property delivers three production behaviors for free:

* **restart** — a resumed run at step k regenerates exactly batch k;
* **elastic** — each data shard slices the same global batch by its index,
  so re-sharding onto a different topology never replays or skips data;
* **straggler-safe** — there is no pipeline head-of-line blocking to stall.

Streams:
* ``TokenStream``    — synthetic LM token batches (zipf-ish marginals with a
  deterministic per-position mixture so the loss is learnable, not uniform).
* ``TabularStream``  — synthetic decision tables of the paper's shape
  (categorical features + redundant copies + label-correlated columns),
  the input to PLAR and to the feature-selected training demo.
* ``FeatureSelectedStream`` — applies a PLAR reduct to a TabularStream:
  the paper's technique as a first-class pipeline stage.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        # learnable structure: next token = (token + fixed per-pos delta) mod V
        base = rng.integers(0, self.vocab, (b, 1))
        delta = np.arange(s)[None, :] * 7 % self.vocab
        noise = rng.integers(0, self.vocab, (b, s)) * (rng.random((b, s)) < 0.1)
        toks = ((base + delta + noise) % self.vocab).astype(np.int32)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        return {"tokens": toks, "labels": labels.astype(np.int32)}

    def shard(self, step: int, shard_index: int, n_shards: int) -> Dict[str, np.ndarray]:
        full = self.batch(step)
        lo = shard_index * self.global_batch // n_shards
        hi = (shard_index + 1) * self.global_batch // n_shards
        return {k: v[lo:hi] for k, v in full.items()}


@dataclasses.dataclass(frozen=True)
class TabularStream:
    """Synthetic decision tables shaped like the paper's datasets.

    ``distinct_fraction`` controls row duplication: rows are sampled from
    ``distinct_fraction · n_rows`` prototypes.  The paper's large datasets
    (KDD99 especially) are massively redundant — that redundancy is exactly
    what GrC initialization exploits (|U/A| ≪ |U|), so the stand-ins must
    reproduce it for Fig. 9 to be meaningful.
    """
    n_rows: int
    n_attrs: int
    v_max: int = 4
    n_dec: int = 2
    redundancy: float = 0.4     # fraction of attributes that copy another
    relevance: int = 3          # attributes the decision actually depends on
    noise: float = 0.05
    distinct_fraction: float = 1.0
    seed: int = 0

    def table(self):
        rng = np.random.default_rng(self.seed)
        n_proto = max(2, int(self.n_rows * self.distinct_fraction))
        x = rng.integers(0, self.v_max, (n_proto, self.n_attrs)).astype(np.int32)
        for j in range(1, self.n_attrs):
            if rng.random() < self.redundancy:
                x[:, j] = x[:, rng.integers(0, j)]
        rel = rng.choice(self.n_attrs, size=min(self.relevance, self.n_attrs),
                         replace=False)
        d = np.zeros(n_proto, np.int64)
        for i, a in enumerate(rel):
            d = d * self.v_max + x[:, a]
        d = (d % self.n_dec).astype(np.int32)
        flip = rng.random(n_proto) < self.noise
        d[flip] = rng.integers(0, self.n_dec, flip.sum())
        if n_proto < self.n_rows:
            # zipf-ish prototype popularity, like real log/connection data
            w = 1.0 / np.arange(1, n_proto + 1)
            idx = rng.choice(n_proto, size=self.n_rows, p=w / w.sum())
            return x[idx], d[idx]
        return x, d


@dataclasses.dataclass(frozen=True)
class FeatureSelectedStream:
    """PLAR-as-pipeline-stage: project a tabular stream onto a reduct."""
    base: TabularStream
    reduct: Sequence[int]

    def table(self):
        x, d = self.base.table()
        return x[:, list(self.reduct)], d


def paper_dataset(name: str, seed: int = 0) -> TabularStream:
    """Synthetic stand-ins shaped like the paper's Table 5 datasets.

    (The UCI/KDD/SDSS files are not redistributable inside this container;
    shapes and cardinalities follow Table 5 so the benchmark cost profile
    matches — documented in EXPERIMENTS.md.)
    """
    shapes = {
        # name: (rows, attrs, v_max, classes, distinct_fraction)
        # distinct_fraction mirrors the real datasets' redundancy: KDD99's
        # 5M connection records collapse to ~1–2% distinct rows, which is
        # what makes the paper's GrC initialization pay off (Fig. 9).
        "mushroom": (5644, 22, 6, 2, 0.6),
        "tic-tac-toe": (958, 9, 3, 2, 1.0),
        "dermatology": (358, 34, 4, 6, 1.0),
        "kr-vs-kp": (3196, 36, 3, 2, 1.0),
        "breast-cancer-wisconsin": (683, 9, 10, 2, 0.7),
        "backup-large": (376, 35, 4, 19, 1.0),
        "shuttle": (58000, 9, 8, 7, 0.15),
        "letter-recognition": (20000, 16, 16, 26, 0.9),
        "ticdata2000": (5822, 85, 10, 2, 0.9),
        "kdd99": (5_000_000, 41, 10, 23, 0.02),
        "weka15360": (15_360_000, 20, 8, 10, 0.05),
        "gisette": (6000, 5000, 2, 2, 1.0),
        "sdss": (320_000, 5201, 8, 17, 0.8),
    }
    rows, attrs, vmax, classes, distinct = shapes[name]
    return TabularStream(n_rows=rows, n_attrs=attrs, v_max=vmax, n_dec=classes,
                         distinct_fraction=distinct, seed=seed)


def scaled_paper_dataset(name: str, max_rows: int = 20000, max_attrs: int = 64,
                         seed: int = 0) -> TabularStream:
    """CPU-budget version of `paper_dataset` (same family, capped dims)."""
    t = paper_dataset(name, seed)
    return dataclasses.replace(
        t, n_rows=min(t.n_rows, max_rows), n_attrs=min(t.n_attrs, max_attrs)
    )
