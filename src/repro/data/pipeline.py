"""Data pipeline: deterministic, shardable, restart-safe streams.

Every batch is a pure function of ``(seed, step)`` — no iterator state.
That single property delivers three production behaviors for free:

* **restart** — a resumed run at step k regenerates exactly batch k;
* **elastic** — each data shard slices the same global batch by its index,
  so re-sharding onto a different topology never replays or skips data;
* **straggler-safe** — there is no pipeline head-of-line blocking to stall.

Streams:
* ``TokenStream``    — synthetic LM token batches (zipf-ish marginals with a
  deterministic per-position mixture so the loss is learnable, not uniform).
* ``TabularStream``  — synthetic decision tables of the paper's shape
  (categorical features + redundant copies + label-correlated columns),
  the input to PLAR and to the feature-selected training demo.  Implements
  :class:`GranuleSource`: ``chunk``/``shard`` materialize rows blockwise for
  streaming GrC ingestion (DESIGN.md §3.6) with the same restart/elastic
  contract as ``TokenStream``.
* ``FeatureSelectedStream`` — applies a PLAR reduct to a TabularStream:
  the paper's technique as a first-class pipeline stage.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict, Iterator, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro import obs

# Canonical generation block: tabular rows are generated (and cached) in
# fixed blocks of this many rows, so ``chunk(step, chunk_rows)`` is a pure
# function of ``(seed, step)`` for *every* chunk size — chunk boundaries
# re-slice the same underlying row sequence instead of re-drawing it.
ROW_BLOCK = 65536


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        # learnable structure: next token = (token + fixed per-pos delta) mod V
        base = rng.integers(0, self.vocab, (b, 1))
        delta = np.arange(s)[None, :] * 7 % self.vocab
        noise = rng.integers(0, self.vocab, (b, s)) * (rng.random((b, s)) < 0.1)
        toks = ((base + delta + noise) % self.vocab).astype(np.int32)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        return {"tokens": toks, "labels": labels.astype(np.int32)}

    def shard(self, step: int, shard_index: int, n_shards: int) -> Dict[str, np.ndarray]:
        full = self.batch(step)
        lo = shard_index * self.global_batch // n_shards
        hi = (shard_index + 1) * self.global_batch // n_shards
        return {k: v[lo:hi] for k, v in full.items()}


@runtime_checkable
class GranuleSource(Protocol):
    """What streaming GrC ingestion needs from a decision-table source.

    A ``GranuleSource`` yields the table *chunkwise* — a pure function of
    ``(seed, step)``, never an iterator with hidden state — plus the static
    metadata the granularity build needs up front.  ``TabularStream``
    implements it; so would a real out-of-core reader (Parquet row groups,
    HDFS splits).  Chunk-size invariance is part of the contract: the
    concatenation of ``chunk(0..n_chunks-1, c)`` must be the same row
    sequence for every ``c`` — consumers (``build_granularity_streaming``)
    rely on it for bit-exact reducts regardless of chunking.
    """

    n_rows: int
    n_attrs: int
    v_max: int
    n_dec: int

    def n_chunks(self, chunk_rows: int) -> int: ...

    def chunk(self, step: int, chunk_rows: int) -> Tuple[np.ndarray, np.ndarray]: ...

    def shard(self, step: int, shard_index: int, n_shards: int,
              chunk_rows: int = ROW_BLOCK) -> Tuple[np.ndarray, np.ndarray]: ...


# Prototype sets above this size get a most-recent-only cache slot instead
# of the shared 8-way one: an 8-deep lru_cache could pin several multi-GB
# sets (sdss: ~5 GB each) for the process lifetime — exactly the
# resident-memory story streaming ingestion exists to avoid — while no
# cache at all would regenerate them once per chunk() call.
_PROTO_CACHE_MAX_BYTES = 1 << 28


def _prototypes(stream: "TabularStream"):
    """Prototype rows + decisions (host-cached; the only O(distinct) state)."""
    n_proto = max(2, int(stream.n_rows * stream.distinct_fraction))
    if n_proto * (stream.n_attrs + 1) * 4 > _PROTO_CACHE_MAX_BYTES:
        return _large_prototypes(stream)
    return _cached_prototypes(stream)


@lru_cache(maxsize=8)
def _cached_prototypes(stream: "TabularStream"):
    return _gen_prototypes(stream)


@lru_cache(maxsize=1)
def _large_prototypes(stream: "TabularStream"):
    return _gen_prototypes(stream)


def _gen_prototypes(stream: "TabularStream"):
    rng = np.random.default_rng(stream.seed)
    n_proto = max(2, int(stream.n_rows * stream.distinct_fraction))
    x = rng.integers(0, stream.v_max, (n_proto, stream.n_attrs)).astype(np.int32)
    for j in range(1, stream.n_attrs):
        if rng.random() < stream.redundancy:
            x[:, j] = x[:, rng.integers(0, j)]
    rel = rng.choice(stream.n_attrs, size=min(stream.relevance, stream.n_attrs),
                     replace=False)
    d = np.zeros(n_proto, np.int64)
    for i, a in enumerate(rel):
        d = d * stream.v_max + x[:, a]
    d = (d % stream.n_dec).astype(np.int32)
    flip = rng.random(n_proto) < stream.noise
    d[flip] = rng.integers(0, stream.n_dec, flip.sum())
    return x, d


@lru_cache(maxsize=32)
def _index_block(stream: "TabularStream", block: int) -> np.ndarray:
    """Prototype indices for canonical row block ``block`` — pure in
    ``(seed, block)``, so any chunking re-derives the same rows."""
    # arithmetic, NOT _prototypes(stream): reading the shape must not force
    # a (potentially uncached multi-GB) prototype generation
    n_proto = max(2, int(stream.n_rows * stream.distinct_fraction))
    lo = block * ROW_BLOCK
    hi = min(lo + ROW_BLOCK, stream.n_rows)
    rng = np.random.default_rng((stream.seed, block))
    # zipf-ish prototype popularity, like real log/connection data
    w = 1.0 / np.arange(1, n_proto + 1)
    return rng.choice(n_proto, size=hi - lo, p=w / w.sum())


@dataclasses.dataclass(frozen=True)
class TabularStream:
    """Synthetic decision tables shaped like the paper's datasets.

    ``distinct_fraction`` controls row duplication: rows are sampled from
    ``distinct_fraction · n_rows`` prototypes.  The paper's large datasets
    (KDD99 especially) are massively redundant — that redundancy is exactly
    what GrC initialization exploits (|U/A| ≪ |U|), so the stand-ins must
    reproduce it for Fig. 9 to be meaningful.

    A :class:`GranuleSource`: rows materialize chunkwise (``chunk``/
    ``shard``, pure in ``(seed, step)``), and ``table()`` is just the
    all-chunks concatenation — paper-scale tables never need it.
    """
    n_rows: int
    n_attrs: int
    v_max: int = 4
    n_dec: int = 2
    redundancy: float = 0.4     # fraction of attributes that copy another
    relevance: int = 3          # attributes the decision actually depends on
    noise: float = 0.05
    distinct_fraction: float = 1.0
    seed: int = 0

    def _rows(self, lo: int, hi: int):
        """Rows [lo, hi) of the logical table, assembled from canonical blocks."""
        x, d = _prototypes(self)
        if x.shape[0] >= self.n_rows:
            # every row is its own prototype — no sampling stage.  Copy: a
            # view would let caller mutation corrupt the process-wide
            # prototype cache and break the pure-(seed, step) contract.
            return x[lo:hi].copy(), d[lo:hi].copy()
        parts = []
        for b in range(lo // ROW_BLOCK, -(-hi // ROW_BLOCK)):
            blk = _index_block(self, b)
            s = max(lo - b * ROW_BLOCK, 0)
            e = min(hi - b * ROW_BLOCK, len(blk))
            parts.append(blk[s:e])
        idx = np.concatenate(parts) if len(parts) != 1 else parts[0]
        return x[idx], d[idx]

    def n_chunks(self, chunk_rows: int) -> int:
        return -(-self.n_rows // chunk_rows)

    def chunk(self, step: int, chunk_rows: int = ROW_BLOCK):
        """Rows ``[step·chunk_rows, (step+1)·chunk_rows)`` — pure in (seed, step)."""
        lo = step * chunk_rows
        if not 0 <= lo < self.n_rows:
            raise IndexError(
                f"chunk step {step} out of range for {self.n_chunks(chunk_rows)} chunks")
        hi = min(lo + chunk_rows, self.n_rows)
        with obs.span("pipeline.chunk", step=step, rows=hi - lo):
            return self._rows(lo, hi)

    def chunks(self, chunk_rows: int = ROW_BLOCK) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """All chunks in order (the streaming-ingestion driver input)."""
        return (self.chunk(i, chunk_rows) for i in range(self.n_chunks(chunk_rows)))

    def shard(self, step: int, shard_index: int, n_shards: int,
              chunk_rows: int = ROW_BLOCK):
        """Shard ``shard_index``'s slice of ``chunk(step)`` — same elastic
        contract as :meth:`TokenStream.shard`: shards partition the chunk,
        re-sharding never replays or skips rows."""
        x, d = self.chunk(step, chunk_rows)
        n = x.shape[0]
        lo = shard_index * n // n_shards
        hi = (shard_index + 1) * n // n_shards
        return x[lo:hi], d[lo:hi]

    def table(self):
        return self._rows(0, self.n_rows)


@dataclasses.dataclass(frozen=True)
class FeatureSelectedStream:
    """PLAR-as-pipeline-stage: project a tabular stream onto a reduct."""
    base: TabularStream
    reduct: Sequence[int]

    def table(self):
        x, d = self.base.table()
        return x[:, list(self.reduct)], d


def paper_dataset(name: str, seed: int = 0) -> TabularStream:
    """Synthetic stand-ins shaped like the paper's Table 5 datasets.

    (The UCI/KDD/SDSS files are not redistributable inside this container;
    shapes and cardinalities follow Table 5 so the benchmark cost profile
    matches — documented in EXPERIMENTS.md.)
    """
    shapes = {
        # name: (rows, attrs, v_max, classes, distinct_fraction)
        # distinct_fraction mirrors the real datasets' redundancy: KDD99's
        # 5M connection records collapse to ~1–2% distinct rows, which is
        # what makes the paper's GrC initialization pay off (Fig. 9).
        "mushroom": (5644, 22, 6, 2, 0.6),
        "tic-tac-toe": (958, 9, 3, 2, 1.0),
        "dermatology": (358, 34, 4, 6, 1.0),
        "kr-vs-kp": (3196, 36, 3, 2, 1.0),
        "breast-cancer-wisconsin": (683, 9, 10, 2, 0.7),
        "backup-large": (376, 35, 4, 19, 1.0),
        "shuttle": (58000, 9, 8, 7, 0.15),
        "letter-recognition": (20000, 16, 16, 26, 0.9),
        "ticdata2000": (5822, 85, 10, 2, 0.9),
        "kdd99": (5_000_000, 41, 10, 23, 0.02),
        "weka15360": (15_360_000, 20, 8, 10, 0.05),
        "gisette": (6000, 5000, 2, 2, 1.0),
        "sdss": (320_000, 5201, 8, 17, 0.8),
    }
    if name not in shapes:
        raise ValueError(
            f"unknown dataset: {name!r} (one of: {', '.join(sorted(shapes))})")
    rows, attrs, vmax, classes, distinct = shapes[name]
    return TabularStream(n_rows=rows, n_attrs=attrs, v_max=vmax, n_dec=classes,
                         distinct_fraction=distinct, seed=seed)


def scaled_paper_dataset(name: str, max_rows: int = 20000, max_attrs: int = 64,
                         seed: int = 0) -> TabularStream:
    """CPU-budget version of `paper_dataset` (same family, capped dims)."""
    t = paper_dataset(name, seed)
    return dataclasses.replace(
        t, n_rows=min(t.n_rows, max_rows), n_attrs=min(t.n_attrs, max_attrs)
    )
