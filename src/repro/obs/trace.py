"""Flight-recorder tracing: spans, a bounded ring buffer, Perfetto export.

The whole PLAR stack — fused kernels, the device-resident engine, the
multi-tenant scheduler, lineage recovery — had *no* timeline visibility
before this module: `service/metrics.py` percentiles say how long a query
took, not where the time went.  This is the Spark event-log equivalent
(DESIGN.md §3.11): every engine dispatch, scheduler batching window,
coalescing merge, checkpoint write, and recovery refold records a **span**
(name + wall-clock interval + attributes) into a bounded in-memory ring
buffer — the *flight recorder* — which exports as Chrome-trace / Perfetto
JSON so one ``ui.perfetto.dev`` load renders the whole process on a single
timeline, worker threads as separate tracks.

Design constraints, in priority order:

* **Zero overhead when disabled.**  Tracing is off by default.  A disabled
  ``span()`` returns a process-wide singleton no-op context manager — no
  object allocation, no lock, no timestamp read — so instrumentation can
  live permanently in hot paths (asserted by tests/test_obs.py with
  ``tracemalloc`` and measured in benchmarks/obs_bench.py).  The
  *attribute* kwargs a call site passes are the only per-call cost.
* **Host-side only.**  Spans wrap dispatches (``block_until_ready`` and
  friends), never traced/jitted code: a span inside a ``lax.while_loop``
  body would either break tracing or record trace-time, not run-time.
* **Bounded.**  The ring buffer holds the last ``capacity`` records
  (default 65536); a week-long serving process keeps its most recent
  history and nothing else.  ``dump()`` serializes that tail next to the
  checkpoint directory when something goes wrong (quarantine, injected
  fault) — the postmortem artifact PR 9's chaos runs were missing.
* **Thread-safe.**  Records append under one lock; span nesting is
  per-thread by construction (Perfetto reconstructs the stack from
  ``tid`` + intervals, so no explicit parent ids are needed).

Environment: ``REPRO_TRACE=1`` enables tracing at import;
``REPRO_TRACE_CAPACITY=N`` sizes the ring.
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

__all__ = [
    "SpanRecord",
    "Tracer",
    "get_tracer",
    "span",
    "event",
    "enable",
    "disable",
    "set_dump_dir",
    "request_dump",
]

# Default ring depth: at ~120 bytes/record this is <10 MB resident, yet
# covers minutes of a busy serving process (the serve-bench firehose emits
# ~40 spans/query).
_DEFAULT_CAPACITY = 65536

# Flight-recorder dumps kept per directory (older ones are GC'd): a fault
# storm must not fill the checkpoint disk with dumps.
_MAX_DUMPS = 16


class SpanRecord:
    """One completed span or instant event (plain data, ``__slots__``).

    ``ph`` is the Chrome-trace phase: ``"X"`` (complete span with
    duration) or ``"i"`` (instant event).  Times are seconds on the
    tracer's ``perf_counter`` timeline; export converts to µs.
    """

    __slots__ = ("name", "cat", "ph", "t_start", "dur", "tid", "args")

    def __init__(self, name: str, cat: str, ph: str, t_start: float,
                 dur: float, tid: int, args: Optional[Dict[str, Any]]):
        self.name = name
        self.cat = cat
        self.ph = ph
        self.t_start = t_start
        self.dur = dur
        self.tid = tid
        self.args = args

    def __repr__(self) -> str:  # debugging aid only
        return (f"SpanRecord({self.name!r}, ph={self.ph!r}, "
                f"dur={self.dur * 1e3:.3f}ms, args={self.args!r})")


class _NullSpan:
    """The disabled-mode span: one process-wide instance, no state.

    Supports the full live-span surface (``set``, context manager) so call
    sites never branch on enablement themselves.
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    """An open span: closes (and records) on ``__exit__``.

    ``set(**attrs)`` attaches attributes after entry — e.g. whether a
    dispatch hit a fresh compile is only known once it returns.
    """

    __slots__ = ("_tracer", "name", "_attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self._attrs = attrs
        self._t0 = 0.0

    def set(self, **attrs) -> "_LiveSpan":
        if self._attrs is None:
            self._attrs = attrs
        else:
            self._attrs.update(attrs)
        return self

    def __enter__(self) -> "_LiveSpan":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        if exc_type is not None:
            self.set(error=exc_type.__name__)
        self._tracer._record(
            self.name, "X", self._t0, t1 - self._t0, self._attrs)
        return False


def _category(name: str) -> str:
    """Subsystem category = the dotted prefix (``engine.dispatch`` →
    ``engine``): the Perfetto color/filter key and the ≥4-subsystems
    coverage check of benchmarks/obs_bench.py."""
    i = name.find(".")
    return name[:i] if i > 0 else name


class Tracer:
    """Thread-safe flight recorder: bounded ring of :class:`SpanRecord`.

    Disabled by default; ``enable()``/``disable()`` flip at runtime (the
    ``enabled`` read in :meth:`span` is a plain attribute load — the
    entirety of the disabled-mode cost).
    """

    def __init__(self, capacity: int = _DEFAULT_CAPACITY,
                 enabled: bool = False):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._buf: Deque[SpanRecord] = collections.deque(
            maxlen=max(int(capacity), 1))
        self._epoch = time.perf_counter()
        self.dropped = 0          # records displaced by the ring bound
        self.recorded = 0         # total records ever appended

    # -- control -------------------------------------------------------------

    def enable(self, capacity: Optional[int] = None) -> "Tracer":
        if capacity is not None and capacity != self._buf.maxlen:
            with self._lock:
                self._buf = collections.deque(self._buf,
                                              maxlen=max(int(capacity), 1))
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0
            self.recorded = 0

    @property
    def capacity(self) -> int:
        return self._buf.maxlen or 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    # -- recording -----------------------------------------------------------

    def span(self, name: str, **attrs):
        """Context manager timing one operation; no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _LiveSpan(self, name, attrs or None)

    def event(self, name: str, **attrs) -> None:
        """Instant record (retry fired, fault injected, quarantine, ...)."""
        if not self.enabled:
            return
        self._record(name, "i", time.perf_counter(), 0.0, attrs or None)

    def _record(self, name: str, ph: str, t0: float, dur: float,
                args: Optional[Dict[str, Any]]) -> None:
        rec = SpanRecord(name, _category(name), ph, t0 - self._epoch, dur,
                         threading.get_ident(), args)
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self.dropped += 1
            self._buf.append(rec)
            self.recorded += 1

    # -- introspection / export ----------------------------------------------

    def records(self, last_n: Optional[int] = None) -> List[SpanRecord]:
        """A stable copy of the ring's tail (oldest → newest)."""
        with self._lock:
            out = list(self._buf)
        return out if last_n is None else out[-last_n:]

    def trace_events(self, last_n: Optional[int] = None) -> List[Dict]:
        """Chrome-trace event dicts (the ``traceEvents`` array)."""
        pid = os.getpid()
        events: List[Dict] = []
        for r in self.records(last_n):
            ev: Dict[str, Any] = {
                "name": r.name, "cat": r.cat, "ph": r.ph,
                "ts": round(r.t_start * 1e6, 3),
                "pid": pid, "tid": r.tid,
            }
            if r.ph == "X":
                ev["dur"] = round(r.dur * 1e6, 3)
            else:
                ev["s"] = "t"          # instant event, thread-scoped
            if r.args:
                ev["args"] = {k: _jsonable(v) for k, v in r.args.items()}
            events.append(ev)
        return events

    def export(self, path: str, last_n: Optional[int] = None,
               meta: Optional[Dict[str, Any]] = None) -> str:
        """Write Perfetto/Chrome-trace JSON; returns ``path``.

        Load at https://ui.perfetto.dev (or chrome://tracing).  ``meta``
        lands in the file's ``otherData`` — dump reason, fired faults, ...
        """
        doc = {
            "traceEvents": self.trace_events(last_n),
            "displayTimeUnit": "ms",
            "otherData": {
                "recorded": self.recorded,
                "dropped": self.dropped,
                **(meta or {}),
            },
        }
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, path)
        return path


def _jsonable(v: Any) -> Any:
    """Span attributes must serialize: common scalars pass through, numpy
    scalars collapse via item(), everything else goes repr()."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    item = getattr(v, "item", None)
    if callable(item):
        try:
            return item()
        except (TypeError, ValueError):
            pass
    return repr(v)


# ---------------------------------------------------------------------------
# the process tracer + module-level conveniences (the instrumentation API)
# ---------------------------------------------------------------------------

_TRACER = Tracer(
    capacity=int(os.environ.get("REPRO_TRACE_CAPACITY", _DEFAULT_CAPACITY)),
    enabled=os.environ.get("REPRO_TRACE", "") not in ("", "0", "false"),
)


def get_tracer() -> Tracer:
    """The process-wide flight recorder."""
    return _TRACER


def span(name: str, **attrs):
    """``with span("engine.dispatch", dataset=...):`` — the one-liner every
    instrumentation point uses.  Returns the shared no-op when disabled."""
    if not _TRACER.enabled:
        return _NULL_SPAN
    return _LiveSpan(_TRACER, name, attrs or None)


def event(name: str, **attrs) -> None:
    if _TRACER.enabled:
        _TRACER._record(name, "i", time.perf_counter(), 0.0, attrs or None)


def enable(capacity: Optional[int] = None) -> Tracer:
    return _TRACER.enable(capacity)


def disable() -> Tracer:
    return _TRACER.disable()


# ---------------------------------------------------------------------------
# dump-on-failure: the flight recorder's reason to exist
# ---------------------------------------------------------------------------

_dump_state: Dict[str, Any] = {"dir": None, "seq": 0, "lock": threading.Lock()}


def set_dump_dir(path: Optional[str]) -> None:
    """Where :func:`request_dump` serializes the ring (``None`` disables).
    The server points this at its checkpoint directory, so postmortem
    traces land next to the state they explain."""
    _dump_state["dir"] = path


def request_dump(reason: str, last_n: Optional[int] = None,
                 meta: Optional[Dict[str, Any]] = None) -> Optional[str]:
    """Serialize the flight recorder's tail for postmortem analysis.

    Called at failure sites (query quarantined, fault plan fired).  A
    no-op — returning ``None`` — unless tracing is enabled *and* a dump
    directory is configured.  Keeps the newest :data:`_MAX_DUMPS` files.
    """
    d = _dump_state["dir"]
    if d is None or not _TRACER.enabled:
        return None
    safe = "".join(c if c.isalnum() or c in "-_." else "-" for c in reason)
    with _dump_state["lock"]:
        _dump_state["seq"] += 1
        seq = _dump_state["seq"]
    try:
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, f"flightrec-{seq:04d}-{safe[:64]}.json")
        _TRACER.export(path, last_n=last_n,
                       meta={"reason": reason, "unix_time": int(time.time()),
                             **(meta or {})})
        _gc_dumps(d)
        return path
    except OSError:
        return None  # a full disk must never take the failing path down too


def _gc_dumps(d: str) -> None:
    try:
        dumps = sorted(f for f in os.listdir(d)
                       if f.startswith("flightrec-") and f.endswith(".json"))
        for f in dumps[:-_MAX_DUMPS]:
            os.unlink(os.path.join(d, f))
    except OSError:
        pass
