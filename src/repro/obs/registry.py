"""Process-wide metrics registry: counters, gauges, histograms, exposition.

Before this module every subsystem kept its own ad-hoc counters —
``ServiceMetrics.counters``, the server's ``stats`` dict, the autotune
``_CACHE_STATS`` pair, nothing at all for engine compiles — each with its
own snapshot idiom.  The registry (DESIGN.md §3.11) is the one place they
all land:

* :class:`Counter` — monotonically increasing (``inc``);
* :class:`Gauge`   — last-value (``set``/``inc``): ladder rung, final K,
  queue depth;
* :class:`Histogram` — fixed cumulative buckets + sum/count, for
  latency-shaped values (per-iteration seconds, merge durations).

``snapshot()`` renders everything as one flat dict (benchmarks, the CLI
stats line); ``render_prometheus()`` emits the Prometheus text exposition
format v0.0.4 that ``reduce_server --metrics-port`` serves.

Everything is host-side plain Python guarded by one registry lock —
instruments hold a reference to it, so an ``inc`` is a lock + int add.
Like the tracer, never call these inside jitted code.

Two scopes by convention:

* the **process registry** (:func:`get_registry`) for process-global
  subsystems: engine compiles, autotune caches, recovery, checkpoints,
  fault injections;
* **per-instance registries** for objects that exist many times per
  process (each ``ReductServer``/``ServiceMetrics`` owns one), merged
  into one exposition by :func:`render_prometheus` callers passing
  ``extra=``.
"""
from __future__ import annotations

import math
import re
import threading
from collections.abc import MutableMapping
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "CounterMap",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "counter",
    "gauge",
    "histogram",
    "render_prometheus",
]

# Latency-shaped default buckets (seconds): 100 µs … 30 s, roughly ×2.5.
DEFAULT_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
                   0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)

_NAME_OK = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*\Z")
_NAME_FIX = re.compile(r"[^a-zA-Z0-9_:]")


def sanitize(name: str) -> str:
    """Prometheus-legal metric name (invalid chars → ``_``)."""
    if _NAME_OK.match(name):
        return name
    fixed = _NAME_FIX.sub("_", name)
    if not fixed or not _NAME_OK.match(fixed[0]):
        fixed = "_" + fixed
    return fixed


class Counter:
    """Monotonic counter.  ``inc(by)`` only; negative increments refused."""

    __slots__ = ("name", "help", "_value", "_lock")
    kind = "counter"

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._value = 0
        self._lock = lock

    def inc(self, by: int = 1) -> None:
        if by < 0:
            raise ValueError(f"counter {self.name} cannot decrease (by={by})")
        with self._lock:
            self._value += by

    @property
    def value(self):
        with self._lock:
            return self._value

    def set(self, value) -> None:
        """Rebase to an absolute value (re-basing pre-existing counter dicts
        like ServiceMetrics.counters needs assignment semantics).  Must not
        go backwards — that would violate the counter contract scrapers
        rely on."""
        with self._lock:
            if value < self._value:
                raise ValueError(
                    f"counter {self.name} cannot decrease "
                    f"({self._value} -> {value})")
            self._value = value


class Gauge:
    """Last-value instrument: ``set``, or ``inc`` with any sign."""

    __slots__ = ("name", "help", "_value", "_lock")
    kind = "gauge"

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = lock

    def set(self, value) -> None:
        with self._lock:
            self._value = value

    def inc(self, by=1) -> None:
        with self._lock:
            self._value += by

    @property
    def value(self):
        with self._lock:
            return self._value


class Histogram:
    """Cumulative fixed-bucket histogram (the Prometheus shape).

    ``observe(v)`` bumps every bucket with ``le ≥ v`` implicitly by
    storing per-bucket counts and cumulating at render time — one int add
    per observe, not len(buckets).
    """

    __slots__ = ("name", "help", "buckets", "_counts", "_sum", "_count",
                 "_lock")
    kind = "histogram"

    def __init__(self, name: str, help: str, lock: threading.Lock,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets: Tuple[float, ...] = tuple(sorted(buckets))
        self._counts = [0] * (len(self.buckets) + 1)   # +1: the +Inf bucket
        self._sum = 0.0
        self._count = 0
        self._lock = lock

    def observe(self, value: float) -> None:
        v = float(value)
        # first bucket with le >= v (linear scan: bucket lists are short and
        # latency values cluster low; bisect would pay more in call overhead)
        i = 0
        bs = self.buckets
        n = len(bs)
        while i < n and v > bs[i]:
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def cumulative(self) -> List[Tuple[str, int]]:
        """``[(le_label, cumulative_count), ...]`` ending with ``+Inf``."""
        with self._lock:
            counts = list(self._counts)
        out, acc = [], 0
        for b, c in zip(self.buckets, counts):
            acc += c
            out.append((_fmt_le(b), acc))
        out.append(("+Inf", acc + counts[-1]))
        return out


def _fmt_le(b: float) -> str:
    if b == int(b) and abs(b) < 1e15:
        return str(int(b))
    return repr(b)


class MetricsRegistry:
    """A namespace of instruments with one lock and one exposition.

    ``counter/gauge/histogram`` are get-or-create: the same name always
    returns the same instrument, and a kind clash raises — two subsystems
    silently sharing a name under different types is a bug, not a merge.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}

    def _get(self, name: str, kind, help: str, **kw):
        name = sanitize(name)
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = kind(name, help, self._lock, **kw)
                self._instruments[name] = inst
            elif not isinstance(inst, kind):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{inst.kind}, requested {kind.kind}")
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, help, buckets=buckets)

    def instruments(self) -> List[Any]:
        with self._lock:
            return list(self._instruments.values())

    def clear(self) -> None:
        """Drop every instrument (tests only — production registries are
        append-only)."""
        with self._lock:
            self._instruments.clear()

    # -- views ---------------------------------------------------------------

    def snapshot(self) -> Dict[str, float]:
        """One flat dict: counters/gauges by name, histograms as
        ``<name>_count`` / ``<name>_sum`` — the benchmark/CLI view."""
        out: Dict[str, float] = {}
        for inst in self.instruments():
            if isinstance(inst, Histogram):
                out[f"{inst.name}_count"] = inst.count
                out[f"{inst.name}_sum"] = inst.sum
            else:
                out[inst.name] = inst.value
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format v0.0.4."""
        lines: List[str] = []
        for inst in self.instruments():
            if inst.help:
                lines.append(f"# HELP {inst.name} {inst.help}")
            lines.append(f"# TYPE {inst.name} {inst.kind}")
            if isinstance(inst, Histogram):
                for le, c in inst.cumulative():
                    lines.append(f'{inst.name}_bucket{{le="{le}"}} {c}')
                lines.append(f"{inst.name}_sum {_fmt_value(inst.sum)}")
                lines.append(f"{inst.name}_count {inst.count}")
            else:
                lines.append(f"{inst.name} {_fmt_value(inst.value)}")
        return "\n".join(lines) + ("\n" if lines else "")


class CounterMap(MutableMapping):
    """``defaultdict(int)``-shaped facade over registry counters.

    Pre-registry code treats its counters as plain dicts —
    ``m["x"] += 1``, ``m.get("x", 0)``, ``dict(m)`` — and tests assert
    those reads exactly.  This adapter keeps that surface byte-compatible
    while backing every key with a registry :class:`Counter` (name =
    ``prefix + key``), so the same bumps show up in ``snapshot()`` and the
    Prometheus exposition for free.

    Reads of unknown keys return 0 and register the counter (defaultdict
    semantics); assignment goes through :meth:`Counter.set`, so the
    monotonicity contract still holds.  Deletion is refused: registries
    are append-only.
    """

    def __init__(self, registry: "MetricsRegistry", prefix: str = "",
                 initial: Iterable[str] = (), help: str = "") -> None:
        self._registry = registry
        self._prefix = prefix
        self._help = help
        self._counters: Dict[str, Counter] = {}
        for key in initial:
            self._ensure(key)

    def _ensure(self, key: str) -> Counter:
        c = self._counters.get(key)
        if c is None:
            c = self._registry.counter(self._prefix + key, self._help)
            self._counters[key] = c
        return c

    def __getitem__(self, key: str) -> int:
        return self._ensure(key).value

    def __setitem__(self, key: str, value) -> None:
        self._ensure(key).set(value)

    def __delitem__(self, key: str) -> None:
        raise TypeError("registry-backed counters cannot be deleted")

    def __iter__(self):
        return iter(list(self._counters))

    def __len__(self) -> int:
        return len(self._counters)

    def __contains__(self, key) -> bool:
        return key in self._counters

    def get(self, key, default=0):
        c = self._counters.get(key)
        return c.value if c is not None else default

    def copy(self) -> Dict[str, int]:
        """A detached plain-dict snapshot (what ``dict.copy()`` gave)."""
        return dict(self)

    def __repr__(self) -> str:
        return f"CounterMap({dict(self)!r})"


def _fmt_value(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if math.isnan(f):
        return "NaN"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


# ---------------------------------------------------------------------------
# the process registry + conveniences
# ---------------------------------------------------------------------------

_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide registry (engine, autotune, recovery, checkpoints,
    faults).  Per-server counters live on the server's own registry and are
    merged at exposition time (:func:`render_prometheus`)."""
    return _REGISTRY


def counter(name: str, help: str = "") -> Counter:
    return _REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return _REGISTRY.gauge(name, help)


def histogram(name: str, help: str = "",
              buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
    return _REGISTRY.histogram(name, help, buckets=buckets)


def render_prometheus(
        extra: Iterable[MetricsRegistry] = ()) -> str:
    """The full exposition: the process registry plus any per-instance
    registries (``reduce_server --metrics-port`` passes the live server's)."""
    parts = [_REGISTRY.render_prometheus()]
    parts.extend(r.render_prometheus() for r in extra)
    return "".join(parts)
