"""Observability: flight-recorder tracing + process metrics (DESIGN.md §3.11).

Two small host-side pieces, imported by every instrumented subsystem
(``repro.obs`` deliberately imports nothing from the rest of the repo, and
no JAX — it must be safe to call from any layer, including module import
time):

* :mod:`repro.obs.trace` — the span API and bounded ring buffer (flight
  recorder) with Perfetto/Chrome-trace export and dump-on-failure.
  **Disabled by default, zero-overhead when disabled.**
* :mod:`repro.obs.registry` — process-wide counter/gauge/histogram
  registry with a flat ``snapshot()`` and Prometheus text exposition.
  **Always on** (a lock + int add per bump).

The one-screen instrumentation idiom::

    from repro import obs

    with obs.span("engine.dispatch", dataset=name, measure=delta):
        result = jax.block_until_ready(runner(...))
    obs.counter("plar_engine_runs_total").inc()

Enable tracing with ``obs.enable()`` (or ``REPRO_TRACE=1``), export with
``obs.get_tracer().export("trace.json")``, read at https://ui.perfetto.dev.
"""
from .registry import (
    DEFAULT_BUCKETS,
    Counter,
    CounterMap,
    Gauge,
    Histogram,
    MetricsRegistry,
    counter,
    gauge,
    get_registry,
    histogram,
    render_prometheus,
)
from .trace import (
    SpanRecord,
    Tracer,
    disable,
    enable,
    event,
    get_tracer,
    request_dump,
    set_dump_dir,
    span,
)

__all__ = [
    "DEFAULT_BUCKETS",
    "Counter",
    "CounterMap",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SpanRecord",
    "Tracer",
    "counter",
    "disable",
    "enable",
    "event",
    "gauge",
    "get_registry",
    "get_tracer",
    "histogram",
    "render_prometheus",
    "request_dump",
    "set_dump_dir",
    "span",
]
