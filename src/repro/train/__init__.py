from .checkpoint import CheckpointManager
from .optimizer import AdamW, AdamWState, constant_schedule, cosine_schedule
from .trainer import TrainConfig, Trainer, make_train_step

__all__ = [
    "CheckpointManager", "AdamW", "AdamWState", "constant_schedule",
    "cosine_schedule", "TrainConfig", "Trainer", "make_train_step",
]
