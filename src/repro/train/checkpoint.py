"""Sharded checkpointing with atomic commits, retention and elastic restore.

Layout (one directory per step)::

    <dir>/step_000120/
        manifest.json        # step, flat key list, shapes/dtypes, extra metadata
        arrays.npz           # flat {key: ndarray}; written by the save host
        COMMITTED            # sentinel written last → crash-safe

* **Atomicity**: everything lands in ``step_NNN.tmp`` and is renamed after
  the sentinel is in place; a restart ignores uncommitted directories.
* **Elastic restore**: arrays are stored logically (unsharded); restore
  `device_put`s against whatever mesh/shardings the *new* topology provides,
  so a 512-chip checkpoint restores onto 256 or 1024 chips unchanged.  (At
  real multi-host scale arrays stream per-host shards; on this single-host
  target the save host materializes the full array — same external layout.)
* **Async**: `save(..., blocking=False)` hands the host arrays to a writer
  thread; training continues, `wait()` joins before the next save.
* **Retention**: keep the last `keep` committed steps, delete older.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import warnings
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any

_SENTINEL = "COMMITTED"

# What a truncated/corrupt checkpoint surfaces as: a half-written npz is a
# BadZipFile or EOFError, a clipped manifest a JSONDecodeError, a missing
# array key a KeyError, a garbage header a ValueError/OSError.
_CORRUPT_ERRORS = (OSError, EOFError, KeyError, ValueError,
                   json.JSONDecodeError, zipfile.BadZipFile)


def _atomic_write(path: str, writer) -> None:
    """Crash-safe file write: ``writer(tmp_path)`` then atomic ``os.replace``.

    A crash mid-write leaves only ``<path>.tmp`` — never a truncated file at
    the final name — so a reader can trust any file that exists."""
    tmp = path + ".tmp"
    writer(tmp)
    os.replace(tmp, path)


def _flatten(tree: Pytree, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Pytree:
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Pytree, *, extra: Optional[dict] = None,
             blocking: bool = True) -> str:
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device → host copy
        if blocking:
            return self._write(step, host, extra or {})
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True
        )
        self._thread.start()
        return self._path(step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def _write(self, step: int, host: Dict[str, np.ndarray], extra: dict) -> str:
        final = self._path(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        # each file lands via its own tmp + os.replace: a crash at any point
        # leaves either no file or a complete one, never a truncated npz
        def dump_npz(p):
            with open(p, "wb") as f:  # file object: savez must not append .npz
                np.savez(f, **host)

        _atomic_write(os.path.join(tmp, "arrays.npz"), dump_npz)
        manifest = {
            "step": step,
            "keys": sorted(host),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": {k: str(v.dtype) for k, v in host.items()},
            "time": time.time(),
            "extra": extra,
        }

        def dump_json(p):
            with open(p, "w") as f:
                json.dump(manifest, f)

        _atomic_write(os.path.join(tmp, "manifest.json"), dump_json)
        self._pre_commit(tmp)
        _atomic_write(os.path.join(tmp, _SENTINEL),
                      lambda p: open(p, "w").write("ok\n"))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _pre_commit(self, tmp_dir: str) -> None:
        """Hook between the array/manifest writes and the commit (sentinel +
        rename).  Subclasses use it for fault injection: raising here aborts
        the step with nothing committed, proving the atomicity contract."""

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(full, _SENTINEL)):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_step(self, step: int) -> Tuple[Dict[str, np.ndarray], dict]:
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as npz:
            flat = {k: npz[k] for k in manifest["keys"]}
        return flat, manifest

    def restore(self, step: Optional[int] = None, *, shardings: Optional[Pytree] = None
                ) -> Tuple[int, Pytree, dict]:
        """Returns (step, tree, extra).  `shardings` (same structure, leaves
        NamedSharding or None) re-shards onto the current topology.

        With ``step=None`` (auto-pick), a truncated or corrupt step — partial
        write that still got committed, bit rot, manual tampering — is
        *skipped with a warning* and the next older committed step is tried,
        so a restart degrades to slightly-older state instead of dying
        mid-startup.  An explicitly requested ``step`` still raises: the
        caller asked for that step specifically and silently substituting
        another would be wrong.
        """
        if step is not None:
            flat, manifest = self._load_step(step)
        else:
            candidates = self.all_steps()
            if not candidates:
                raise FileNotFoundError(
                    f"no committed checkpoints in {self.directory}")
            flat = manifest = None
            for s in reversed(candidates):
                try:
                    flat, manifest = self._load_step(s)
                    step = s
                    break
                except _CORRUPT_ERRORS as e:
                    warnings.warn(
                        f"skipping corrupt checkpoint {self._path(s)}: "
                        f"{type(e).__name__}: {e}", stacklevel=2)
            if flat is None:
                raise FileNotFoundError(
                    f"no readable checkpoints in {self.directory} "
                    f"(all {len(candidates)} committed steps are corrupt)")
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)

            def put(key, arr):
                s = flat_sh.get(key)
                return jax.device_put(arr, s) if s is not None else jax.device_put(arr)

            tree = _unflatten({k: put(k, v) for k, v in flat.items()})
        return step, tree, manifest.get("extra", {})
