"""Sharded checkpointing with atomic commits, retention and elastic restore.

Layout (one directory per step)::

    <dir>/step_000120/
        manifest.json        # step, flat key list, shapes/dtypes, extra metadata
        arrays.npz           # flat {key: ndarray}; written by the save host
        COMMITTED            # sentinel written last → crash-safe

* **Atomicity**: everything lands in ``step_NNN.tmp`` and is renamed after
  the sentinel is in place; a restart ignores uncommitted directories.
* **Elastic restore**: arrays are stored logically (unsharded); restore
  `device_put`s against whatever mesh/shardings the *new* topology provides,
  so a 512-chip checkpoint restores onto 256 or 1024 chips unchanged.  (At
  real multi-host scale arrays stream per-host shards; on this single-host
  target the save host materializes the full array — same external layout.)
* **Async**: `save(..., blocking=False)` hands the host arrays to a writer
  thread; training continues, `wait()` joins before the next save.
* **Retention**: keep the last `keep` committed steps, delete older.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any

_SENTINEL = "COMMITTED"


def _flatten(tree: Pytree, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Pytree:
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Pytree, *, extra: Optional[dict] = None,
             blocking: bool = True) -> str:
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device → host copy
        if blocking:
            return self._write(step, host, extra or {})
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True
        )
        self._thread.start()
        return self._path(step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def _write(self, step: int, host: Dict[str, np.ndarray], extra: dict) -> str:
        final = self._path(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "keys": sorted(host),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": {k: str(v.dtype) for k, v in host.items()},
            "time": time.time(),
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, _SENTINEL), "w") as f:
            f.write("ok\n")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(full, _SENTINEL)):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, shardings: Optional[Pytree] = None
                ) -> Tuple[int, Pytree, dict]:
        """Returns (step, tree, extra).  `shardings` (same structure, leaves
        NamedSharding or None) re-shards onto the current topology."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.directory}")
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        npz = np.load(os.path.join(path, "arrays.npz"))
        flat = {k: npz[k] for k in manifest["keys"]}
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)

            def put(key, arr):
                s = flat_sh.get(key)
                return jax.device_put(arr, s) if s is not None else jax.device_put(arr)

            tree = _unflatten({k: put(k, v) for k, v in flat.items()})
        return step, tree, manifest.get("extra", {})
