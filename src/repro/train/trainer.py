"""Training loop: sharded train_step, grad accumulation, fault tolerance.

Production behaviors implemented here (DESIGN.md §3.4):

* **Sharded step** — params/optimizer state placed by ParamDef specs; batch
  over ('pod','data'); one jitted `train_step` reused for the dry-run.
* **Grad accumulation** — microbatch `lax.scan` inside the step (activation
  memory ∝ 1/n_micro; gradient memory unchanged).
* **Checkpoint/restart** — every `ckpt_every` steps via CheckpointManager
  (async, atomic); `Trainer.restore()` resumes bit-exact (same data stream
  position — the pipeline is indexed by step, never by an iterator cursor).
* **Preemption** — SIGTERM/SIGINT set a flag; the loop checkpoints and exits
  cleanly at the next step boundary.
* **Straggler mitigation** — per-step wall time EMA; steps slower than
  `straggler_factor ×` EMA are logged with their step index.  (On real
  multi-host topologies this feeds the scheduler's replace-node decision;
  here it is surfaced in metrics so tests can assert the detector fires.)
"""
from __future__ import annotations

import dataclasses
import signal
import time
from functools import cached_property
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.api import BATCH_AXES, sharding_for, use_mesh
from repro.models import build_model
from repro.models.config import ArchConfig
from .checkpoint import CheckpointManager
from .optimizer import AdamW, AdamWState, cosine_schedule

Pytree = Any


@dataclasses.dataclass
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    microbatches: int = 1
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_dir: Optional[str] = None
    straggler_factor: float = 2.0
    log_every: int = 10


class TrainState:
    """Tiny immutable train-state record (params + AdamW state)."""

    def __init__(self, params: Pytree, opt: AdamWState):
        self.params = params
        self.opt = opt

    def as_tree(self):
        return {"params": self.params, "opt_m": self.opt.m, "opt_v": self.opt.v,
                "opt_step": self.opt.step}

    @staticmethod
    def from_tree(tree) -> "TrainState":
        return TrainState(tree["params"],
                          AdamWState(tree["opt_step"], tree["opt_m"], tree["opt_v"]))


def make_train_step(model, optimizer: AdamW, *, microbatches: int = 1) -> Callable:
    """(state, batch) → (state, metrics); microbatch scan when requested."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: Dict[str, Any], batch: Dict[str, jnp.ndarray]):
        params = state["params"]
        opt_state = AdamWState(state["opt_step"], state["opt_m"], state["opt_v"])

        if microbatches > 1:
            def micro(carry, mb):
                gsum = carry
                (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return gsum, metrics

            split = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:]),
                batch,
            )
            gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, metrics = jax.lax.scan(micro, gzero, split)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

        new_params, new_opt, gnorm = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, grad_norm=gnorm, lr=optimizer.lr(new_opt.step))
        new_state = {"params": new_params, "opt_m": new_opt.m, "opt_v": new_opt.v,
                     "opt_step": new_opt.step}
        return new_state, metrics

    return train_step


class Trainer:
    def __init__(self, arch_cfg: ArchConfig, train_cfg: TrainConfig,
                 mesh: Optional[Mesh] = None):
        self.cfg = arch_cfg
        self.tc = train_cfg
        self.mesh = mesh
        self.model = build_model(arch_cfg)
        self.optimizer = AdamW(
            lr=cosine_schedule(train_cfg.peak_lr, train_cfg.warmup_steps,
                               train_cfg.total_steps),
            weight_decay=train_cfg.weight_decay,
            grad_clip=train_cfg.grad_clip,
            moment_dtype=train_cfg.moment_dtype,
        )
        self.ckpt = (CheckpointManager(train_cfg.ckpt_dir, keep=train_cfg.ckpt_keep)
                     if train_cfg.ckpt_dir else None)
        self._preempted = False
        self.step_times: list = []
        self.straggler_steps: list = []

    # -- sharding ----------------------------------------------------------
    def state_shardings(self):
        if self.mesh is None:
            return None
        with use_mesh(self.mesh):
            psh = self.model.param_shardings(self.mesh)
            return {"params": psh, "opt_m": psh, "opt_v": psh,
                    "opt_step": sharding_for(P(), self.mesh)}

    def batch_sharding(self):
        if self.mesh is None:
            return None
        return sharding_for(P(BATCH_AXES), self.mesh)

    # -- lifecycle -----------------------------------------------------------
    def init_state(self, seed: int = 0) -> Dict[str, Any]:
        with use_mesh(self.mesh):
            params = self.model.init(jax.random.PRNGKey(seed))
            opt = self.optimizer.init(params)
        return {"params": params, "opt_m": opt.m, "opt_v": opt.v, "opt_step": opt.step}

    @cached_property
    def step_fn(self):
        fn = make_train_step(self.model, self.optimizer,
                             microbatches=self.tc.microbatches)
        jitted = jax.jit(fn, donate_argnums=(0,))

        def run(state, batch):
            with use_mesh(self.mesh):
                return jitted(state, batch)

        return run

    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    # -- loop ------------------------------------------------------------------
    def restore_or_init(self, seed: int = 0):
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            step, tree, extra = self.ckpt.restore(shardings=self.state_shardings())
            return int(step), tree
        return 0, self.init_state(seed)

    def fit(self, data_fn: Callable[[int], Dict[str, np.ndarray]],
            *, steps: Optional[int] = None, start_step: Optional[int] = None,
            state: Optional[Dict[str, Any]] = None):
        """data_fn(step) → batch dict (deterministic per step: restart-safe)."""
        total = steps if steps is not None else self.tc.total_steps
        if state is None:
            start, state = self.restore_or_init()
        else:
            start = start_step or 0
        history = []
        ema = None
        step = start
        steps_done = 0
        while step < total:
            batch = data_fn(step)
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            steps_done += 1
            if steps_done == 1:
                pass  # first step is compile-dominated: never seeds the EMA
            elif ema is None:
                ema = dt
            elif dt > self.tc.straggler_factor * ema:
                self.straggler_steps.append(step)
            else:
                ema = 0.9 * ema + 0.1 * dt
            step += 1
            if step % self.tc.log_every == 0 or step == total:
                history.append({"step": step, "loss": float(metrics["loss"]),
                                "grad_norm": float(metrics["grad_norm"]),
                                "sec_per_step": dt})
            should_ckpt = self.ckpt is not None and (
                step % self.tc.ckpt_every == 0 or step == total or self._preempted)
            if should_ckpt:
                self.ckpt.save(step, state, extra={"arch": self.cfg.name},
                               blocking=False)
            if self._preempted:
                if self.ckpt is not None:
                    self.ckpt.wait()
                break
        if self.ckpt is not None:
            self.ckpt.wait()
        return state, history
