"""Optimizers + schedules (no external deps): AdamW with sharded state.

Moment dtype is configurable: ``moment_dtype="bfloat16"`` halves optimizer
HBM (the ≥100B archs need it to fit the v5e budget — see EXPERIMENTS.md
§Dry-run memory table); the update math still runs in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Pytree
    v: Pytree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"

    def init(self, params: Pytree) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype)
        z = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(z, params),
            v=jax.tree.map(z, params),
        )

    def update(self, grads: Pytree, state: AdamWState, params: Pytree
               ) -> Tuple[Pytree, AdamWState, jnp.ndarray]:
        """Returns (new_params, new_state, grad_norm)."""
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)))
        if self.grad_clip:
            scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)

        step = state.step + 1
        lr = self.lr(step)
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.moment_dtype)

        def upd(p, g, m, v):
            mf = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            vf = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mh = mf / c1
            vh = vf / c2
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (
                (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mf.astype(dt),
                vf.astype(dt),
            )

        out = jax.tree.map(upd, params, gf, state.m, state.v)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step, new_m, new_v), gnorm


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(s < warmup, warm, cos)

    return lr


def constant_schedule(value: float):
    return lambda step: jnp.full((), value, jnp.float32)
