"""Serving engine: batched prefill + continuous-batching decode.

A deliberately small but real engine:

* requests enter a queue; the engine packs up to `max_batch` live sequences;
* prefill runs per request (left-padded into the shared KV cache capacity);
* decode steps run the whole live batch through one jitted `decode` call;
* finished sequences (EOS or budget) free their slot, the queue refills it
  (continuous batching), and the cache slot is re-primed by the next
  request's prefill.

The decode step is the same `model.decode` the dry-run lowers for the
``decode_32k`` / ``long_500k`` cells — serving and dry-run share one code
path, which is the point.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.config import ArchConfig
from repro.service.metrics import RequestTiming


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] token ids
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # latency accounting (same stamp shape as service.ReduceRequest)
    timing: RequestTiming = dataclasses.field(default_factory=RequestTiming)
    # filled by the engine:
    output: Optional[List[int]] = None
    latency_s: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 4,
                 cache_len: int = 128, greedy: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.greedy = greedy
        self._decode = jax.jit(self.model.decode)

    def _prefill_one(self, prompt: np.ndarray):
        toks = jnp.asarray(prompt[None], jnp.int32)
        logits, cache, lengths = self.model.prefill(
            self.params, {"tokens": toks}, cache_len=self.cache_len
        )
        return logits, cache, lengths

    def serve(self, requests: List[Request]) -> List[Request]:
        """Run all requests to completion with continuous batching."""
        queue = collections.deque(requests)   # popleft is O(1), not O(n)
        for req in queue:
            req.timing.mark_enqueue()
        # slots: per-slot state (cache is kept per-slot, batch=1, and decode
        # batches are formed by stacking slot caches — simple and correct;
        # a production engine would use a paged cache, noted in DESIGN.md)
        live: List[Dict[str, Any]] = []

        def admit():
            while queue and len(live) < self.max_batch:
                req = queue.popleft()
                req.timing.mark_start()
                logits, cache, lengths = self._prefill_one(req.prompt)
                tok = int(jnp.argmax(logits[0, -1]))
                live.append({
                    "req": req, "cache": cache, "lengths": lengths,
                    "tokens": [tok],
                })

        admit()
        while live:
            # stack slot caches into one batched decode call
            caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1), *
                                  [s["cache"] for s in live]) if len(live) > 1 \
                else live[0]["cache"]
            lengths = jnp.concatenate([s["lengths"] for s in live]) if len(live) > 1 \
                else live[0]["lengths"]
            last = jnp.asarray([[s["tokens"][-1]] for s in live], jnp.int32)
            logits, caches, lengths = self._decode(self.params, caches, last, lengths)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))

            done_idx = []
            for i, slot in enumerate(live):
                slot["tokens"].append(int(nxt[i]))
                # unstack this slot's cache/lengths view
                slot["cache"] = jax.tree.map(lambda x, i=i: x[:, i : i + 1], caches)
                slot["lengths"] = lengths[i : i + 1]
                req = slot["req"]
                hit_eos = req.eos_id is not None and int(nxt[i]) == req.eos_id
                if len(slot["tokens"]) >= req.max_new_tokens or hit_eos:
                    req.output = slot["tokens"]
                    req.timing.mark_done()
                    req.latency_s = req.timing.service_s
                    done_idx.append(i)
            for i in reversed(done_idx):
                live.pop(i)
            admit()
        return requests
