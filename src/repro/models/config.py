"""Architecture + shape configuration for the model substrate.

One :class:`ArchConfig` describes any of the 10 assigned architectures
(dense / MoE / hybrid / SSM / VLM / audio enc-dec).  ``reduced()`` derives the
CPU smoke-test config of the same family (few layers, narrow, tiny vocab) —
the full config is only ever lowered via the dry-run (ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set; identical across LM-family archs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden width
    moe_every: int = 1               # MoE block every k-th layer (jamba: 2)
    n_shared_experts: int = 0        # always-on experts (kimi k2)
    capacity_factor: float = 1.25

    # --- hybrid (jamba): attention block every `attn_every` layers ---
    attn_every: int = 0              # 0 → all layers are attention
    # --- SSM (mamba) ---
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # default ceil(d_model / 16)
    ssm_compute_dtype: str = "float32"  # §Perf: bf16 halves the scan tensors
    # --- rwkv6 ---
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64

    # --- encoder-decoder (seamless) ---
    enc_layers: int = 0              # 0 → decoder-only
    dec_layers: int = 0

    # --- frontends (stub) ---
    frontend: Optional[str] = None   # "vision" | "audio"
    frontend_dim: int = 0            # precomputed patch/frame feature width
    frontend_tokens: int = 0         # prefix positions fed by the frontend

    # --- misc ---
    activation: str = "swiglu"       # swiglu | geglu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: hidden ×= sqrt(d_model)
    qk_norm: bool = False            # qwen3
    window: Optional[int] = None     # sliding-window size for long-context attn
    logit_softcap: Optional[float] = None

    # --- numerics / distribution ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    fsdp: bool = True                # ZeRO shard params/optimizer over 'data'
    remat: bool = True
    remat_policy: str = "nothing"    # nothing | dots (save matmul outputs)
    scan_unroll: bool = False        # unroll the layer scan (dry-run analysis)
    attn_naive: bool = False         # S² einsum attention (probe cost analysis)
    flash_bwd: bool = False          # §Perf: streaming custom-vjp attention bwd
    moe_weight_stationary: bool = False  # §Perf: serve-time MoE island keeps the
    # experts' 2-D (model × data) storage sharding and all-gathers the (few)
    # decode tokens instead of all-gathering expert weights every layer
    sub_quadratic: bool = False      # supports long_500k (SSM/hybrid/linear)

    note: str = ""

    # -- derived ----------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind: 'attn' | 'mamba' | 'rwkv'."""
        if self.family == "ssm":
            return ("rwkv",) * self.n_layers
        if self.family == "hybrid":
            # jamba: 1 attention layer per `attn_every` (paper: 1:7 interleave,
            # attention at position attn_every-1 of each period)
            kinds = []
            for i in range(self.n_layers):
                kinds.append("attn" if (i % self.attn_every) == self.attn_every - 1 else "mamba")
            return tuple(kinds)
        return ("attn",) * self.n_layers

    def layer_is_moe(self, i: int) -> bool:
        return self.n_experts > 0 and (i % self.moe_every) == self.moe_every - 1

    def param_count(self) -> int:
        """Total parameters (embedding + blocks), analytic."""
        d, hd = self.d_model, self.hd
        total = self.vocab * d  # embed
        if not self.tie_embeddings:
            total += self.vocab * d
        n_blocks = self.n_layers if not self.is_encdec else self.enc_layers + self.dec_layers
        for i in range(n_blocks):
            kind = self.block_kinds()[i % self.n_layers] if not self.is_encdec else "attn"
            if kind == "attn":
                total += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            elif kind == "mamba":
                di, ds, r = self.ssm_d_inner, self.ssm_d_state, self.dt_rank
                total += d * 2 * di + di * self.ssm_d_conv + di * (r + 2 * ds) + r * di + di * ds + di + di * d
            elif kind == "rwkv":
                total += 5 * d * d + d * d  # r,k,v,g,o + w-lora approx
                total += 2 * d * self.d_ff  # channel mix
            if kind != "rwkv":
                if self.layer_is_moe(i):
                    e, fe = self.n_experts, self.moe_d_ff
                    total += d * e  # router
                    total += e * 3 * d * fe
                    total += self.n_shared_experts * 3 * d * fe
                else:
                    mult = 3 if self.activation in ("swiglu", "geglu") else 2
                    total += mult * d * self.d_ff
            total += 2 * d  # norms
        return total

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE counts top_k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        n_moe = sum(1 for i in range(self.n_layers) if self.layer_is_moe(i))
        inactive = n_moe * (self.n_experts - self.top_k) * 3 * d * self.moe_d_ff
        return total - inactive

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        changes = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 0 else 0,
            head_dim=32,
            d_ff=256,
            vocab=512,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            note=f"reduced smoke config of {self.name}",
        )
        if self.n_experts:
            changes.update(n_experts=4, top_k=2, moe_d_ff=64)
        if self.attn_every:
            changes.update(attn_every=2, n_layers=4)
        if self.family == "ssm":
            changes.update(rwkv_head_dim=32, rwkv_decay_lora=16, d_ff=224)
        if self.is_encdec:
            changes.update(enc_layers=2, dec_layers=2, n_layers=2)
        if self.frontend:
            changes.update(frontend_dim=64, frontend_tokens=8)
        if self.ssm_dt_rank:
            changes.update(ssm_dt_rank=8)
        return dataclasses.replace(self, **changes)
