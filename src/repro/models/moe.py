"""Mixture-of-Experts layer with expert parallelism over the 'model' axis.

Design (DESIGN.md §3.3, EP): the GShard-style ``[T, E, C]`` dispatch einsum is
O(T²·k/E) memory and is unusable at 10⁶-token batches (a 17 GB/shard dispatch
tensor for qwen3 train_4k).  Instead we use *sort-based capacity dispatch*
inside ``shard_map``:

  1. tokens are sharded over ('pod','data') and replicated over 'model';
  2. each model shard owns ``E_loc = E / tp`` experts;
  3. router logits → top-k (identical on every model shard — same inputs,
     same weights, deterministic argsort);
  4. per shard: flatten (token, expert) pairs, stable-sort by expert id,
     rank-in-segment, drop beyond per-expert capacity, gather into an
     ``[E_loc·C, D]`` buffer (static shape), two batched matmuls, weighted
     scatter-add back to token order;
  5. one ``psum`` over 'model' combines expert outputs (each token's k
     experts live on arbitrary shards) — the same collective pattern as the
     TP MLP, so EP costs no extra all_to_all on this mesh.

Dropped-token accounting: capacity C = ceil(T_loc·k/E · capacity_factor);
overflow tokens lose that expert's contribution (standard capacity dropping;
the router's gate renormalization keeps the output well-scaled).

Without an active mesh (CPU smoke tests) the same inner function runs with
E_loc = E and no psum — bitwise the tp=1 case.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.api import (
    BATCH_AXES, FSDP_AXIS, TP_AXIS, active_mesh, axis_size, shard_map,
)
from .layers import ParamDef
from .mlp import _act


def moe_defs(cfg) -> Dict[str, ParamDef]:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    dt = cfg.param_dtype
    defs = {
        "router": ParamDef((d, e), (FSDP_AXIS, None), "fan_in", dt),
        "wg": ParamDef((e, d, fe), (TP_AXIS, FSDP_AXIS, None), "fan_in", dt,
                       keep_fsdp=True),
        "wu": ParamDef((e, d, fe), (TP_AXIS, FSDP_AXIS, None), "fan_in", dt,
                       keep_fsdp=True),
        "wd": ParamDef((e, fe, d), (TP_AXIS, None, FSDP_AXIS), "fan_in", dt,
                       keep_fsdp=True),
    }
    if cfg.n_shared_experts:
        fe_sh = cfg.moe_d_ff * cfg.n_shared_experts
        defs["shared_wg"] = ParamDef((d, fe_sh), (FSDP_AXIS, TP_AXIS), "fan_in", dt)
        defs["shared_wu"] = ParamDef((d, fe_sh), (FSDP_AXIS, TP_AXIS), "fan_in", dt)
        defs["shared_wd"] = ParamDef((fe_sh, d), (TP_AXIS, FSDP_AXIS), "fan_in", dt)
    return defs


def _moe_local(x2d, router, wg, wu, wd, cfg, e_start: int, tp: int):
    """Tokens [T, D] × local experts wg/wu/wd [E_loc, ...] → [T, D] partial."""
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = wg.shape[0]
    cdt = jnp.dtype(cfg.compute_dtype)
    if t <= 32:
        # decode regime: capacity = t ⇒ provably drop-free (a single expert
        # can at most be picked by every token once)
        cap = t
    else:
        cap = max(1, int(t * k / e * cfg.capacity_factor))

    logits = (x2d @ router.astype(cdt)).astype(jnp.float32)         # [T, E]
    gates, eids = jax.lax.top_k(logits, k)                          # [T, k]
    gates = jax.nn.softmax(gates, axis=-1)

    flat_e = eids.reshape(-1)                                       # [T·k]
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gates.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    seg_start = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(t * k) - seg_start                            # pos within expert
    local = (se >= e_start) & (se < e_start + e_loc)
    keep = (rank < cap) & local
    slot = jnp.where(keep, (se - e_start) * cap + rank, e_loc * cap)  # OOB → dropped

    buf = jnp.zeros((e_loc * cap, d), cdt).at[slot].set(
        x2d[st] * keep[:, None].astype(cdt), mode="drop"
    )
    buf = buf.reshape(e_loc, cap, d)
    h = _act(jnp.einsum("ecd,edf->ecf", buf, wg.astype(cdt)), cfg.activation)
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu.astype(cdt))
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(cdt)).reshape(e_loc * cap, d)

    out = jnp.zeros((t, d), cdt).at[st].add(
        y[jnp.minimum(slot, e_loc * cap - 1)]
        * (sg * keep.astype(jnp.float32))[:, None].astype(cdt),
        mode="drop",
    )
    return out


def _moe_weight_stationary(params, x, cfg, mesh, tp: int):
    """§Perf (serve): experts keep their 2-D (model × data) storage sharding.

    The baseline island's ``in_specs=P('model', None, None)`` forces an
    all-gather of every expert's weights over 'data' each layer — 245 GB/step
    per device for kimi-k2 decode_32k (the measured baseline bottleneck).
    Here the island's in_specs MATCH the storage layout (wg [E, D, Fe] over
    (model, data)), and instead the *tokens* are all-gathered over the data
    axes — a few MB at decode batch sizes.  Exact for t ≤ 512 (capacity = t).

    Per layer wire: gather x (t·D·2B) + psum h (2·E_loc·cap·Fe·4B) + psum y +
    gather out — ~10 MB vs 4 GB of expert weights.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // tp
    fe = cfg.moe_d_ff
    cdt = jnp.dtype(cfg.compute_dtype)
    from repro.distributed.api import _divisible
    entry = _divisible(tuple(a for a in BATCH_AXES if a in mesh.axis_names), b, mesh)
    daxes = (() if entry is None
             else ((entry,) if isinstance(entry, str) else tuple(entry)))
    nd_fsdp = mesh.shape.get("data", 1)
    d_slice = d // nd_fsdp

    def island(x_loc, router, wg_loc, wu_loc, wd_loc):
        bl, sl, _ = x_loc.shape
        x_g = (jax.lax.all_gather(x_loc, daxes, axis=0, tiled=True)
               if daxes else x_loc)                                  # [b_g, s, d]
        t_g = x_g.shape[0] * sl
        x2 = x_g.reshape(t_g, d)
        # §Perf iteration A4: capacity-based buffers above the drop-free
        # regime — shrinks the h/u psum wire bytes ~cap-fold (overflow tokens
        # lose that expert, standard serving capacity dropping).
        cap = t_g if t_g <= 32 else max(k, int(t_g * k / e * cfg.capacity_factor))

        logits = (x2 @ router.astype(cdt)).astype(jnp.float32)
        gates, eids = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(gates, axis=-1)
        flat_e = eids.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t_g), k)
        flat_g = gates.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        rank = jnp.arange(t_g * k) - jnp.searchsorted(se, se, side="left")
        e0 = jax.lax.axis_index(TP_AXIS) * e_loc
        keep = (rank < cap) & (se >= e0) & (se < e0 + e_loc)
        slot = jnp.where(keep, (se - e0) * cap + rank, e_loc * cap)

        # dispatch only the LOCAL D-slice of each token (weights stay put)
        d0 = jax.lax.axis_index("data") * d_slice if nd_fsdp > 1 else 0
        x_sl = jax.lax.dynamic_slice_in_dim(x2, d0, d_slice, axis=1)
        buf = jnp.zeros((e_loc * cap + 1, d_slice), cdt).at[slot].set(
            x_sl[st] * keep[:, None].astype(cdt), mode="drop"
        )[:-1].reshape(e_loc, cap, d_slice)

        g_part = jnp.einsum("ecd,edf->ecf", buf, wg_loc.astype(cdt))
        u_part = jnp.einsum("ecd,edf->ecf", buf, wu_loc.astype(cdt))
        if nd_fsdp > 1:
            g_part = jax.lax.psum(g_part, "data")     # combine D slices
            u_part = jax.lax.psum(u_part, "data")
        h = _act(g_part, cfg.activation) * u_part                     # [E_loc, cap, Fe]
        y = jnp.einsum("ecf,efd->ecd", h, wd_loc.astype(cdt))         # [.., d_slice]
        y = y.reshape(e_loc * cap, d_slice)

        out_sl = jnp.zeros((t_g, d_slice), cdt).at[st].add(
            y[jnp.minimum(slot, e_loc * cap - 1)]
            * (sg * keep.astype(jnp.float32))[:, None].astype(cdt),
            mode="drop",
        )
        out_sl = jax.lax.psum(out_sl, TP_AXIS)                        # expert combine
        if nd_fsdp > 1:
            out_full = jax.lax.all_gather(out_sl, "data", axis=1, tiled=True)
        else:
            out_full = out_sl                                          # [t_g, d]
        # local batch rows for this (pod, data) shard
        if not daxes:
            return out_full.reshape(bl, sl, d)
        shard_rows = bl * sl
        idx = 0
        for a in daxes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        out_loc = jax.lax.dynamic_slice_in_dim(out_full, idx * shard_rows,
                                               shard_rows, axis=0)
        return out_loc.reshape(bl, sl, d)

    # expert weights keep their 2-D (model × data) storage regardless of
    # cfg.fsdp (ParamDef.keep_fsdp) — the island always matches that layout
    fsdp_d = "data" if nd_fsdp > 1 else None
    batch_entry = daxes if daxes else None
    return shard_map(
        island,
        mesh=mesh,
        in_specs=(
            P(batch_entry, None, None),
            P(None, None),
            P(TP_AXIS, fsdp_d, None),
            P(TP_AXIS, fsdp_d, None),
            P(TP_AXIS, None, fsdp_d),
        ),
        out_specs=P(batch_entry, None, None),
        check_vma=False,
    )(x, params["router"], params["wg"], params["wu"], params["wd"])


def moe(params, x, cfg):
    """x [B, S, D] → [B, S, D]; experts sharded over the 'model' mesh axis."""
    b, s, d = x.shape
    mesh = active_mesh()
    tp = axis_size(TP_AXIS)
    e = cfg.n_experts

    if (cfg.moe_weight_stationary and mesh is not None and tp > 1
            and e % tp == 0
            and d % max(1, mesh.shape.get("data", 1)) == 0):
        out = _moe_weight_stationary(params, x, cfg, mesh, tp)
        if cfg.n_shared_experts:
            cdt = jnp.dtype(cfg.compute_dtype)
            g = x @ params["shared_wg"].astype(cdt)
            u = x @ params["shared_wu"].astype(cdt)
            out = out + (_act(g, cfg.activation) * u) @ params["shared_wd"].astype(cdt)
        return out

    if mesh is None or tp == 1 or e % tp != 0:
        out2d = _moe_local(
            x.reshape(b * s, d), params["router"],
            params["wg"], params["wu"], params["wd"], cfg, 0, 1,
        )
        out = out2d.reshape(b, s, d)
    else:
        e_loc = e // tp
        from repro.distributed.api import _divisible
        batch_entry = _divisible(
            tuple(a for a in BATCH_AXES if a in mesh.axis_names), b, mesh)
        batch_axes = (() if batch_entry is None
                      else ((batch_entry,) if isinstance(batch_entry, str)
                            else tuple(batch_entry)))

        def island(x_loc, router, wg_loc, wu_loc, wd_loc):
            bl, sl, _ = x_loc.shape
            e0 = jax.lax.axis_index(TP_AXIS) * e_loc
            part = _moe_local(
                x_loc.reshape(bl * sl, d), router, wg_loc, wu_loc, wd_loc, cfg, e0, tp
            )
            return jax.lax.psum(part, TP_AXIS).reshape(bl, sl, d)

        out = shard_map(
            island,
            mesh=mesh,
            in_specs=(
                P(batch_axes, None, None),
                P(None, None),
                P(TP_AXIS, None, None),
                P(TP_AXIS, None, None),
                P(TP_AXIS, None, None),
            ),
            out_specs=P(batch_axes, None, None),
            check_vma=False,
        )(x, params["router"], params["wg"], params["wu"], params["wd"])

    if cfg.n_shared_experts:
        cdt = jnp.dtype(cfg.compute_dtype)
        g = x @ params["shared_wg"].astype(cdt)
        u = x @ params["shared_wu"].astype(cdt)
        out = out + (_act(g, cfg.activation) * u) @ params["shared_wd"].astype(cdt)
    return out
