"""GQA attention: train/prefill (chunked flash), decode (KV cache), cross.

Three execution paths share one parameter set:

* ``full``    — self-attention over the whole sequence (train / prefill).
  Uses the Pallas flash kernel on single-device runs; under an active mesh it
  lowers the *chunked XLA* streaming-softmax equivalent (`_flash_xla`), which
  GSPMD partitions with the same O(S) memory guarantee — never an S×S
  materialization (prefill_32k would otherwise need 17 GB/device of scores).
* ``decode``  — one (or few) new tokens against a padded KV cache, in-place
  `dynamic_update_slice` at the per-sequence length.
* ``cross``   — encoder-decoder cross attention against precomputed memory.

Sharding: q/k/v/o weights are TP-sharded on the head axis; activations are
constrained to P(batch, None, 'model', None) per head when the head count
divides the mesh axis, otherwise the KV cache falls back to sequence
sharding (flash-decode style) — see `kv_cache_spec`.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.api import BATCH_AXES, FSDP_AXIS, TP_AXIS, active_mesh, axis_size, constrain
from repro.kernels.flash_attention import flash_attention_diff
from .layers import ParamDef, apply_rope

NEG_INF = -1e30


def attn_defs(cfg) -> Dict[str, ParamDef]:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.param_dtype
    defs = {
        "wq": ParamDef((d, hq * hd), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "wk": ParamDef((d, hkv * hd), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "wv": ParamDef((d, hkv * hd), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "wo": ParamDef((hq * hd, d), (TP_AXIS, FSDP_AXIS), "fan_in", dt),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), "ones", dt)
        defs["k_norm"] = ParamDef((hd,), (None,), "ones", dt)
    return defs


def _maybe_head_axis(n_heads: int) -> Optional[str]:
    """TP axis name if the head count divides the mesh's model axis."""
    size = axis_size(TP_AXIS)
    if size > 1 and n_heads % size == 0:
        return TP_AXIS
    return None


def kv_cache_spec(cfg) -> P:
    """[B, Hkv, S, Dh] cache: head-sharded when divisible, else seq-sharded."""
    if _maybe_head_axis(cfg.n_kv_heads):
        return P(BATCH_AXES, TP_AXIS, None, None)
    return P(BATCH_AXES, None, TP_AXIS, None)


def _rms(x, gamma, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma.astype(x.dtype)


def _project_qkv(params, x, positions, cfg, *, rope: bool = True):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cdt = jnp.dtype(cfg.compute_dtype)
    q = (x @ params["wq"].astype(cdt)).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = (x @ params["wk"].astype(cdt)).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = (x @ params["wv"].astype(cdt)).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    q = constrain(q, BATCH_AXES, _maybe_head_axis(hq), None, None)
    kv_ax = _maybe_head_axis(hkv)
    k = constrain(k, BATCH_AXES, kv_ax, None, None)
    v = constrain(v, BATCH_AXES, kv_ax, None, None)
    if cfg.qk_norm:
        q = _rms(q, params["q_norm"], cfg.norm_eps)
        k = _rms(k, params["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked flash attention in pure XLA (GSPMD-partitionable)
# ---------------------------------------------------------------------------


def _pad_qkv(q, k, v, qc, kc):
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    sq_pad = -(-sq // qc) * qc
    skv_pad = -(-skv // kc) * kc
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    return q, k, v, sq_pad, skv_pad


def _block_mask(qpos, kpos, skv, causal, window):
    mask = (kpos < skv)[None, :]
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    return mask


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "q_chunk", "kv_chunk")
)
def _flash_xla(
    q, k, v, *, causal: bool, window: Optional[int], scale: float,
    q_chunk: int = 1024, kv_chunk: int = 1024,
):
    """Streaming-softmax attention, O(S·chunk) memory, scan over kv blocks."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    sq_pad = -(-sq // qc) * qc
    skv_pad = -(-skv // kc) * kc
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    kx = k.reshape(b, hkv, 1, skv_pad, dh)
    vx = v.reshape(b, hkv, 1, skv_pad, dh)
    qx = q.reshape(b, hkv, group, sq_pad, dh)

    nq, nk = sq_pad // qc, skv_pad // kc

    def q_block(iq):
        q_i = jax.lax.dynamic_slice_in_dim(qx, iq * qc, qc, axis=3).astype(jnp.float32)
        qpos = iq * qc + jnp.arange(qc) + (skv - sq)

        def kv_step(carry, ik):
            m_prev, l_prev, acc = carry
            k_j = jax.lax.dynamic_slice_in_dim(kx, ik * kc, kc, axis=3).astype(jnp.float32)
            v_j = jax.lax.dynamic_slice_in_dim(vx, ik * kc, kc, axis=3).astype(jnp.float32)
            s_ij = jnp.einsum("bhgqd,bhgkd->bhgqk", q_i, k_j) * scale
            kpos = ik * kc + jnp.arange(kc)
            mask = (kpos < skv)[None, :]
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
            m_new = jnp.maximum(m_prev, s_ij.max(-1))
            alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
            p = jnp.exp(s_ij - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            l_new = alpha * l_prev + p.sum(-1)
            acc_new = alpha[..., None] * acc + jnp.einsum("bhgqk,bhgkd->bhgqd", p, v_j)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, group, qc), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, group, qc), jnp.float32),
            jnp.zeros((b, hkv, group, qc, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    out = jax.lax.map(q_block, jnp.arange(nq))               # [nq, b, hkv, g, qc, dh]
    out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, group, sq_pad, dh)
    return out.reshape(b, hq, sq_pad, dh)[:, :, :sq]


# ---------------------------------------------------------------------------
# §Perf optimization: hand-written streaming backward (flash-attention bwd).
#
# The naive autodiff of `_flash_xla` saves every kv-step scan carry for the
# backward pass — O(S²·dh/kc) per layer, the dominant temp-memory term in the
# baseline dry-run (24.5 GB/device for tinyllama train_4k).  This custom_vjp
# saves only (q, k, v, o, lse) and re-streams kv blocks in the backward,
# restoring O(S·chunk) memory.  Enabled by ArchConfig.flash_bwd.
# ---------------------------------------------------------------------------


def _flash_fwd_lse(q, k, v, causal, window, scale, qc, kc):
    """Forward that also returns lse = m + log(l) per query (for the bwd)."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    q, k, v, sq_pad, skv_pad = _pad_qkv(q, k, v, qc, kc)
    kx = k.reshape(b, hkv, 1, skv_pad, dh)
    vx = v.reshape(b, hkv, 1, skv_pad, dh)
    qx = q.reshape(b, hkv, group, sq_pad, dh)
    nq, nk = sq_pad // qc, skv_pad // kc

    def q_block(iq):
        q_i = jax.lax.dynamic_slice_in_dim(qx, iq * qc, qc, axis=3).astype(jnp.float32)
        qpos = iq * qc + jnp.arange(qc) + (skv - sq)

        def kv_step(carry, ik):
            m_prev, l_prev, acc = carry
            k_j = jax.lax.dynamic_slice_in_dim(kx, ik * kc, kc, axis=3).astype(jnp.float32)
            v_j = jax.lax.dynamic_slice_in_dim(vx, ik * kc, kc, axis=3).astype(jnp.float32)
            s_ij = jnp.einsum("bhgqd,bhgkd->bhgqk", q_i, k_j) * scale
            kpos = ik * kc + jnp.arange(kc)
            mask = _block_mask(qpos, kpos, skv, causal, window)
            s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
            m_new = jnp.maximum(m_prev, s_ij.max(-1))
            alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
            p = jnp.where(mask[None, None, None], jnp.exp(s_ij - m_new[..., None]), 0.0)
            l_new = alpha * l_prev + p.sum(-1)
            acc_new = alpha[..., None] * acc + jnp.einsum("bhgqk,bhgkd->bhgqd", p, v_j)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, group, qc), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, group, qc), jnp.float32),
            jnp.zeros((b, hkv, group, qc, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        o_i = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse_i = m + jnp.log(jnp.maximum(l, 1e-30))
        return o_i, lse_i

    o, lse = jax.lax.map(q_block, jnp.arange(nq))
    o = jnp.moveaxis(o, 0, 3).reshape(b, hkv, group, sq_pad, dh)
    o = o.reshape(b, hq, sq_pad, dh)[:, :, :sq]
    lse = jnp.moveaxis(lse, 0, 3).reshape(b, hkv, group, sq_pad)
    lse = lse.reshape(b, hq, sq_pad)[:, :, :sq]
    return o, lse


def _flash_bwd_stream(res, g, causal, window, scale, qc, kc):
    """Re-streaming backward: dq via inner accumulation, dk/dv via outer carry."""
    q, k, v, o, lse = res
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    qp, kp, vp, sq_pad, skv_pad = _pad_qkv(q, k, v, qc, kc)
    gp = jnp.pad(g, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0))) if sq_pad != sq else g
    op = jnp.pad(o, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0))) if sq_pad != sq else o
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, sq_pad - sq)),
                   constant_values=NEG_INF) if sq_pad != sq else lse

    qx = qp.reshape(b, hkv, group, sq_pad, dh).astype(jnp.float32)
    gx = gp.reshape(b, hkv, group, sq_pad, dh).astype(jnp.float32)
    ox = op.reshape(b, hkv, group, sq_pad, dh).astype(jnp.float32)
    lx = lsep.reshape(b, hkv, group, sq_pad)
    kx = kp.astype(jnp.float32)
    vx = vp.astype(jnp.float32)
    nq, nk = sq_pad // qc, skv_pad // kc
    delta = (gx * ox).sum(-1)                                   # [b,hkv,g,sq]

    def q_step(carry, iq):
        dk_acc, dv_acc = carry
        q_i = jax.lax.dynamic_slice_in_dim(qx, iq * qc, qc, axis=3)
        g_i = jax.lax.dynamic_slice_in_dim(gx, iq * qc, qc, axis=3)
        l_i = jax.lax.dynamic_slice_in_dim(lx, iq * qc, qc, axis=3)
        d_i = jax.lax.dynamic_slice_in_dim(delta, iq * qc, qc, axis=3)
        qpos = iq * qc + jnp.arange(qc) + (skv - sq)

        def kv_step(dq_i, ik):
            k_j = jax.lax.dynamic_slice_in_dim(kx, ik * kc, kc, axis=2)
            v_j = jax.lax.dynamic_slice_in_dim(vx, ik * kc, kc, axis=2)
            s_ij = jnp.einsum("bhgqd,bhkd->bhgqk", q_i, k_j) * scale
            kpos = ik * kc + jnp.arange(kc)
            mask = _block_mask(qpos, kpos, skv, causal, window)
            # padded q rows carry lse = -inf: zero them before exp overflows
            mask = mask[None, None, None] & (l_i[..., None] > NEG_INF / 2)
            p = jnp.where(mask, jnp.exp(jnp.minimum(s_ij - l_i[..., None], 30.0)), 0.0)
            dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, g_i)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", g_i, v_j)
            ds = p * (dp - d_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhgqk,bhkd->bhgqd", ds, k_j)
            dk_j = jnp.einsum("bhgqk,bhgqd->bhkd", ds, q_i)
            return dq_i, (dk_j, dv_j)

        dq0 = jnp.zeros((b, hkv, group, qc, dh), jnp.float32)
        dq_i, (dk_blks, dv_blks) = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
        dk_full = jnp.moveaxis(dk_blks, 0, 2).reshape(b, hkv, skv_pad, dh)
        dv_full = jnp.moveaxis(dv_blks, 0, 2).reshape(b, hkv, skv_pad, dh)
        return (dk_acc + dk_full, dv_acc + dv_full), dq_i

    zeros_kv = jnp.zeros((b, hkv, skv_pad, dh), jnp.float32)
    (dk, dv), dq_blocks = jax.lax.scan(q_step, (zeros_kv, zeros_kv), jnp.arange(nq))
    dq = jnp.moveaxis(dq_blocks, 0, 3).reshape(b, hkv, group, sq_pad, dh)
    dq = dq.reshape(b, hq, sq_pad, dh)[:, :, :sq]
    return (dq.astype(q.dtype), dk[:, :, :skv].astype(k.dtype),
            dv[:, :, :skv].astype(v.dtype))


@functools.lru_cache(maxsize=None)
def _flash_xla_diff(causal: bool, window: Optional[int], scale: float,
                    qc: int, kc: int):
    @jax.custom_vjp
    def f(q, k, v):
        return _flash_xla(q, k, v, causal=causal, window=window, scale=scale,
                          q_chunk=qc, kv_chunk=kc)

    def fwd(q, k, v):
        o, lse = _flash_fwd_lse(q, k, v, causal, window, scale, qc, kc)
        return o, (q, k, v, o, lse)

    def bwd(res, g):
        return _flash_bwd_stream(res, g.astype(jnp.float32), causal, window,
                                 scale, qc, kc)

    f.defvjp(fwd, bwd)
    return f


def flash_xla_attention(q, k, v, *, causal=True, window=None, scale=None,
                        q_chunk=1024, kv_chunk=1024):
    """O(S·chunk)-memory attention with the hand-written streaming backward."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    qc = min(q_chunk, q.shape[2])
    kc = min(kv_chunk, k.shape[2])
    return _flash_xla_diff(causal, window, scale, qc, kc)(q, k, v)


def _naive_attention(q, k, v, *, causal, window, scale):
    """Direct S² einsum attention — every FLOP visible to cost analysis.

    Used only by the dry-run probe configs (attn_naive=True): probes are
    lowered, never executed, so the S² score materialization is harmless and
    makes `cost_analysis()` loop-free (see roofline.py CAVEAT).
    """
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    qx = q.reshape(b, hkv, group, sq, dh).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qx, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, dh).astype(q.dtype)


def self_attention_full(
    params, x, positions, cfg,
    *, causal: bool = True, window=None, return_kv: bool = False,
):
    """Train / prefill path.  Returns (out [B,S,D], optional (k, v))."""
    q, k, v = _project_qkv(params, x, positions, cfg)
    scale = cfg.hd ** -0.5
    if cfg.attn_naive:
        o = _naive_attention(q, k, v, causal=causal, window=window, scale=scale)
    elif cfg.flash_bwd:
        o = flash_xla_attention(q, k, v, causal=causal, window=window, scale=scale)
    elif active_mesh() is None and q.shape[2] >= 8:
        o = flash_attention_diff(q, k, v, causal=causal, window=window, scale=scale,
                                 bq=min(128, q.shape[2]), bkv=min(128, k.shape[2]))
    else:
        o = _flash_xla(q, k, v, causal=causal, window=window, scale=scale)
    b, s = x.shape[0], x.shape[1]
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    out = o @ params["wo"].astype(jnp.dtype(cfg.compute_dtype))
    out = constrain(out, BATCH_AXES, None, None)
    if return_kv:
        return out, (k, v)
    return out


def _masked_decode_attention(q, k_cache, v_cache, lengths, cfg, *, window=None):
    """q [B,Hq,T,Dh] vs cache [B,Hkv,S,Dh]; key j valid iff j < lengths[b]+t+1.

    §Perf note: scores accumulate in f32 via `preferred_element_type` — the
    bf16 caches are never materialized as f32 copies (that upcast was ~0.5
    GB/layer/device of the decode_32k memory term).
    """
    b, hq, t, dh = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    qx = q.reshape(b, hkv, group, t, dh)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qx, k_cache,
                        preferred_element_type=jnp.float32) * (dh ** -0.5)
    kpos = jnp.arange(s)[None, None, :]
    qabs = lengths[:, None, None] + jnp.arange(t)[None, :, None]   # absolute pos of queries
    mask = kpos <= qabs
    if window is not None:
        mask = mask & (kpos > qabs - window)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(q.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, hq, t, dh).astype(q.dtype)


def self_attention_decode(
    params, x, cfg, cache_k, cache_v, lengths, *, window=None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Decode T new tokens; cache updated in place at per-sequence lengths.

    For rolling-window caches (long_500k hybrid attention) the write position
    wraps modulo the cache size — positions for RoPE stay absolute.
    """
    b, t, _ = x.shape
    s_cache = cache_k.shape[2]
    positions = lengths[:, None] + jnp.arange(t)[None, :]
    q, k_new, v_new = _project_qkv(params, x, positions, cfg)

    # Write the new kv at each sequence's offset (wrap if windowed).
    # §Perf: expressed as a one-hot masked update, NOT a scatter — scatter on
    # the seq-sharded cache forces GSPMD reshards (~2.6 GB/layer/device in
    # the decode_32k baseline); the masked form partitions elementwise.
    write_pos = positions % s_cache if window is not None else positions

    def write(cache, new):
        # cache [B,Hkv,S,Dh], new [B,Hkv,T,Dh], write_pos [B,T]
        onehot = (jnp.arange(s_cache)[None, None, :] ==
                  write_pos[:, :, None])                       # [B,T,S]
        keep = 1.0 - onehot.any(axis=1).astype(cache.dtype)    # [B,S]
        upd = jnp.einsum("bts,bhtd->bhsd", onehot.astype(cache.dtype), new)
        return cache * keep[:, None, :, None] + upd

    cache_k = write(cache_k, k_new)
    cache_v = write(cache_v, v_new)
    spec = tuple(kv_cache_spec(cfg))
    cache_k = constrain(cache_k, *spec)
    cache_v = constrain(cache_v, *spec)

    if window is not None:
        # Rolling cache: every live slot is attendable; absolute masking is
        # handled by the wrap (slots hold the last `s_cache` positions).
        eff_len = jnp.minimum(lengths, s_cache)
        o = _masked_decode_attention(q, cache_k, cache_v, eff_len, cfg, window=None)
    else:
        o = _masked_decode_attention(q, cache_k, cache_v, lengths, cfg)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.n_heads * cfg.hd)
    out = o @ params["wo"].astype(jnp.dtype(cfg.compute_dtype))
    return constrain(out, BATCH_AXES, None, None), cache_k, cache_v


def cross_attention(params, x, memory_k, memory_v, cfg):
    """Decoder cross-attention against encoder memory [B, Hkv, Se, Dh]."""
    b, t, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cdt = jnp.dtype(cfg.compute_dtype)
    q = (x @ params["wq"].astype(cdt)).reshape(b, t, hq, hd).transpose(0, 2, 1, 3)
    q = constrain(q, BATCH_AXES, _maybe_head_axis(hq), None, None)
    se = memory_k.shape[2]
    lengths = jnp.full((b,), se, jnp.int32)  # full memory attendable
    group = hq // hkv
    qx = q.reshape(b, hkv, group, t, hd).astype(jnp.float32)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qx, memory_k.astype(jnp.float32)) * (hd ** -0.5)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, memory_v.astype(jnp.float32))
    o = o.reshape(b, hq, t, hd).astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, t, hq * hd)
    return constrain(o @ params["wo"].astype(cdt), BATCH_AXES, None, None)


def project_cross_kv(params, memory, cfg):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    b, se, _ = memory.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd
    cdt = jnp.dtype(cfg.compute_dtype)
    k = (memory @ params["wk"].astype(cdt)).reshape(b, se, hkv, hd).transpose(0, 2, 1, 3)
    v = (memory @ params["wv"].astype(cdt)).reshape(b, se, hkv, hd).transpose(0, 2, 1, 3)
    ax = _maybe_head_axis(hkv)
    return constrain(k, BATCH_AXES, ax, None, None), constrain(v, BATCH_AXES, ax, None, None)
