"""CausalLM: the decoder-only model family (dense / MoE / hybrid / SSM / VLM).

Pure-functional API over nested-dict params, built from ParamDefs so the same
source of truth yields materialized params (smoke tests), ShapeDtypeStructs
(dry-run) and PartitionSpecs (pjit shardings).

Entry points:
  * ``forward``  — logits for a full sequence (train / eval).
  * ``loss``     — next-token cross entropy (+ metrics).
  * ``prefill``  — full forward that also returns the serving cache.
  * ``decode``   — one incremental step against the cache (serve_step).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.api import BATCH_AXES, TP_AXIS, constrain
from .attention import kv_cache_spec
from .blocks import layer_pattern, run_stack_decode, run_stack_full, stack_defs
from .config import ArchConfig
from .frontends import frontend_defs, project_frontend
from .layers import (
    ParamDef, cross_entropy_loss, embed_defs, init_from_defs, norm_def,
    rms_norm, shapes_from_defs, specs_from_defs,
)

Pytree = Any


@dataclasses.dataclass(frozen=True)
class CausalLM:
    cfg: ArchConfig

    # ---- parameters -------------------------------------------------------
    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        defs: Dict[str, Any] = {
            "embed": embed_defs(cfg),
            "blocks": stack_defs(cfg),
            "final_norm": norm_def(cfg),
        }
        if cfg.frontend:
            defs["frontend"] = frontend_defs(cfg)
        return defs

    def init(self, key: jax.Array) -> Pytree:
        return init_from_defs(self.param_defs(), key)

    def param_specs(self) -> Pytree:
        return specs_from_defs(self.param_defs(), self.cfg.fsdp)

    def param_shapes(self) -> Pytree:
        return shapes_from_defs(self.param_defs())

    def param_shardings(self, mesh) -> Pytree:
        from .layers import shardings_from_defs
        return shardings_from_defs(self.param_defs(), self.cfg.fsdp, mesh)

    # ---- embedding / head --------------------------------------------------
    def _embed(self, params, tokens):
        cfg = self.cfg
        h = jnp.take(params["embed"]["embedding"], tokens, axis=0)
        h = h.astype(jnp.dtype(cfg.compute_dtype))
        if cfg.embed_scale:
            h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
        return constrain(h, BATCH_AXES, None, None)

    def _logits(self, params, h):
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        if cfg.tie_embeddings:
            logits = h @ params["embed"]["embedding"].astype(cdt).T
        else:
            logits = h @ params["embed"]["lm_head"].astype(cdt)
        if cfg.logit_softcap:
            c = cfg.logit_softcap
            logits = jnp.tanh(logits / c) * c
        return constrain(logits, BATCH_AXES, None, TP_AXIS)

    def _fuse_frontend(self, params, h, batch):
        if self.cfg.frontend and "frontend_feats" in batch:
            pre = project_frontend(params["frontend"], batch["frontend_feats"], self.cfg)
            h = jax.lax.dynamic_update_slice(h, pre.astype(h.dtype), (0, 0, 0))
        return h

    # ---- full-sequence paths ----------------------------------------------
    def forward(self, params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        tokens = batch["tokens"]
        positions = jnp.arange(tokens.shape[1])[None].astype(jnp.int32)
        positions = jnp.broadcast_to(positions, tokens.shape)
        h = self._fuse_frontend(params, self._embed(params, tokens), batch)
        h, _ = run_stack_full(params["blocks"], h, positions, self.cfg,
                              window=self.cfg.window)
        h = rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        return self._logits(params, h)

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits = self.forward(params, batch)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        return cross_entropy_loss(logits, labels, mask)

    # ---- serving -----------------------------------------------------------
    def cache_defs(self, batch_size: int, cache_len: int) -> Dict[str, Any]:
        """ParamDef-style description of the decode cache (shapes + specs)."""
        cfg = self.cfg
        pattern, n_periods = layer_pattern(cfg)
        kv_spec = tuple(kv_cache_spec(cfg))
        cdt = cfg.compute_dtype
        out: Dict[str, Any] = {}
        for j, (kind, _) in enumerate(pattern):
            if kind == "attn":
                c = {
                    "k": ParamDef((batch_size, cfg.n_kv_heads, cache_len, cfg.hd),
                                  kv_spec, "zeros", cdt),
                    "v": ParamDef((batch_size, cfg.n_kv_heads, cache_len, cfg.hd),
                                  kv_spec, "zeros", cdt),
                }
            elif kind == "mamba":
                c = {
                    "conv": ParamDef((batch_size, cfg.ssm_d_conv - 1, cfg.ssm_d_inner),
                                     (BATCH_AXES, None, TP_AXIS), "zeros", cdt),
                    "ssm": ParamDef((batch_size, cfg.ssm_d_inner, cfg.ssm_d_state),
                                    (BATCH_AXES, TP_AXIS, None), "zeros", "float32"),
                }
            else:  # rwkv
                c = {
                    "tm_shift": ParamDef((batch_size, cfg.d_model), (BATCH_AXES, None),
                                         "zeros", cdt),
                    "wkv": ParamDef((batch_size, cfg.rwkv_heads, cfg.rwkv_head_dim,
                                     cfg.rwkv_head_dim),
                                    (BATCH_AXES, TP_AXIS, None, None), "zeros", "float32"),
                    "cm_shift": ParamDef((batch_size, cfg.d_model), (BATCH_AXES, None),
                                         "zeros", cdt),
                }
            out[f"pos{j}"] = jax.tree.map(
                lambda d: d.with_layer_dim(n_periods), c,
                is_leaf=lambda v: isinstance(v, ParamDef),
            )
        return out

    def init_cache(self, batch_size: int, cache_len: int) -> Pytree:
        defs = self.cache_defs(batch_size, cache_len)
        return init_from_defs(defs, jax.random.PRNGKey(0))

    def cache_specs(self, batch_size: int, cache_len: int) -> Pytree:
        return specs_from_defs(self.cache_defs(batch_size, cache_len), fsdp=True)

    def cache_shardings(self, batch_size: int, cache_len: int, mesh) -> Pytree:
        from .layers import shardings_from_defs
        return shardings_from_defs(self.cache_defs(batch_size, cache_len), True, mesh)

    def cache_shapes(self, batch_size: int, cache_len: int) -> Pytree:
        return shapes_from_defs(self.cache_defs(batch_size, cache_len))

    def prefill(self, params, batch, cache_len: int):
        """Full forward + cache build.  Returns (last-token logits, cache, lengths)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
        h = self._fuse_frontend(params, self._embed(params, tokens), batch)
        h, caches = run_stack_full(params["blocks"], h, positions, cfg,
                                   window=cfg.window, collect_cache=True)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, h[:, -1:])

        # pad attention kv to cache capacity
        pattern, n_periods = layer_pattern(cfg)
        out_cache = {}
        for j, (kind, _) in enumerate(pattern):
            c = caches[f"pos{j}"]
            if kind == "attn":
                def pad_kv(kv):
                    pad = cache_len - kv.shape[3]
                    if pad > 0:
                        kv = jnp.pad(kv, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
                    kv = kv[:, :, :, :cache_len]
                    return constrain(kv, None, *kv_cache_spec(cfg))
                c = {"k": pad_kv(c["k"]), "v": pad_kv(c["v"])}
            out_cache[f"pos{j}"] = c
        lengths = jnp.full((b,), s, jnp.int32)
        return logits, out_cache, lengths

    def decode(self, params, cache, tokens, lengths):
        """tokens [B, T_new] (typically T_new = 1).  Returns (logits, cache, lengths)."""
        cfg = self.cfg
        h = self._embed(params, tokens)
        h, cache = run_stack_decode(params["blocks"], h, cfg, cache, lengths,
                                    window=cfg.window)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, h)
        return logits, cache, lengths + tokens.shape[1]
