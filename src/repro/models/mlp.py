"""Gated MLP (SwiGLU / GeGLU), TP-sharded on the hidden axis."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.api import BATCH_AXES, FSDP_AXIS, TP_AXIS, constrain
from .layers import ParamDef


def mlp_defs(cfg, d_ff: int = 0) -> Dict[str, ParamDef]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    return {
        "wg": ParamDef((d, f), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "wu": ParamDef((d, f), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "wd": ParamDef((f, d), (TP_AXIS, FSDP_AXIS), "fan_in", dt),
    }


def _act(x, kind: str):
    if kind == "geglu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)  # swiglu


def mlp(params, x, cfg):
    cdt = jnp.dtype(cfg.compute_dtype)
    g = x @ params["wg"].astype(cdt)
    u = x @ params["wu"].astype(cdt)
    h = _act(g, cfg.activation) * u
    h = constrain(h, BATCH_AXES, None, TP_AXIS)
    out = h @ params["wd"].astype(cdt)
    return constrain(out, BATCH_AXES, None, None)
