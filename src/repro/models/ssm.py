"""Mamba (selective SSM) block — the Jamba hybrid's attention-free layer.

Faithful Mamba-1 recurrence (arXiv:2312.00752), TPU-adapted:

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t x_t) ⊗ B_t          h ∈ R^{Di × Ds}
    y_t = h_t · C_t + D ⊙ x_t

* The CUDA "selective scan" kernel fuses a sequential scan in SRAM; the TPU
  adaptation is a *chunked associative scan*: `lax.scan` over sequence chunks
  (bounding live memory to one chunk's [B, L, Di, Ds] tensor) with
  `lax.associative_scan` inside the chunk (log-depth, VPU-friendly).  See
  DESIGN.md §2 (assumption changes).
* Di (= expand·d_model) is TP-sharded: every per-channel tensor partitions
  cleanly on 'model'; the only cross-shard contractions are the small
  x_proj/out_proj matmuls (one psum each, inserted by GSPMD).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import BATCH_AXES, FSDP_AXIS, TP_AXIS, constrain
from .layers import ParamDef


def mamba_defs(cfg) -> Dict[str, ParamDef]:
    d, di, ds, r, kc = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_d_state, cfg.dt_rank, cfg.ssm_d_conv
    dt = cfg.param_dtype
    return {
        "in_proj": ParamDef((d, 2 * di), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "conv_w": ParamDef((kc, di), (None, TP_AXIS), "fan_in", dt),
        "conv_b": ParamDef((di,), (TP_AXIS,), "zeros", dt),
        "x_proj": ParamDef((di, r + 2 * ds), (TP_AXIS, None), "fan_in", dt),
        "dt_proj": ParamDef((r, di), (None, TP_AXIS), "fan_in", dt),
        "dt_bias": ParamDef((di,), (TP_AXIS,), "zeros", "float32"),
        "a_log": ParamDef((di, ds), (TP_AXIS, None), "ones", "float32"),
        "d_skip": ParamDef((di,), (TP_AXIS,), "ones", "float32"),
        "out_proj": ParamDef((di, d), (TP_AXIS, FSDP_AXIS), "fan_in", dt),
    }


def _depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                    state: Optional[jnp.ndarray] = None):
    """Causal depthwise conv over seq.  x [B, T, Di], w [K, Di].

    Returns (y [B, T, Di], new_state [B, K-1, Di]) — state carries the last
    K-1 inputs for decode continuation.
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                # [B, K-1+T, Di]
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_state = xp[:, xp.shape[1] - (k - 1):]
    return y + b[None, None].astype(y.dtype), new_state


def _ssm_chunk_scan(a: jnp.ndarray, bu: jnp.ndarray, h0: jnp.ndarray, chunk: int):
    """Prefix recurrence h_t = a_t ⊙ h_{t-1} + bu_t over [B, T, Di, Ds].

    Chunked: lax.scan over T/chunk carrying h, associative_scan inside.
    Returns (h_all [B, T, Di, Ds], h_final [B, Di, Ds]).
    """
    b, t, di, ds = a.shape
    l = min(chunk, t)
    pad = -(-t // l) * l - t
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bu = jnp.pad(bu, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nch = (t + pad) // l
    a_c = a.reshape(b, nch, l, di, ds).transpose(1, 0, 2, 3, 4)
    bu_c = bu.reshape(b, nch, l, di, ds).transpose(1, 0, 2, 3, 4)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, bl * ar + br

    def step(h, inp):
        a_i, bu_i = inp                                    # [B, L, Di, Ds]
        pa, pb = jax.lax.associative_scan(combine, (a_i, bu_i), axis=1)
        h_all = pa * h[:, None] + pb                       # h_t = A_t h0 + B_t
        return h_all[:, -1], h_all

    h_fin, h_chunks = jax.lax.scan(step, h0, (a_c, bu_c))
    h_all = h_chunks.transpose(1, 0, 2, 3, 4).reshape(b, nch * l, di, ds)
    return h_all[:, :t], h_fin


def mamba(
    params, x, cfg, *,
    conv_state: Optional[jnp.ndarray] = None,
    ssm_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
    chunk: int = 256,
):
    """x [B, T, D] → [B, T, D] (+ (conv_state, ssm_state) when requested)."""
    bsz, t, d = x.shape
    di, ds = cfg.ssm_d_inner, cfg.ssm_d_state
    r = cfg.dt_rank
    cdt = jnp.dtype(cfg.compute_dtype)

    xz = x @ params["in_proj"].astype(cdt)                  # [B, T, 2Di]
    xz = constrain(xz, BATCH_AXES, None, TP_AXIS)
    xi, z = jnp.split(xz, 2, axis=-1)

    xi, conv_state_new = _depthwise_conv(xi, params["conv_w"].astype(cdt),
                                         params["conv_b"], conv_state)
    xi = jax.nn.silu(xi)
    xi = constrain(xi, BATCH_AXES, None, TP_AXIS)

    dbc = xi @ params["x_proj"].astype(cdt)                 # [B, T, R+2Ds] (psum over Di)
    dt_lo, b_ssm, c_ssm = jnp.split(dbc.astype(jnp.float32), [r, r + ds], axis=-1)
    dt = jax.nn.softplus(dt_lo @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"][None, None])   # [B, T, Di]
    dt = constrain(dt, BATCH_AXES, None, TP_AXIS)

    sdt = jnp.dtype(cfg.ssm_compute_dtype)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))       # [Di, Ds]
    decay = jnp.exp(dt[..., None] * a[None, None]).astype(sdt)  # [B, T, Di, Ds]
    xf = xi.astype(jnp.float32)
    bu = ((dt * xf)[..., None] * b_ssm[:, :, None, :]).astype(sdt)

    if ssm_state is None:
        ssm_state = jnp.zeros((bsz, di, ds), jnp.float32)
    h_all, h_fin = _ssm_chunk_scan(decay, bu, ssm_state.astype(sdt), chunk)
    h_fin = h_fin.astype(jnp.float32)
    y = jnp.einsum("btis,bts->bti", h_all.astype(jnp.float32), c_ssm)
    y = y + params["d_skip"][None, None] * xf
    y = (y.astype(cdt)) * jax.nn.silu(z)
    y = constrain(y, BATCH_AXES, None, TP_AXIS)
    out = y @ params["out_proj"].astype(cdt)
    out = constrain(out, BATCH_AXES, None, None)
    if return_state:
        return out, (conv_state_new, h_fin)
    return out
