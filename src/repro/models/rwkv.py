"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

Faithful to arXiv:2404.05892 §3 with one documented simplification: the
token-shift interpolation weights are static learned vectors (the paper adds
a low-rank data-dependent term to the mix weights too); the *decay* — the
defining Finch feature — keeps its full LoRA data dependence:

    w_t = exp(-exp(w0 + tanh(x̃_t W_a) W_b))          (per-channel, per-token)

The recurrence itself runs through :mod:`repro.kernels.rwkv6` (Pallas kernel
on single-device; chunked XLA scan under a mesh).  Heads are TP-sharded —
each head's [Dh, Dh] state is shard-local, so the only collectives per block
are the in/out projections' psums.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import BATCH_AXES, FSDP_AXIS, TP_AXIS, active_mesh, constrain
from repro.kernels.rwkv6 import rwkv6_diff, rwkv6_ref
from .layers import ParamDef


def rwkv_defs(cfg) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    r = cfg.rwkv_decay_lora
    dt = cfg.param_dtype
    return {
        # time-mix
        "mu_r": ParamDef((d,), (None,), "normal", dt),
        "mu_k": ParamDef((d,), (None,), "normal", dt),
        "mu_v": ParamDef((d,), (None,), "normal", dt),
        "mu_w": ParamDef((d,), (None,), "normal", dt),
        "mu_g": ParamDef((d,), (None,), "normal", dt),
        "wr": ParamDef((d, d), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "wk": ParamDef((d, d), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "wv": ParamDef((d, d), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "wg": ParamDef((d, d), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "w0": ParamDef((d,), (None,), "normal", "float32"),
        "w_lora_a": ParamDef((d, r), (FSDP_AXIS, None), "fan_in", "float32"),
        "w_lora_b": ParamDef((r, d), (None, TP_AXIS), "fan_in", "float32"),
        "bonus_u": ParamDef((h, hd), (TP_AXIS, None), "normal", "float32"),
        "ln_x": ParamDef((d,), (None,), "ones", dt),
        "wo": ParamDef((d, d), (TP_AXIS, FSDP_AXIS), "fan_in", dt),
        # channel-mix
        "cmu_k": ParamDef((d,), (None,), "normal", dt),
        "cmu_r": ParamDef((d,), (None,), "normal", dt),
        "ck": ParamDef((d, f), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
        "cv": ParamDef((f, d), (TP_AXIS, FSDP_AXIS), "fan_in", dt),
        "cr": ParamDef((d, d), (FSDP_AXIS, TP_AXIS), "fan_in", dt),
    }


def _token_shift(x: jnp.ndarray, state: Optional[jnp.ndarray]):
    """x_{t-1} per position; `state` carries the last token across calls."""
    if state is None:
        state = jnp.zeros_like(x[:, :1])
    prev = jnp.concatenate([state[:, None] if state.ndim == 2 else state, x[:, :-1]], axis=1)
    return prev, x[:, -1]


def _lerp(x, prev, mu):
    return x + (prev - x) * mu[None, None].astype(x.dtype)


def rwkv_time_mix(
    params, x, cfg, *,
    shift_state: Optional[jnp.ndarray] = None,   # [B, D] last token
    wkv_state: Optional[jnp.ndarray] = None,     # [B, H, Dh, Dh]
    return_state: bool = False,
):
    b, t, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    cdt = jnp.dtype(cfg.compute_dtype)

    prev, last = _token_shift(x, shift_state)
    xr = _lerp(x, prev, params["mu_r"])
    xk = _lerp(x, prev, params["mu_k"])
    xv = _lerp(x, prev, params["mu_v"])
    xw = _lerp(x, prev, params["mu_w"])
    xg = _lerp(x, prev, params["mu_g"])

    r = (xr @ params["wr"].astype(cdt)).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = (xk @ params["wk"].astype(cdt)).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = (xv @ params["wv"].astype(cdt)).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ params["wg"].astype(cdt))

    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(x̃)))
    lora = jnp.tanh(xw.astype(jnp.float32) @ params["w_lora_a"]) @ params["w_lora_b"]
    logw = params["w0"][None, None] + lora                       # [B, T, D]
    w = jnp.exp(-jnp.exp(logw)).reshape(b, t, h, hd).transpose(0, 2, 1, 3)

    r = constrain(r, BATCH_AXES, TP_AXIS, None, None)
    k = constrain(k, BATCH_AXES, TP_AXIS, None, None)
    v = constrain(v, BATCH_AXES, TP_AXIS, None, None)
    w = constrain(w, BATCH_AXES, TP_AXIS, None, None)

    if wkv_state is None:
        wkv_state = jnp.zeros((b, h, hd, hd), jnp.float32)
    if active_mesh() is None:
        o, s_fin = rwkv6_diff(
            r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            w.astype(jnp.float32), params["bonus_u"], wkv_state,
            chunk=min(128, t),
        )
    else:
        o, s_fin = rwkv6_ref(
            r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            w.astype(jnp.float32), params["bonus_u"], wkv_state,
        )
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)

    # per-head group norm (ln_x), then gate
    of = o.astype(jnp.float32).reshape(b, t, h, hd)
    var = jnp.mean(of * of, axis=-1, keepdims=True)
    of = of * jax.lax.rsqrt(var + 64e-5)
    o = (of.reshape(b, t, d) * params["ln_x"][None, None].astype(jnp.float32)).astype(cdt)
    out = (o * g) @ params["wo"].astype(cdt)
    out = constrain(out, BATCH_AXES, None, None)
    if return_state:
        return out, (last, s_fin)
    return out


def rwkv_channel_mix(
    params, x, cfg, *,
    shift_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
):
    cdt = jnp.dtype(cfg.compute_dtype)
    prev, last = _token_shift(x, shift_state)
    xk = _lerp(x, prev, params["cmu_k"])
    xr = _lerp(x, prev, params["cmu_r"])
    kk = jnp.square(jax.nn.relu(xk @ params["ck"].astype(cdt)))
    kk = constrain(kk, BATCH_AXES, None, TP_AXIS)
    vv = kk @ params["cv"].astype(cdt)
    rr = jax.nn.sigmoid(xr @ params["cr"].astype(cdt))
    out = constrain(rr * vv, BATCH_AXES, None, None)
    if return_state:
        return out, last
    return out
