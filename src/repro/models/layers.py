"""Shared building blocks: param definitions, norms, RoPE, embeddings, loss.

Parameters are plain nested dicts of arrays.  Every module exposes a
``*_defs(cfg)`` function returning a dict of :class:`ParamDef` — the single
source of truth for shapes, initializers *and* partition specs, consumed by
``init_from_defs`` (materialization) and ``specs_from_defs`` (dry-run
ShapeDtypeStructs + pjit shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.api import FSDP_AXIS, TP_AXIS

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: Tuple[Any, ...]            # logical partition entries, len == ndim
    init: str = "fan_in"             # fan_in | normal | zeros | ones
    dtype: str = "bfloat16"
    keep_fsdp: bool = False          # retain 'data' sharding even when fsdp=False
    # (serving: dense weights replicate over data, experts stay 2-D sharded)

    def with_layer_dim(self, n_layers: int) -> "ParamDef":
        return dataclasses.replace(
            self, shape=(n_layers, *self.shape), spec=(None, *self.spec)
        )


def _leaf_key(key: jax.Array, path: str) -> jax.Array:
    return jax.random.fold_in(key, abs(hash(path)) % (2**31))


def init_from_defs(defs: Dict[str, Any], key: jax.Array, prefix: str = "") -> Pytree:
    out = {}
    for name, d in defs.items():
        path = f"{prefix}/{name}"
        if isinstance(d, dict):
            out[name] = init_from_defs(d, key, path)
            continue
        dtype = jnp.dtype(d.dtype)
        if d.init == "zeros":
            out[name] = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            out[name] = jnp.ones(d.shape, dtype)
        else:
            k = _leaf_key(key, path)
            if d.init == "fan_in" and len(d.shape) >= 2:
                scale = (d.shape[-2]) ** -0.5
            else:
                scale = 0.02
            out[name] = (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dtype)
    return out


def specs_from_defs(defs: Dict[str, Any], fsdp: bool) -> Pytree:
    """PartitionSpec tree.  fsdp=False strips the 'data' axis from specs."""
    out = {}
    for name, d in defs.items():
        if isinstance(d, dict):
            out[name] = specs_from_defs(d, fsdp)
            continue
        entries = []
        for e in d.spec:
            if not fsdp and not d.keep_fsdp:
                if e == FSDP_AXIS:
                    e = None
                elif isinstance(e, tuple):
                    e = tuple(a for a in e if a != FSDP_AXIS) or None
            entries.append(e)
        out[name] = P(*entries)
    return out


def shapes_from_defs(defs: Dict[str, Any]) -> Pytree:
    out = {}
    for name, d in defs.items():
        out[name] = (
            shapes_from_defs(d) if isinstance(d, dict)
            else jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype))
        )
    return out


def shardings_from_defs(defs: Dict[str, Any], fsdp: bool, mesh) -> Pytree:
    """NamedShardings with divisibility filtering (see api.shard_by_shape)."""
    from repro.distributed.api import shard_by_shape

    specs = specs_from_defs(defs, fsdp)
    shapes = shapes_from_defs(defs)
    return jax.tree.map(
        lambda sp, sd: shard_by_shape(sp, sd.shape, mesh), specs, shapes,
        is_leaf=lambda v: isinstance(v, P),
    )


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, H, S, Dh]; positions: [B, S] absolute token positions."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                              # [Dh/2]
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,S,Dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(
    logits: jnp.ndarray,       # [B, S, V] (V may be sharded over 'model')
    labels: jnp.ndarray,       # [B, S]
    mask: Optional[jnp.ndarray] = None,
    *,
    z_loss: float = 0.0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse**2
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((logits.argmax(-1) == labels) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}


def embed_defs(cfg) -> Dict[str, ParamDef]:
    d = {"embedding": ParamDef((cfg.vocab, cfg.d_model), (TP_AXIS, FSDP_AXIS), "normal", cfg.param_dtype)}
    if not cfg.tie_embeddings:
        d["lm_head"] = ParamDef((cfg.d_model, cfg.vocab), (FSDP_AXIS, TP_AXIS), "fan_in", cfg.param_dtype)
    return d


def norm_def(cfg) -> ParamDef:
    return ParamDef((cfg.d_model,), (None,), "ones", cfg.param_dtype)
