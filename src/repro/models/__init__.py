"""Model substrate: configs, layers, and the two model families."""
from .config import ArchConfig, ShapeConfig, SHAPES
from .encdec import EncDecLM
from .lm import CausalLM


def build_model(cfg: ArchConfig):
    return EncDecLM(cfg) if cfg.is_encdec else CausalLM(cfg)


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "CausalLM", "EncDecLM", "build_model"]
