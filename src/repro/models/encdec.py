"""Encoder–decoder model (seamless-m4t family, audio frontend stub).

Encoder: bidirectional attention over projected frame embeddings.
Decoder: causal self-attention + cross-attention against encoder memory.

Serving decomposes as the brief's shapes require:
  * ``prefill_32k``  — encode 32k frames + precompute per-layer cross-K/V.
  * ``decode_32k``   — one decoder token against the 32k encoder memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import BATCH_AXES, TP_AXIS, constrain
from .attention import (
    attn_defs, cross_attention, kv_cache_spec, project_cross_kv,
    self_attention_decode, self_attention_full,
)
from .config import ArchConfig
from .frontends import frontend_defs, project_frontend
from .layers import (
    ParamDef, cross_entropy_loss, embed_defs, init_from_defs, norm_def,
    rms_norm, shapes_from_defs, specs_from_defs,
)
from .mlp import mlp, mlp_defs

Pytree = Any


def _enc_block_defs(cfg):
    return {"norm1": norm_def(cfg), "attn": attn_defs(cfg),
            "norm2": norm_def(cfg), "ffn": mlp_defs(cfg)}


def _dec_block_defs(cfg):
    return {"norm1": norm_def(cfg), "self_attn": attn_defs(cfg),
            "norm2": norm_def(cfg), "cross_attn": attn_defs(cfg),
            "norm3": norm_def(cfg), "ffn": mlp_defs(cfg)}


def _stack(defs: Dict[str, Any], n: int) -> Dict[str, Any]:
    return jax.tree.map(lambda d: d.with_layer_dim(n), defs,
                        is_leaf=lambda v: isinstance(v, ParamDef))


@dataclasses.dataclass(frozen=True)
class EncDecLM:
    cfg: ArchConfig

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": embed_defs(cfg),
            "frontend": frontend_defs(cfg),
            "encoder": _stack(_enc_block_defs(cfg), cfg.enc_layers),
            "enc_norm": norm_def(cfg),
            "decoder": _stack(_dec_block_defs(cfg), cfg.dec_layers),
            "final_norm": norm_def(cfg),
        }

    def init(self, key):
        return init_from_defs(self.param_defs(), key)

    def param_specs(self):
        return specs_from_defs(self.param_defs(), self.cfg.fsdp)

    def param_shapes(self):
        return shapes_from_defs(self.param_defs())

    def param_shardings(self, mesh):
        from .layers import shardings_from_defs
        return shardings_from_defs(self.param_defs(), self.cfg.fsdp, mesh)

    # ---- encoder ------------------------------------------------------------
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        h = project_frontend(params["frontend"], frames, cfg)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)

        def body(h, layer):
            x = rms_norm(h, layer["norm1"], cfg.norm_eps)
            h = h + self_attention_full(layer["attn"], x, positions, cfg, causal=False)
            x = rms_norm(h, layer["norm2"], cfg.norm_eps)
            h = h + mlp(layer["ffn"], x, cfg)
            return h, None

        if cfg.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(body, h, params["encoder"], unroll=cfg.scan_unroll)
        return rms_norm(h, params["enc_norm"], cfg.norm_eps)

    # ---- decoder ------------------------------------------------------------
    def _decode_stack_full(self, params, tokens, memory):
        cfg = self.cfg
        b, s = tokens.shape
        h = jnp.take(params["embed"]["embedding"], tokens, axis=0).astype(
            jnp.dtype(cfg.compute_dtype))
        h = constrain(h, BATCH_AXES, None, None)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)

        def body(h, layer):
            x = rms_norm(h, layer["norm1"], cfg.norm_eps)
            h = h + self_attention_full(layer["self_attn"], x, positions, cfg)
            x = rms_norm(h, layer["norm2"], cfg.norm_eps)
            mk, mv = project_cross_kv(layer["cross_attn"], memory, cfg)
            h = h + cross_attention(layer["cross_attn"], x, mk, mv, cfg)
            x = rms_norm(h, layer["norm3"], cfg.norm_eps)
            h = h + mlp(layer["ffn"], x, cfg)
            return h, None

        if cfg.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(body, h, params["decoder"], unroll=cfg.scan_unroll)
        return rms_norm(h, params["final_norm"], cfg.norm_eps)

    def _logits(self, params, h):
        cfg = self.cfg
        logits = h @ params["embed"]["lm_head"].astype(jnp.dtype(cfg.compute_dtype))
        return constrain(logits, BATCH_AXES, None, TP_AXIS)

    # ---- training -----------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        memory = self.encode(params, batch["frames"])
        h = self._decode_stack_full(params, batch["tokens"], memory)
        logits = self._logits(params, h)
        return cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))

    # ---- serving -------------------------------------------------------------
    def cache_defs(self, batch_size: int, cache_len: int, enc_len: int):
        cfg = self.cfg
        kv_spec = tuple(kv_cache_spec(cfg))
        cdt = cfg.compute_dtype
        kv = lambda s_: ParamDef((batch_size, cfg.n_kv_heads, s_, cfg.hd),
                                 kv_spec, "zeros", cdt)
        per_layer = {
            "k": kv(cache_len), "v": kv(cache_len),
            "cross_k": kv(enc_len), "cross_v": kv(enc_len),
        }
        return _stack(per_layer, cfg.dec_layers)

    def cache_shapes(self, batch_size, cache_len, enc_len):
        return shapes_from_defs(self.cache_defs(batch_size, cache_len, enc_len))

    def cache_specs(self, batch_size, cache_len, enc_len):
        return specs_from_defs(self.cache_defs(batch_size, cache_len, enc_len), fsdp=True)

    def cache_shardings(self, batch_size, cache_len, enc_len, mesh):
        from .layers import shardings_from_defs
        return shardings_from_defs(
            self.cache_defs(batch_size, cache_len, enc_len), True, mesh)

    def init_cache(self, batch_size, cache_len, enc_len):
        return init_from_defs(self.cache_defs(batch_size, cache_len, enc_len),
                              jax.random.PRNGKey(0))

    def prefill(self, params, batch, cache_len: int):
        """Encode frames; precompute cross-K/V; empty self cache."""
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        b = memory.shape[0]

        def collect(_, layer):
            mk, mv = project_cross_kv(layer["cross_attn"], memory, cfg)
            return None, (mk, mv)

        _, (mks, mvs) = jax.lax.scan(collect, None, params["decoder"])
        cache = {
            "k": jnp.zeros((cfg.dec_layers, b, cfg.n_kv_heads, cache_len, cfg.hd),
                           jnp.dtype(cfg.compute_dtype)),
            "v": jnp.zeros((cfg.dec_layers, b, cfg.n_kv_heads, cache_len, cfg.hd),
                           jnp.dtype(cfg.compute_dtype)),
            "cross_k": mks.astype(jnp.dtype(cfg.compute_dtype)),
            "cross_v": mvs.astype(jnp.dtype(cfg.compute_dtype)),
        }
        lengths = jnp.zeros((b,), jnp.int32)
        return cache, lengths

    def decode(self, params, cache, tokens, lengths):
        cfg = self.cfg
        b, t = tokens.shape
        h = jnp.take(params["embed"]["embedding"], tokens, axis=0).astype(
            jnp.dtype(cfg.compute_dtype))
        h = constrain(h, BATCH_AXES, None, None)

        def body(h, xs):
            layer, c = xs
            x = rms_norm(h, layer["norm1"], cfg.norm_eps)
            o, ck, cv = self_attention_decode(layer["self_attn"], x, cfg,
                                              c["k"], c["v"], lengths)
            h = h + o
            x = rms_norm(h, layer["norm2"], cfg.norm_eps)
            h = h + cross_attention(layer["cross_attn"], x, c["cross_k"], c["cross_v"], cfg)
            x = rms_norm(h, layer["norm3"], cfg.norm_eps)
            h = h + mlp(layer["ffn"], x, cfg)
            return h, {"k": ck, "v": cv, "cross_k": c["cross_k"], "cross_v": c["cross_v"]}

        h, new_cache = jax.lax.scan(body, h, (params["decoder"], cache),
                                    unroll=cfg.scan_unroll)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, h)
        return logits, new_cache, lengths + t
