"""Layer-stack composition: period-pattern scan over heterogeneous blocks.

Architectures mix block kinds (jamba: 7 mamba + 1 attention per period; MoE
every other layer).  To keep the lowered HLO small (one while-loop, not 94
inlined layers — critical for 80 dry-run compiles on one CPU), layers are
grouped into *periods*: the layer pattern repeats every
``lcm(attn_every, moe_every)`` layers, parameters are stacked per pattern
position over periods, and the stack runs as one ``lax.scan`` whose body
executes one period (pattern positions unrolled).

Caches thread through the same scan: per pattern position, a stacked
[n_periods, ...] cache leaf is consumed (xs) and re-emitted (ys).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    attn_defs, self_attention_decode, self_attention_full,
)
from .layers import ParamDef, norm_def, rms_norm
from .mlp import mlp, mlp_defs
from .moe import moe, moe_defs
from .rwkv import rwkv_channel_mix, rwkv_defs, rwkv_time_mix
from .ssm import mamba, mamba_defs

Pytree = Any


def layer_pattern(cfg) -> Tuple[List[Tuple[str, bool]], int]:
    """[(kind, is_moe)] over one period + the period count."""
    kinds = cfg.block_kinds()
    period = 1
    if cfg.attn_every:
        period = math.lcm(period, cfg.attn_every)
    if cfg.n_experts:
        period = math.lcm(period, cfg.moe_every)
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    pattern = [(kinds[i], cfg.layer_is_moe(i) and kinds[i] != "rwkv") for i in range(period)]
    return pattern, cfg.n_layers // period


def block_defs(cfg, kind: str, is_moe: bool) -> Dict[str, Any]:
    """ParamDefs of one block (pre-norms + mixer + feed-forward)."""
    defs: Dict[str, Any] = {"norm1": norm_def(cfg)}
    if kind == "attn":
        defs["attn"] = attn_defs(cfg)
    elif kind == "mamba":
        defs["mamba"] = mamba_defs(cfg)
    elif kind == "rwkv":
        defs["time_mix"] = rwkv_defs(cfg)
        # rwkv block = time-mix + channel-mix, no separate mlp
        defs["norm2"] = norm_def(cfg)
        return defs
    defs["norm2"] = norm_def(cfg)
    defs["ffn"] = moe_defs(cfg) if is_moe else mlp_defs(cfg)
    return defs


def stack_defs(cfg) -> Dict[str, Any]:
    """All block params: {"pos{j}": defs stacked over periods}."""
    pattern, n_periods = layer_pattern(cfg)
    out = {}
    for j, (kind, is_moe) in enumerate(pattern):
        defs = block_defs(cfg, kind, is_moe)
        out[f"pos{j}"] = jax.tree.map(
            lambda d: d.with_layer_dim(n_periods), defs,
            is_leaf=lambda v: isinstance(v, ParamDef),
        )
    return out


# ---------------------------------------------------------------------------
# forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------


def _block_full(params, x, positions, cfg, kind: str, is_moe: bool,
                *, window=None, collect_cache: bool):
    """One block, full-sequence path.  Returns (x, cache_leaf_or_None)."""
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    cache = None
    if kind == "attn":
        if collect_cache:
            o, (k, v) = self_attention_full(
                params["attn"], h, positions, cfg, window=window, return_kv=True
            )
            cache = {"k": k, "v": v}
        else:
            o = self_attention_full(params["attn"], h, positions, cfg, window=window)
        x = x + o
    elif kind == "mamba":
        if collect_cache:
            o, (conv_s, ssm_s) = mamba(params["mamba"], h, cfg, return_state=True)
            cache = {"conv": conv_s, "ssm": ssm_s}
        else:
            x_o = mamba(params["mamba"], h, cfg)
            o = x_o
        x = x + o
    elif kind == "rwkv":
        if collect_cache:
            o, (tm_last, wkv) = rwkv_time_mix(params["time_mix"], h, cfg, return_state=True)
            x = x + o
            h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
            o2, cm_last = rwkv_channel_mix(params["time_mix"], h2, cfg, return_state=True)
            x = x + o2
            return x, {"tm_shift": tm_last, "wkv": wkv, "cm_shift": cm_last}
        o = rwkv_time_mix(params["time_mix"], h, cfg)
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + rwkv_channel_mix(params["time_mix"], h2, cfg)
        return x, None
    h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
    ffn = moe if is_moe else mlp
    x = x + ffn(params["ffn"], h2, cfg)
    return x, cache


def run_stack_full(stack_params, x, positions, cfg, *, window=None,
                   collect_cache: bool = False):
    """Scan all periods.  Returns (x, caches or None).

    caches: {"pos{j}": stacked-[n_periods, ...] cache pytree}.
    """
    pattern, n_periods = layer_pattern(cfg)

    per_block = cfg.remat and cfg.remat_policy == "per_block"

    def one_block(j, kind, is_moe, params_j, h):
        return _block_full(params_j, h, positions, cfg, kind, is_moe,
                           window=window, collect_cache=collect_cache)

    def period_body(h, xs):
        caches = {}
        for j, (kind, is_moe) in enumerate(pattern):
            fn = functools.partial(one_block, j, kind, is_moe)
            if per_block:
                # §Perf: recompute at block granularity — the period-level
                # checkpoint re-materializes a whole 8-block jamba period at
                # once (≈500 GB/device temp); per-block bounds the recompute
                # working set to one block.
                fn = jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.nothing_saveable)
            h, c = fn(xs[f"pos{j}"], h)
            if collect_cache:
                caches[f"pos{j}"] = c
        return h, (caches if collect_cache else None)

    body = period_body
    if cfg.remat and not per_block:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(period_body, policy=policy)
    x, caches = jax.lax.scan(body, x, stack_params, unroll=cfg.scan_unroll)
    return x, caches


# ---------------------------------------------------------------------------
# decode (incremental, stateful)
# ---------------------------------------------------------------------------


def _block_decode(params, x, cfg, kind: str, is_moe: bool, cache, lengths,
                  *, window=None):
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind == "attn":
        o, ck, cv = self_attention_decode(
            params["attn"], h, cfg, cache["k"], cache["v"], lengths, window=window
        )
        cache = {"k": ck, "v": cv}
        x = x + o
    elif kind == "mamba":
        o, (conv_s, ssm_s) = mamba(
            params["mamba"], h, cfg,
            conv_state=cache["conv"], ssm_state=cache["ssm"], return_state=True,
        )
        cache = {"conv": conv_s, "ssm": ssm_s}
        x = x + o
    elif kind == "rwkv":
        o, (tm_last, wkv) = rwkv_time_mix(
            params["time_mix"], h, cfg,
            shift_state=cache["tm_shift"], wkv_state=cache["wkv"], return_state=True,
        )
        x = x + o
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        o2, cm_last = rwkv_channel_mix(
            params["time_mix"], h2, cfg, shift_state=cache["cm_shift"], return_state=True
        )
        x = x + o2
        return x, {"tm_shift": tm_last, "wkv": wkv, "cm_shift": cm_last}
    h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
    ffn = moe if is_moe else mlp
    x = x + ffn(params["ffn"], h2, cfg)
    return x, cache


def run_stack_decode(stack_params, x, cfg, caches, lengths, *, window=None):
    """One decode step through all periods; caches updated functionally."""
    pattern, _ = layer_pattern(cfg)

    def period_body(h, xs):
        params, cache = xs
        new_caches = {}
        for j, (kind, is_moe) in enumerate(pattern):
            h, c = _block_decode(params[f"pos{j}"], h, cfg, kind, is_moe,
                                 cache[f"pos{j}"], lengths, window=window)
            new_caches[f"pos{j}"] = c
        return h, new_caches

    x, new_caches = jax.lax.scan(period_body, x, (stack_params, caches),
                                 unroll=cfg.scan_unroll)
    return x, new_caches
