"""Modality frontends — STUBS per the brief.

``[vlm]`` / ``[audio]`` architectures specify the transformer backbone only;
``input_specs()`` provides *precomputed* patch/frame embeddings.  The stub is
a single learned projection from the frontend feature width to d_model —
enough to exercise the real data path (prefix fusion, masking, sharding)
without reproducing SigLIP / w2v-BERT.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.distributed.api import BATCH_AXES, FSDP_AXIS, constrain
from .layers import ParamDef


def frontend_defs(cfg) -> Dict[str, ParamDef]:
    return {
        "proj": ParamDef((cfg.frontend_dim, cfg.d_model), (None, FSDP_AXIS),
                         "fan_in", cfg.param_dtype),
    }


def project_frontend(params, feats: jnp.ndarray, cfg) -> jnp.ndarray:
    """feats [B, P, frontend_dim] → [B, P, D] prefix embeddings."""
    out = feats.astype(jnp.dtype(cfg.compute_dtype)) @ params["proj"].astype(
        jnp.dtype(cfg.compute_dtype)
    )
    return constrain(out, BATCH_AXES, None, None)
