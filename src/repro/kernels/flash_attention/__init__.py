from .kernel import flash_attention
from .ops import flash_attention_diff, mha
from .ref import attention_ref

__all__ = ["flash_attention", "flash_attention_diff", "mha", "attention_ref"]
