"""Jit'd public wrappers for flash attention.

``mha`` dispatches between the Pallas kernel (train/prefill hot path) and a
plain XLA fallback.  ``flash_attention_diff`` wraps the kernel in a
``custom_vjp``: Pallas forward, reference-VJP backward (the TPU production
path would pair it with a flash backward kernel; on this CPU target the
backward recompute goes through the jnp oracle — documented in DESIGN.md).
"""
from __future__ import annotations

import functools
from functools import partial
from typing import Optional

import jax

from .kernel import flash_attention
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "scale", "impl", "interpret"))
def mha(
    q, k, v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    impl: str = "pallas",
    interpret: bool = True,
):
    """Multi-head attention [B, H, S, Dh] with GQA kv broadcast."""
    if impl == "pallas":
        return flash_attention(
            q, k, v, causal=causal, window=window, scale=scale, interpret=interpret
        )
    if impl == "xla":
        return attention_ref(q, k, v, causal=causal, window=window, scale=scale)
    raise ValueError(f"unknown attention impl: {impl}")


@functools.lru_cache(maxsize=None)
def _diff_attention(causal: bool, window: Optional[int], scale: Optional[float],
                    bq: int, bkv: int):
    @jax.custom_vjp
    def f(q, k, v):
        return flash_attention(q, k, v, causal=causal, window=window, scale=scale,
                               bq=bq, bkv=bkv)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                             window=window, scale=scale),
            q, k, v,
        )
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def flash_attention_diff(q, k, v, *, causal=True, window=None, scale=None,
                         bq=128, bkv=128):
    """Differentiable flash attention: Pallas fwd, reference-VJP bwd."""
    return _diff_attention(causal, window, scale, bq, bkv)(q, k, v)
