"""Pallas TPU kernel: flash attention forward (GQA, causal, sliding window).

The LM substrate's dominant compute hot-spot.  Online-softmax streaming over
key/value tiles keeps the working set in VMEM regardless of sequence length:

    q tile    [BQ, Dh]      (resident across the kv walk)
    k,v tiles [BKV, Dh]     (streamed, double-buffered by the pipeline)
    scratch   m [BQ], l [BQ], acc [BQ, Dh]

Grid = (B, Hq, Sq/BQ, Skv/BKV) with the kv axis innermost: scratch persists
across the kv walk of one (b, h, iq) cell (TPU grid steps are sequential) and
the output tile is written once at the last kv step.  GQA is expressed in the
k/v BlockSpec index maps (query head h reads kv head h // group) — no repeated
kv materialization in HBM.

The two matmuls per step are [BQ,Dh]@[Dh,BKV] and [BQ,BKV]@[BKV,Dh]; with
BQ = BKV = 128 and Dh ∈ {64, 128, 256} every MXU dim is 128-aligned.
Numerics follow the standard streaming-softmax recurrence in f32; fully
masked tiles are handled by zeroing probabilities (never exp of a sentinel).

Positions are aligned to the *ends* of q/kv (decode convention): query i has
absolute position skv - sq + i.  Causal skip of fully-masked tiles is a
masking no-op here (interpret-mode correctness target); on hardware the same
grid supports `pltpu.emit_pipeline`-style early-exit — see EXPERIMENTS.md
§Perf for how we count the causal/window FLOP discount in the roofline.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BKV = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int],
    bq: int, bkv: int, sq: int, skv: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                       # [BQ, Dh]
    k = k_ref[0, 0].astype(jnp.float32)                       # [BKV, Dh]
    v = v_ref[0, 0].astype(jnp.float32)                       # [BKV, Dh]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                                 # [BQ, BKV]

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + (skv - sq)
    kpos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < skv  # padded kv tail is never attendable
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)                           # exp(-inf - -inf) guarded below
    alpha = jnp.where(m_prev == NEG_INF, 0.0, alpha)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)

    l_new = alpha * l_scr[...] + p.sum(axis=-1)
    acc_new = alpha[:, None] * acc_scr[...] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bkv", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, Hq, Sq, Dh]
    k: jnp.ndarray,  # [B, Hkv, Skv, Dh]
    v: jnp.ndarray,  # [B, Hkv, Skv, Dh]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    bq: int = DEFAULT_BQ,
    bkv: int = DEFAULT_BKV,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = dh ** -0.5

    bq_ = min(bq, sq)
    bkv_ = min(bkv, skv)
    # Pad sequence dims to tile multiples; padded kv keys are masked off via
    # positions (kpos >= skv never satisfies kpos <= qpos for real queries).
    sq_pad = -(-sq // bq_) * bq_
    skv_pad = -(-skv // bkv_) * bkv_
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))

    grid = (b, hq, sq_pad // bq_, skv_pad // bkv_)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        bq=bq_, bkv=bkv_, sq=sq, skv=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq_, dh), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bkv_, dh), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bkv_, dh), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, dh), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_pad, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
