"""Pure-jnp oracle for fused (flash) attention with GQA / causal / window."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # [B, Hq, Sq, Dh]
    k: jnp.ndarray,  # [B, Hkv, Skv, Dh]
    v: jnp.ndarray,  # [B, Hkv, Skv, Dh]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Dense softmax attention; kv heads broadcast over query-head groups."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    if scale is None:
        scale = dh ** -0.5

    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)) * scale

    # Query position i attends to key position j (aligned to sequence ends:
    # query i sits at absolute position skv - sq + i, the decode convention).
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(q.dtype)
