"""Public wrappers for the contingency kernels.

Handles the TPU lane-width padding of the decision axis (M → multiple of 128)
and unpadding of the result; callers see the logical ``[nc, n_bins, n_dec]``
(unfused) or ``[nc]`` (fused Θ).

Tile resolution happens *here*, in plain Python, before the jitted inner
calls: passing ``bk=None``/``bg=None`` (and ``bc=None`` for the sweep) routes
through :func:`repro.kernels.contingency.autotune.resolve_tiles`, whose
default mode is the **analytic** roofline selector (DESIGN.md §5.2).  The
resolved tiles become ordinary static arguments of the jitted kernels, so
every compiled executable is keyed on its concrete tiling — no selector
state is ever baked into a trace, and switching ``selector`` can never serve
a stale compile.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# Re-exported for kernel callers: the row math and its normalization live in
# one module (repro.core.measures).
from repro.core.measures import theta_scale  # noqa: F401  (public re-export)

from .autotune import resolve_tiles, select_block_sizes  # noqa: F401 (re-export)
from .fused import fused_theta_pallas
from .kernel import contingency_pallas
from .sweep import sweep_theta_pallas

LANE = 128


def _lane_padded_wd(w: jnp.ndarray, d: jnp.ndarray, n_dec: int):
    """w ⊙ one-hot(d) with the decision axis padded to the 128 lane width.

    The single home of the kernels' padding contract: padded columns are
    all-zero, so they contribute 0 to every count and every θ' epilogue.
    """
    m_pad = -(-n_dec // LANE) * LANE
    wd = w[:, None] * (d[:, None] == jnp.arange(m_pad)[None, :]).astype(jnp.float32)
    return wd, m_pad


def contingency(
    packed: jnp.ndarray,   # [nc, G] int32
    d: jnp.ndarray,        # [G] int32
    w: jnp.ndarray,        # [G] float32 (already masked: 0 on padding slots)
    *,
    n_bins: int,
    n_dec: int,
    bk: Optional[int] = None,
    bg: Optional[int] = None,
    interpret: bool = True,
    selector: Optional[str] = None,
) -> jnp.ndarray:
    """counts[c, k, j] = Σ_g w_g · 1[packed[c,g]=k] · 1[d_g=j]."""
    nc, g = packed.shape
    m_pad = -(-n_dec // LANE) * LANE
    if bk is None or bg is None:
        rk, rg = resolve_tiles("contingency", nc=nc, g=g, n_bins=n_bins,
                               m=m_pad, selector=selector)
        bk = rk if bk is None else bk
        bg = rg if bg is None else bg
    return _contingency_jit(packed, d, w, n_bins=n_bins, n_dec=n_dec,
                            bk=bk, bg=bg, interpret=interpret)


@partial(jax.jit, static_argnames=("n_bins", "n_dec", "bk", "bg", "interpret"))
def _contingency_jit(packed, d, w, *, n_bins, n_dec, bk, bg, interpret):
    wd, _ = _lane_padded_wd(w, d, n_dec)
    out = contingency_pallas(packed, wd, n_bins=n_bins, bk=bk, bg=bg,
                             interpret=interpret)
    return out[:, :, :n_dec]


def fused_theta(
    packed: jnp.ndarray,   # [nc, G] int32
    d: jnp.ndarray,        # [G] int32
    w: jnp.ndarray,        # [G] float32 (already masked: 0 on padding slots)
    n,                     # |U| scalar — normalization only, never enters the kernel
    *,
    delta: str,
    n_bins: int,
    n_dec: int,
    bk: Optional[int] = None,
    bg: Optional[int] = None,
    interpret: bool = True,
    selector: Optional[str] = None,
) -> jnp.ndarray:
    """Θ(D|B∪{a})[c] without materializing the [nc, K, M] contingency tensor.

    Semantics: ``measures.evaluate(delta, contingency(...), n)`` with the θ
    row-reduction fused into the kernel's accumulation epilogue (DESIGN.md
    §5.2).  Default tiling comes from the analytic selector.
    """
    nc, g = packed.shape
    m_pad = -(-n_dec // LANE) * LANE
    if bk is None or bg is None:
        rk, rg = resolve_tiles("fused", nc=nc, g=g, n_bins=n_bins, m=m_pad,
                               delta=delta, selector=selector)
        bk = rk if bk is None else bk
        bg = rg if bg is None else bg
    return _fused_theta_jit(packed, d, w, n, delta=delta, n_bins=n_bins,
                            n_dec=n_dec, bk=bk, bg=bg, interpret=interpret)


@partial(jax.jit, static_argnames=("delta", "n_bins", "n_dec", "bk", "bg",
                                   "interpret"))
def _fused_theta_jit(packed, d, w, n, *, delta, n_bins, n_dec, bk, bg,
                     interpret):
    wd, _ = _lane_padded_wd(w, d, n_dec)
    raw = fused_theta_pallas(
        packed, wd, n_bins=n_bins, delta=delta, bk=bk, bg=bg,
        interpret=interpret)
    return theta_scale(delta, raw, n)


def sweep_theta(
    x_t: jnp.ndarray,      # [nc, G] int32 — pre-transposed candidate slab
    r_ids: jnp.ndarray,    # [G]     int32 — shared class ids of U/R
    d: jnp.ndarray,        # [G]     int32
    w: jnp.ndarray,        # [G]   float32 (already masked: 0 on padding slots)
    n,                     # |U| scalar — normalization only, never enters the kernel
    *,
    delta: str,
    v_max: int,
    n_bins: int,
    n_dec: int,
    bc: Optional[int] = None,
    bk: Optional[int] = None,
    bg: Optional[int] = None,
    interpret: bool = True,
    selector: Optional[str] = None,
) -> jnp.ndarray:
    """Θ(D|R∪{a})[c] from the read-once slab operands (DESIGN.md §5.3).

    Semantics: ``fused_theta(r_ids[None]·V + x_t, ...)`` with the id-packing
    fused into the kernel and each granule tile loaded once per candidate
    *block* — ``packed [nc, G]`` never reaches HBM.  ``n_bins`` may be any
    §5.3 ladder rung ≥ K·V.  Default ``(bc, bk, bg)`` come from the shared
    selector, whose sweep cost model prices the BC× shared-operand reuse.
    """
    nc, g = x_t.shape
    m_pad = -(-n_dec // LANE) * LANE
    if bc is None or bk is None or bg is None:
        rc, rk, rg = resolve_tiles("sweep", nc=nc, g=g, n_bins=n_bins,
                                   m=m_pad, v_max=v_max, delta=delta,
                                   selector=selector)
        bc = rc if bc is None else bc
        bk = rk if bk is None else bk
        bg = rg if bg is None else bg
    return _sweep_theta_jit(x_t, r_ids, d, w, n, delta=delta, v_max=v_max,
                            n_bins=n_bins, n_dec=n_dec, bc=bc, bk=bk, bg=bg,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("delta", "v_max", "n_bins", "n_dec", "bc",
                                   "bk", "bg", "interpret"))
def _sweep_theta_jit(x_t, r_ids, d, w, n, *, delta, v_max, n_bins, n_dec,
                     bc, bk, bg, interpret):
    wd, _ = _lane_padded_wd(w, d, n_dec)
    raw = sweep_theta_pallas(
        x_t, r_ids, wd, v_max=v_max, n_bins=n_bins, delta=delta, bc=bc,
        bk=bk, bg=bg, interpret=interpret)
    return theta_scale(delta, raw, n)
