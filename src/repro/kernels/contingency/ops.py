"""Jit'd public wrappers for the contingency kernels.

Handles the TPU lane-width padding of the decision axis (M → multiple of 128)
and unpadding of the result; callers see the logical ``[nc, n_bins, n_dec]``
(unfused) or ``[nc]`` (fused Θ).  Passing ``bk=None``/``bg=None`` defers the
tiling to the shape heuristic in :mod:`repro.kernels.contingency.autotune`.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# Re-exported for kernel callers: the row math and its normalization live in
# one module (repro.core.measures).
from repro.core.measures import theta_scale  # noqa: F401  (public re-export)

from .autotune import select_block_sizes
from .fused import fused_theta_pallas
from .kernel import DEFAULT_BG, DEFAULT_BK, contingency_pallas
from .sweep import DEFAULT_BC, sweep_theta_pallas

LANE = 128


def _resolve_blocks(n_bins: int, g: int, m_pad: int, bk, bg):
    if bk is None or bg is None:
        hk, hg = select_block_sizes(n_bins, g, m_pad)
        bk = hk if bk is None else bk
        bg = hg if bg is None else bg
    return bk, bg


def _lane_padded_wd(w: jnp.ndarray, d: jnp.ndarray, n_dec: int):
    """w ⊙ one-hot(d) with the decision axis padded to the 128 lane width.

    The single home of the kernels' padding contract: padded columns are
    all-zero, so they contribute 0 to every count and every θ' epilogue.
    """
    m_pad = -(-n_dec // LANE) * LANE
    wd = w[:, None] * (d[:, None] == jnp.arange(m_pad)[None, :]).astype(jnp.float32)
    return wd, m_pad


@partial(jax.jit, static_argnames=("n_bins", "n_dec", "bk", "bg", "interpret"))
def contingency(
    packed: jnp.ndarray,   # [nc, G] int32
    d: jnp.ndarray,        # [G] int32
    w: jnp.ndarray,        # [G] float32 (already masked: 0 on padding slots)
    *,
    n_bins: int,
    n_dec: int,
    bk: Optional[int] = DEFAULT_BK,
    bg: Optional[int] = DEFAULT_BG,
    interpret: bool = True,
) -> jnp.ndarray:
    """counts[c, k, j] = Σ_g w_g · 1[packed[c,g]=k] · 1[d_g=j]."""
    wd, m_pad = _lane_padded_wd(w, d, n_dec)
    bk, bg = _resolve_blocks(n_bins, packed.shape[1], m_pad, bk, bg)
    out = contingency_pallas(packed, wd, n_bins=n_bins, bk=bk, bg=bg, interpret=interpret)
    return out[:, :, :n_dec]


@partial(jax.jit, static_argnames=("delta", "n_bins", "n_dec", "bk", "bg", "interpret"))
def fused_theta(
    packed: jnp.ndarray,   # [nc, G] int32
    d: jnp.ndarray,        # [G] int32
    w: jnp.ndarray,        # [G] float32 (already masked: 0 on padding slots)
    n,                     # |U| scalar — normalization only, never enters the kernel
    *,
    delta: str,
    n_bins: int,
    n_dec: int,
    bk: Optional[int] = None,
    bg: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Θ(D|B∪{a})[c] without materializing the [nc, K, M] contingency tensor.

    Semantics: ``measures.evaluate(delta, contingency(...), n)`` with the θ
    row-reduction fused into the kernel's accumulation epilogue (DESIGN.md
    §5.2).  Default tiling comes from ``autotune.select_block_sizes``.
    """
    wd, m_pad = _lane_padded_wd(w, d, n_dec)
    bk, bg = _resolve_blocks(n_bins, packed.shape[1], m_pad, bk, bg)
    raw = fused_theta_pallas(
        packed, wd, n_bins=n_bins, delta=delta, bk=bk, bg=bg, interpret=interpret
    )
    return theta_scale(delta, raw, n)


@partial(jax.jit, static_argnames=("delta", "v_max", "n_bins", "n_dec", "bc",
                                   "bk", "bg", "interpret"))
def sweep_theta(
    x_t: jnp.ndarray,      # [nc, G] int32 — pre-transposed candidate slab
    r_ids: jnp.ndarray,    # [G]     int32 — shared class ids of U/R
    d: jnp.ndarray,        # [G]     int32
    w: jnp.ndarray,        # [G]   float32 (already masked: 0 on padding slots)
    n,                     # |U| scalar — normalization only, never enters the kernel
    *,
    delta: str,
    v_max: int,
    n_bins: int,
    n_dec: int,
    bc: int = DEFAULT_BC,
    bk: Optional[int] = None,
    bg: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Θ(D|R∪{a})[c] from the read-once slab operands (DESIGN.md §5.3).

    Semantics: ``fused_theta(r_ids[None]·V + x_t, ...)`` with the id-packing
    fused into the kernel and each granule tile loaded once per candidate
    *block* — ``packed [nc, G]`` never reaches HBM.  ``n_bins`` may be any
    §5.3 ladder rung ≥ K·V.
    """
    wd, m_pad = _lane_padded_wd(w, d, n_dec)
    bk, bg = _resolve_blocks(n_bins, x_t.shape[1], m_pad, bk, bg)
    raw = sweep_theta_pallas(
        x_t, r_ids, wd, v_max=v_max, n_bins=n_bins, delta=delta, bc=bc,
        bk=bk, bg=bg, interpret=interpret)
    return theta_scale(delta, raw, n)
