"""Jit'd public wrapper for the contingency kernel.

Handles the TPU lane-width padding of the decision axis (M → multiple of 128)
and unpadding of the result; callers see the logical ``[nc, n_bins, n_dec]``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BG, DEFAULT_BK, contingency_pallas

LANE = 128


@partial(jax.jit, static_argnames=("n_bins", "n_dec", "bk", "bg", "interpret"))
def contingency(
    packed: jnp.ndarray,   # [nc, G] int32
    d: jnp.ndarray,        # [G] int32
    w: jnp.ndarray,        # [G] float32 (already masked: 0 on padding slots)
    *,
    n_bins: int,
    n_dec: int,
    bk: int = DEFAULT_BK,
    bg: int = DEFAULT_BG,
    interpret: bool = True,
) -> jnp.ndarray:
    """counts[c, k, j] = Σ_g w_g · 1[packed[c,g]=k] · 1[d_g=j]."""
    m_pad = -(-n_dec // LANE) * LANE
    wd = w[:, None] * (d[:, None] == jnp.arange(m_pad)[None, :]).astype(jnp.float32)
    out = contingency_pallas(packed, wd, n_bins=n_bins, bk=bk, bg=bg, interpret=interpret)
    return out[:, :, :n_dec]
