"""Pure-jnp oracle for the batched contingency reduction.

``counts[c, k, j] = Σ_g w_g · 1[packed[c, g] = k] · 1[d_g = j]``

This is the paper's REDUCE phase (reduceByKey over ``(E⃗_B, E⃗_D)`` keys) after
id-packing has turned keys into compact integers — expressed as the dense
one-hot contraction that defines the Pallas kernel's semantics.
"""
from __future__ import annotations

import jax.numpy as jnp


def contingency_ref(
    packed: jnp.ndarray,  # [nc, G] int32, values in [0, n_bins)
    d: jnp.ndarray,       # [G]    int32, values in [0, n_dec)
    w: jnp.ndarray,       # [G]    float32 (0 for padding granules)
    *,
    n_bins: int,
    n_dec: int,
) -> jnp.ndarray:
    """Dense one-hot reference: O(nc · G · n_bins) flops, exact in f32."""
    onehot_k = (packed[..., None] == jnp.arange(n_bins)[None, None, :]).astype(jnp.float32)
    wd = w[:, None] * (d[:, None] == jnp.arange(n_dec)[None, :]).astype(jnp.float32)
    return jnp.einsum("cgk,gm->ckm", onehot_k, wd)


def fused_theta_ref(
    packed: jnp.ndarray,  # [nc, G] int32
    d: jnp.ndarray,       # [G]    int32
    w: jnp.ndarray,       # [G]    float32 (0 for padding granules)
    n,                    # |U| scalar
    *,
    delta: str,
    n_bins: int,
    n_dec: int,
) -> jnp.ndarray:
    """Oracle for the fused Θ kernel: unfused contingency + θ row-reduction.

    This is the defining semantics of ``ops.fused_theta`` — materialize the
    full contingency, then apply the measure's per-row sub-evaluation and sum
    (``Θ(D|B) = Σ_i θ(S_i)``, paper §3.2).
    """
    from repro.core import measures

    cont = contingency_ref(packed, d, w, n_bins=n_bins, n_dec=n_dec)
    return measures.theta_rows(delta, cont, n).sum(axis=-1)


def sweep_theta_ref(
    x_t: jnp.ndarray,      # [nc, G] int32 — pre-transposed candidate slab
    r_ids: jnp.ndarray,    # [G]     int32 — shared class ids of U/R
    d: jnp.ndarray,        # [G]     int32
    w: jnp.ndarray,        # [G]   float32 (0 for padding granules)
    n,                     # |U| scalar
    *,
    delta: str,
    v_max: int,
    n_bins: int,
    n_dec: int,
) -> jnp.ndarray:
    """Oracle for the multi-candidate sweep kernel (DESIGN.md §5.3).

    Defining semantics: pack explicitly (``p = r·V + v``), then the fused-Θ
    oracle — the sweep kernel must equal this for every ladder rung
    ``n_bins ≥ K·V``.
    """
    packed = r_ids[None, :] * v_max + x_t
    return fused_theta_ref(
        packed, d, w, n, delta=delta, n_bins=n_bins, n_dec=n_dec)
