"""Pallas TPU kernel: fused contingency→Θ reduction (DESIGN.md §5.2).

The unfused pipeline (``kernel.py`` → ``core.measures.evaluate``) materializes
the full ``[nc, K, M]`` contingency tensor in HBM even though every measure
(PR/SCE/LCE/CCE, paper Table 1/2) only needs a *per-row* sub-evaluation θ that
is then summed:  Θ(D|B) = Σ_i θ(S_i).  Because θ is row-separable and each
contingency row is complete once the G-axis grid walk of its ``[BK, M]`` tile
finishes, the θ epilogue can run inside the kernel — the contingency tensor
never leaves VMEM and the kernel's HBM output shrinks from O(nc·K·M) to
O(nc).

Schedule (grid = (nc, K/BK, G/BG), G innermost, same as the unfused kernel):

    pid_g == 0        init the VMEM accumulator tile with the one-hot matmul
    0 < pid_g         accumulate partial counts (MXU, [BK,BG] @ [BG,M])
    pid_g == nG - 1   EPILOGUE: θ per row of the finished [BK, M] tile,
                      Σ over BK rows, accumulate the scalar into out[c]

The four epilogues are branch-free (``jnp.where`` only, selected statically by
``delta``) and compute the *unnormalized* per-row sub-evaluation; the single
measure-dependent normalization by |U| (and the sign convention Θ_PR = -γ) is
one scalar multiply applied by the caller (``ops.fused_theta``) — keeping the
kernel free of scalar operands.  Padding is self-cancelling end to end:
padding granules carry a sentinel key outside every bin, padding bins are
all-zero rows, and θ of an all-zero row is exactly 0 for all four measures
(0·log 0 ≝ 0 — the ``where(c > 0, ·, 0)`` guards below).

VMEM working set per grid step: the unfused kernel's tiles plus the same
``[BK, M]`` accumulator it already kept resident — the fusion is free in VMEM
and removes the ``[nc, K, M]`` HBM round-trip from the hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The epilogues are the measures' own unnormalized row functions — one source
# of truth: plain branch-free jnp, so they trace inside the kernel unchanged.
from repro.core.measures import RAW_ROWS as EPILOGUES

from . import model
from .kernel import DEFAULT_BG, DEFAULT_BK, _cost_estimate


def _fused_kernel(packed_ref, wd_ref, out_ref, acc_ref, *, bk: int, delta: str):
    """One (candidate, bin-tile, granule-tile) grid step with θ epilogue."""
    pid_k = pl.program_id(1)
    pid_g = pl.program_id(2)
    n_g = pl.num_programs(2)

    p = packed_ref[0, :]                                    # [BG] int32
    bins = pid_k * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, p.shape[0]), 0)
    onehot = (p[None, :] == bins).astype(jnp.float32)       # [BK, BG]
    acc = jnp.dot(onehot, wd_ref[...], preferred_element_type=jnp.float32)  # [BK, M]

    @pl.when(pid_g == 0)
    def _init():
        acc_ref[...] = acc

    @pl.when(pid_g != 0)
    def _accum():
        acc_ref[...] += acc

    @pl.when(pid_g == n_g - 1)
    def _epilogue():
        partial = EPILOGUES[delta](acc_ref[...]).sum()      # scalar Θ partial

        @pl.when(pid_k == 0)
        def _first_tile():
            out_ref[0, 0] = partial

        @pl.when(pid_k != 0)
        def _later_tiles():
            out_ref[0, 0] += partial


@functools.partial(
    jax.jit,
    static_argnames=("n_bins", "delta", "bk", "bg", "interpret"),
)
def fused_theta_pallas(
    packed: jnp.ndarray,   # [nc, G] int32
    wd: jnp.ndarray,       # [G, M] float32 — w ⊙ one-hot(d), M lane-padded
    *,
    n_bins: int,
    delta: str,
    bk: int = DEFAULT_BK,
    bg: int = DEFAULT_BG,
    interpret: bool = True,
) -> jnp.ndarray:
    """Unnormalized Θ partials [nc]; see module docstring for the epilogue math.

    The caller applies the measure's sign/|U| normalization (``ops.fused_theta``).
    """
    if delta not in EPILOGUES:
        raise ValueError(f"unknown measure: {delta}")
    nc, g = packed.shape
    m = wd.shape[1]

    # Same padding contract as the unfused kernel: padding granules carry a
    # sentinel key matching no bin; padding bins are all-zero rows with θ = 0.
    g_pad = -(-g // bg) * bg
    k_pad = -(-n_bins // bk) * bk
    if g_pad != g:
        packed = jnp.pad(packed, ((0, 0), (0, g_pad - g)), constant_values=-1)
        wd = jnp.pad(wd, ((0, g_pad - g), (0, 0)))

    grid = (nc, k_pad // bk, g_pad // bg)

    out = pl.pallas_call(
        functools.partial(_fused_kernel, bk=bk, delta=delta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bg), lambda c, k, g_: (c, g_)),
            pl.BlockSpec((bg, m), lambda c, k, g_: (g_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda c, k, g_: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bk, m), jnp.float32)],
        cost_estimate=_cost_estimate(
            model.fused_cost(nc, g, n_bins, m, bk, bg, delta=delta)),
        interpret=interpret,
    )(packed, wd)
    return out[:, 0]
