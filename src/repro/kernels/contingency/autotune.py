"""Tile selection for the contingency kernels (DESIGN.md §5.2).

Three selector modes, shared by every kernel entry point
(:func:`resolve_tiles` is the one seam ``ops.py`` calls):

* ``analytic`` — **the default**: the closed-form roofline model of
  :mod:`repro.kernels.contingency.model` ranks every feasible aligned tiling
  and picks the best modeled time.  Free (no compiles), shape-exact, and
  consistent across processes.  Tuned picks persisted by
  :func:`autotune_block_sizes` (keyed by platform × kernel × shape bucket)
  override the model when present, so a service process reuses tunings
  measured elsewhere.
* ``heuristic`` — the PR-1 zero-cost shape rule (:func:`select_block_sizes`):
  largest MXU-aligned tile under the VMEM budget.  Kept as the legacy
  fallback and as a parity baseline for the selector tests.
* ``pinned`` — the kernels' module defaults (``DEFAULT_BK``/``BG``/``BC``),
  for pinning a known tiling in benchmarks and bisections.

:func:`autotune_block_sizes` is the measured refinement: the analytic rank
prunes the candidate grid to a top-k (default 3) **before any timing**, and
timing itself is opt-in (``refine=True``) — on this interpret-mode host
timings are meaningless, and on real hardware each timed candidate costs a
compile.  Winners land in a bounded in-memory LRU *and* the on-disk cache.
"""
from __future__ import annotations

import json
import logging
import os
import time
from collections import OrderedDict
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .model import (  # noqa: F401  (public re-exports: the constants' one home)
    LANE,
    SUBLANE,
    VMEM_BUDGET_BYTES,
    feasible_tiles,
    rank_tiles,
    select_tiles,
    sweep_working_set_bytes,
    working_set_bytes,
)

logger = logging.getLogger(__name__)

SELECTOR_MODES = ("heuristic", "analytic", "pinned")
DEFAULT_SELECTOR = "analytic"

# Candidate grid of the *measured* hook when the caller pins one explicitly;
# the default candidate set is the model's feasible enumeration.
CANDIDATE_BK = (128, 256, 512)
CANDIDATE_BG = (256, 512, 1024)

# In-memory tuning cache: bounded LRU (a long-lived service process sweeps
# many (K, G) regimes; the cache must not grow with them unboundedly).
_CACHE_MAXSIZE = 256
_CACHE: "OrderedDict[Tuple, Tuple[int, ...]]" = OrderedDict()
_CACHE_STATS = {"hits": 0, "misses": 0}

# On-disk tuning cache (shared across processes — the PR 5/6 service seam).
_DISK_ENV = "REPRO_AUTOTUNE_CACHE"
_disk_state: Dict[str, object] = {"path": None, "data": None}


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def select_block_sizes(
    n_bins: int,
    g: int,
    m: int,
    *,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> Tuple[int, int]:
    """Shape heuristic: largest aligned (BK, BG) fitting the VMEM budget.

    BK never exceeds the padded bin count (no all-padding bin tiles) and BG
    never exceeds the padded granule count; both stay hardware-aligned
    (sublane/lane multiples) so the one-hot matmul runs at full MXU occupancy.
    """
    bk = min(max(_round_up(n_bins, SUBLANE), SUBLANE), 512)
    # Prefer a full 128-row MXU tile when there are enough bins to fill it.
    if n_bins >= LANE:
        bk = max(bk, LANE)
        bk = min(bk, _round_up(n_bins, LANE))
    bg = min(max(_round_up(g, LANE), LANE), 1024)
    while bg > LANE and working_set_bytes(bk, bg, m) > vmem_budget:
        bg //= 2
    while bk > SUBLANE and working_set_bytes(bk, bg, m) > vmem_budget:
        bk = max(_round_up(bk // 2, SUBLANE), SUBLANE)  # halve, stay aligned
    return bk, bg


def _pinned_tiles(kernel: str) -> Tuple[int, ...]:
    if kernel == "sweep":
        from .sweep import DEFAULT_BC, DEFAULT_BG, DEFAULT_BK

        return (DEFAULT_BC, DEFAULT_BK, DEFAULT_BG)
    from .kernel import DEFAULT_BG, DEFAULT_BK

    return (DEFAULT_BK, DEFAULT_BG)


def resolve_tiles(
    kernel: str,
    *,
    nc: int,
    g: int,
    n_bins: int,
    m: int,
    v_max: int = 1,
    delta: str = "SCE",
    selector: Optional[str] = None,
) -> Tuple[int, ...]:
    """The shared tile selector: ``(bk, bg)``, or ``(bc, bk, bg)`` for sweep.

    ``selector=None`` means the default mode (``analytic``).  Resolution is
    pure host Python over concrete ints — the ``ops.py`` wrappers call it
    *outside* (or at trace time of) their jitted bodies, so the chosen tiles
    are ordinary static arguments and no selector state is baked into a
    compiled executable.
    """
    mode = DEFAULT_SELECTOR if selector is None else selector
    if mode not in SELECTOR_MODES:
        raise ValueError(
            f"unknown tile selector: {mode!r} "
            f"(one of: {', '.join(SELECTOR_MODES)})")
    if mode == "pinned":
        tiles = _pinned_tiles(kernel)
        obs.event("autotune.resolve", kernel=kernel, mode=mode,
                  tiles=repr(tiles))
        return tiles
    if mode == "heuristic":
        bk, bg = select_block_sizes(n_bins, g, m)
        if kernel == "sweep":
            from .sweep import DEFAULT_BC

            tiles = (DEFAULT_BC, bk, bg)
        else:
            tiles = (bk, bg)
        obs.event("autotune.resolve", kernel=kernel, mode=mode,
                  tiles=repr(tiles))
        return tiles
    # analytic: a persisted tuning for this (platform, kernel, shape bucket)
    # wins over the model — measured beats modeled when available.
    tuned = _disk_get(_disk_key(jax.default_backend(), kernel,
                                shape_bucket(nc, g, n_bins, m)))
    if tuned is not None:
        obs.counter("plar_autotune_disk_hits_total",
                    "tile resolutions served from the persisted tuning"
                    ).inc()
        obs.event("autotune.resolve", kernel=kernel, mode="analytic",
                  source="disk", tiles=repr(tuned))
        return tuned
    obs.counter("plar_autotune_disk_misses_total",
                "tile resolutions that fell through to the analytic model"
                ).inc()
    tiles = select_tiles(kernel, nc, g, n_bins, m, v_max=v_max, delta=delta)
    obs.event("autotune.resolve", kernel=kernel, mode="analytic",
              source="model", tiles=repr(tiles))
    return tiles


# ---------------------------------------------------------------------------
# persistent tuning cache: (platform, kernel, shape-bucket) → tiles
# ---------------------------------------------------------------------------


def shape_bucket(nc: int, g: int, n_bins: int, m: int) -> Tuple[int, int, int, int]:
    """Pow2 shape bucket: one tuning covers a ×2 band per axis, so the greedy
    loop's drifting (K, G) regimes hit a handful of entries, not thousands."""

    def p2(v: int) -> int:
        b = 1
        while b < max(v, 1):
            b *= 2
        return b

    return (p2(nc), p2(g), p2(n_bins), p2(m))


def _disk_path() -> Path:
    env = os.environ.get(_DISK_ENV)
    if env:
        return Path(env)
    base = Path(os.environ.get("XDG_CACHE_HOME",
                               Path.home() / ".cache")) / "repro-plar"
    return base / "autotune.json"


def _disk_key(platform: str, kernel: str, bucket: Tuple[int, ...]) -> str:
    return f"{platform}|{kernel}|" + "x".join(str(b) for b in bucket)


def _disk_data() -> Dict[str, list]:
    """Lazily loaded disk cache, reloaded when the path changes (tests point
    ``REPRO_AUTOTUNE_CACHE`` at tmp files)."""
    path = _disk_path()
    if _disk_state["path"] != path or _disk_state["data"] is None:
        data: Dict[str, list] = {}
        try:
            with open(path) as f:
                raw = json.load(f)
            if isinstance(raw, dict):
                data = {str(k): list(v) for k, v in raw.items()
                        if isinstance(v, (list, tuple))}
        except (OSError, ValueError):
            data = {}
        _disk_state["path"] = path
        _disk_state["data"] = data
    return _disk_state["data"]  # type: ignore[return-value]


def _disk_get(key: str) -> Optional[Tuple[int, ...]]:
    val = _disk_data().get(key)
    return tuple(int(v) for v in val) if val else None


def _disk_put(key: str, tiles: Sequence[int]) -> None:
    data = _disk_data()
    data[key] = [int(t) for t in tiles]
    path = _disk_path()
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        with open(tmp, "w") as f:
            json.dump(data, f, indent=0, sort_keys=True)
        os.replace(tmp, path)
    except OSError as e:  # read-only FS etc. — tuning still served from memory
        logger.warning("autotune: could not persist tuning cache to %s: %s",
                       path, e)


# ---------------------------------------------------------------------------
# measured refinement
# ---------------------------------------------------------------------------


def _cache_put(key: Tuple, tiles: Tuple[int, ...]) -> None:
    _CACHE[key] = tiles
    _CACHE.move_to_end(key)
    while len(_CACHE) > _CACHE_MAXSIZE:
        _CACHE.popitem(last=False)


def autotune_cache_clear(disk: bool = False) -> None:
    """Drop all in-memory tunings (and the on-disk cache with ``disk=True``)."""
    _CACHE.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0
    if disk:
        _disk_state["data"] = {}
        try:
            _disk_path().unlink(missing_ok=True)
        except OSError:
            pass


def autotune_cache_info() -> Dict[str, object]:
    """Cache observability: sizes, hit/miss counters, disk location."""
    return {
        "size": len(_CACHE),
        "maxsize": _CACHE_MAXSIZE,
        "hits": _CACHE_STATS["hits"],
        "misses": _CACHE_STATS["misses"],
        "disk_path": str(_disk_path()),
        "disk_entries": len(_disk_data()),
    }


def _build_candidate_fn(kernel, tiles, packed, wd, x_t, r_ids, *, n_bins,
                        delta, v_max, interpret):
    """Zero-arg launcher for one candidate tiling (monkeypatch seam for the
    compile-count tests)."""
    if kernel == "contingency":
        from .kernel import contingency_pallas

        bk, bg = tiles
        return lambda: contingency_pallas(
            packed, wd, n_bins=n_bins, bk=bk, bg=bg, interpret=interpret)
    if kernel == "fused":
        from .fused import fused_theta_pallas

        bk, bg = tiles
        return lambda: fused_theta_pallas(
            packed, wd, n_bins=n_bins, delta=delta, bk=bk, bg=bg,
            interpret=interpret)
    from .sweep import sweep_theta_pallas

    bc, bk, bg = tiles
    return lambda: sweep_theta_pallas(
        x_t, r_ids, wd, v_max=v_max, n_bins=n_bins, delta=delta, bc=bc,
        bk=bk, bg=bg, interpret=interpret)


def _time_candidate(fn, reps: int) -> float:
    """Best-of-reps wall time; every rep blocks on its own output so async
    dispatch cannot fold rep k's device time into rep k+1's measurement."""
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_block_sizes(
    nc: int,
    g: int,
    n_bins: int,
    m: int,
    *,
    delta: Optional[str] = None,
    kernel: Optional[str] = None,
    v_max: int = 1,
    reps: int = 3,
    interpret: bool = True,
    candidates: Optional[Sequence[Sequence[int]]] = None,
    refine: bool = False,
    top_k: int = 3,
    platform: Optional[str] = None,
) -> Tuple[int, ...]:
    """Analytically rank candidate tilings; optionally time the top-k.

    ``delta=None`` tunes the unfused contingency kernel; a measure name tunes
    the fused Θ kernel; ``kernel="sweep"`` (with ``v_max``) tunes the
    multi-candidate sweep kernel — candidates are then ``(bc, bk, bg)``.

    By default (``refine=False``) the pick is the analytic rank's best: zero
    compiles.  ``refine=True`` times the ``top_k`` (default 3) analytically
    best candidates — each rep blocked on its own output — and candidates
    whose compile fails are skipped with a logged warning, never silently.
    Winners are memoized in the bounded in-memory LRU (keyed *including the
    JAX platform* — a CPU tuning must not leak onto TPU) and persisted to the
    on-disk cache so other processes' ``analytic`` selector reuses them.
    """
    if kernel is None:
        kernel = "contingency" if delta is None else "fused"
    if kernel not in ("contingency", "fused", "sweep"):
        raise ValueError(
            f"unknown kernel: {kernel!r} (one of: contingency, fused, sweep)")
    delta_eff = delta or "SCE"
    if platform is None:
        platform = jax.default_backend()
    if candidates is not None:
        candidates = tuple(tuple(int(t) for t in c) for c in candidates)
    key = (platform, kernel, nc, g, n_bins, m, delta, v_max, interpret, reps,
           candidates, refine, top_k)
    if key in _CACHE:
        _CACHE_STATS["hits"] += 1
        obs.counter("plar_autotune_cache_hits_total",
                    "autotune LRU hits").inc()
        _CACHE.move_to_end(key)
        return _CACHE[key]
    _CACHE_STATS["misses"] += 1
    obs.counter("plar_autotune_cache_misses_total",
                "autotune LRU misses (re-ranked/timed)").inc()

    m_pad = _round_up(max(m, 1), LANE)
    ranked = rank_tiles(kernel, nc, g, n_bins, m_pad, v_max=v_max,
                        delta=delta_eff, candidates=candidates)
    best = ranked[0][0]

    if refine and len(ranked) > 1:
        rng = np.random.default_rng(0)
        x_host = rng.integers(0, max(n_bins // max(v_max, 1), 1), (nc, g))
        packed = jnp.asarray(rng.integers(0, n_bins, (nc, g)), jnp.int32)
        x_t = jnp.asarray(x_host, jnp.int32)
        r_ids = jnp.zeros((g,), jnp.int32)
        wd = jnp.zeros((g, m_pad), jnp.float32).at[
            jnp.arange(g), jnp.asarray(rng.integers(0, max(m, 1), (g,)))
        ].set(1.0)

        best_dt = float("inf")
        timed_best = None
        for tiles, _cost, _t in ranked[:top_k]:
            fn = _build_candidate_fn(
                kernel, tiles, packed, wd, x_t, r_ids, n_bins=n_bins,
                delta=delta_eff, v_max=v_max, interpret=interpret)
            try:
                jax.block_until_ready(fn())            # compile + warm
            except Exception as e:
                logger.warning(
                    "autotune: %s candidate %s failed to compile on %s "
                    "(skipped): %s", kernel, tiles, platform, e)
                continue
            dt = _time_candidate(fn, reps)
            if dt < best_dt:
                timed_best, best_dt = tiles, dt
        if timed_best is not None:
            best = timed_best

    _cache_put(key, best)
    # Persist full-grid ranks and every measured refinement; a rank over a
    # caller-restricted candidate list is not a shape tuning — don't let it
    # shadow the model for the whole bucket.
    if candidates is None or refine:
        _disk_put(_disk_key(platform, kernel, shape_bucket(nc, g, n_bins, m)),
                  best)
    return best
