"""(BK, BG) block-size selection for the contingency kernels (DESIGN.md §5.2).

Two layers, mirroring how production kernel libraries pick tilings:

* :func:`select_block_sizes` — a zero-cost shape heuristic: MXU-aligned BK,
  contraction depth BG sized so the per-step VMEM working set (packed tile +
  wd tile + output/accumulator tile, double-buffered streams) stays under the
  budget.  This is the default used by ``ops.contingency``/``ops.fused_theta``
  when the caller passes ``bk=None``/``bg=None``.
* :func:`autotune_block_sizes` — an explicit hook that *times* a small grid of
  candidate tilings for one problem shape and caches the winner per
  (shape, measure, fused) key.  Opt-in: interpret-mode timings (this host) are
  correctness vehicles, so the hook only orders configs meaningfully on real
  TPU backends — which is exactly where it is intended to run.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LANE = 128
SUBLANE = 8
VMEM_BUDGET_BYTES = 4 * 1024 * 1024   # per-step working set cap (¼ of VMEM)

# Candidate grid for the timing-based hook: MXU-aligned bin tiles × a range of
# contraction depths.
CANDIDATE_BK = (128, 256, 512)
CANDIDATE_BG = (256, 512, 1024)

_CACHE: Dict[Tuple, Tuple[int, int]] = {}


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def working_set_bytes(bk: int, bg: int, m: int) -> int:
    """f32/int32 bytes resident per grid step.

    packed tile + double-buffered wd stream + output/accumulator tile + the
    [BK, BG] one-hot intermediate (the largest term for big tiles).
    """
    packed = 4 * bg
    wd = 2 * 4 * bg * m          # double-buffered stream
    acc = 4 * bk * m             # output/accumulator tile
    onehot = 4 * bk * bg         # materialized before the dot
    return packed + wd + acc + onehot


def select_block_sizes(
    n_bins: int,
    g: int,
    m: int,
    *,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> Tuple[int, int]:
    """Shape heuristic: largest aligned (BK, BG) fitting the VMEM budget.

    BK never exceeds the padded bin count (no all-padding bin tiles) and BG
    never exceeds the padded granule count; both stay hardware-aligned
    (sublane/lane multiples) so the one-hot matmul runs at full MXU occupancy.
    """
    bk = min(max(_round_up(n_bins, SUBLANE), SUBLANE), 512)
    # Prefer a full 128-row MXU tile when there are enough bins to fill it.
    if n_bins >= LANE:
        bk = max(bk, LANE)
        bk = min(bk, _round_up(n_bins, LANE))
    bg = min(max(_round_up(g, LANE), LANE), 1024)
    while bg > LANE and working_set_bytes(bk, bg, m) > vmem_budget:
        bg //= 2
    while bk > SUBLANE and working_set_bytes(bk, bg, m) > vmem_budget:
        bk = max(_round_up(bk // 2, SUBLANE), SUBLANE)  # halve, stay aligned
    return bk, bg


def autotune_block_sizes(
    nc: int,
    g: int,
    n_bins: int,
    m: int,
    *,
    delta: Optional[str] = None,
    reps: int = 3,
    interpret: bool = True,
    candidates: Optional[Tuple[Tuple[int, int], ...]] = None,
) -> Tuple[int, int]:
    """Time candidate tilings for one problem shape; cache and return the best.

    ``delta=None`` tunes the unfused contingency kernel; a measure name tunes
    the fused Θ kernel.  Results are memoized per (shape, delta, sweep) key so
    the greedy loop pays the sweep once per (K, G) regime.
    """
    if candidates is not None:
        candidates = tuple(tuple(c) for c in candidates)
    key = (nc, g, n_bins, m, delta, interpret, reps, candidates)
    if key in _CACHE:
        return _CACHE[key]

    from .fused import fused_theta_pallas
    from .kernel import contingency_pallas

    m_pad = _round_up(max(m, 1), LANE)
    rng = np.random.default_rng(0)
    packed = jnp.asarray(rng.integers(0, n_bins, (nc, g)), jnp.int32)
    wd = jnp.zeros((g, m_pad), jnp.float32).at[
        jnp.arange(g), jnp.asarray(rng.integers(0, m, (g,)))
    ].set(1.0)

    if candidates is None:
        # Fall back to the (budget-respecting) shape heuristic if no candidate
        # fits — never time a tiling the VMEM filter just rejected.
        candidates = tuple(
            (bk, bg)
            for bk in CANDIDATE_BK
            for bg in CANDIDATE_BG
            if working_set_bytes(bk, bg, m_pad) <= VMEM_BUDGET_BYTES
        ) or (select_block_sizes(n_bins, g, m_pad),)

    best, best_dt = select_block_sizes(n_bins, g, m_pad), float("inf")
    for bk, bg in candidates:
        if delta is None:
            fn = lambda: contingency_pallas(
                packed, wd, n_bins=n_bins, bk=bk, bg=bg, interpret=interpret)
        else:
            fn = lambda: fused_theta_pallas(
                packed, wd, n_bins=n_bins, delta=delta, bk=bk, bg=bg,
                interpret=interpret)
        try:
            jax.block_until_ready(fn())            # compile + warm
        except Exception:
            continue                               # invalid tiling on this backend
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
        if dt < best_dt:
            best, best_dt = (bk, bg), dt

    _CACHE[key] = best
    return best
