"""Pallas TPU kernel: multi-candidate sweep contingency→Θ (DESIGN.md §5.3).

The fused kernel (``fused.py``) evaluates one candidate per grid row and
takes pre-packed ids, so the greedy sweep stages ``packed [nc, G]`` — nc
redundant arithmetic copies of ``r_ids`` — through HBM every iteration, and
every candidate re-streams the granule-resident operands (``r_ids``, ``wd``)
from scratch.  The sweep kernel removes both redundancies:

* **Read-once granule tiles.**  The grid is ``(nc/BC, K/BK, G/BG)`` with G
  innermost and a *block of BC candidates* per grid row.  Each ``r_ids`` and
  ``wd`` tile is DMA'd into VMEM once per (block, bin-tile) and reused by all
  BC candidates of the block — the per-candidate HBM read traffic for the
  shared operands drops by BC×.
* **In-register packing.**  The kernel takes the pre-transposed candidate
  slab ``x_t [nc, G]`` (hoisted out of the greedy loop by the §3.5 engine)
  and the shared ``r_ids [G]``, and computes ``p = r·V + v`` on the tile in
  VMEM — ``packed [nc, G]`` never exists in HBM.

Per-candidate compute is the same one-hot matmul as §5.1/§5.2 (``[BK, BG] @
[BG, M]`` on the MXU), and the θ' epilogue at ``pid_g == nG−1`` is the fused
kernel's, applied per candidate of the block in ascending bin-tile order —
the tile order the §5.3 bin ladder's bit-parity argument relies on.

Padding contract: padded candidate rows are sliced off by the wrapper;
padded granule slots carry ``wd = 0`` rows (zero contribution to every count
and every θ') plus a sentinel key outside every bin; padded bin tiles hold
all-zero rows with θ' = 0.

VMEM working set per grid step: the fused kernel's tiles + a ``[BC, BK, M]``
accumulator (BC× the fused kernel's scratch) — 512 KB at the BC = 8,
BK = 128, M = 128 defaults, 1 MB at BK = 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.measures import RAW_ROWS as EPILOGUES

from . import model
from .kernel import _cost_estimate

DEFAULT_BC = 8     # candidate block (shared-operand reuse factor)
DEFAULT_BK = 128   # bin-tile (MXU sublane-aligned output rows)
DEFAULT_BG = 256   # granule-tile (contraction depth per step)


def _sweep_kernel(xt_ref, r_ref, wd_ref, out_ref, acc_ref, *, bc: int,
                  bk: int, v_max: int, delta: str):
    """One (candidate-block, bin-tile, granule-tile) grid step."""
    pid_k = pl.program_id(1)
    pid_g = pl.program_id(2)
    n_g = pl.num_programs(2)

    r = r_ref[0, :]                                         # [BG] int32
    wd = wd_ref[...]                                        # [BG, M] f32
    bins = pid_k * bk + jax.lax.broadcasted_iota(
        jnp.int32, (bk, r.shape[0]), 0)

    # The candidate loop is a static unroll: r/wd stay resident in VMEM and
    # are reused by every candidate of the block (the read-once property).
    for c in range(bc):
        p = r * v_max + xt_ref[c, :]                        # in-register pack
        onehot = (p[None, :] == bins).astype(jnp.float32)   # [BK, BG]
        acc = jnp.dot(onehot, wd, preferred_element_type=jnp.float32)

        @pl.when(pid_g == 0)
        def _init(acc=acc, c=c):
            acc_ref[c] = acc

        @pl.when(pid_g != 0)
        def _accum(acc=acc, c=c):
            acc_ref[c] += acc

    @pl.when(pid_g == n_g - 1)
    def _epilogue():
        for c in range(bc):
            partial = EPILOGUES[delta](acc_ref[c]).sum()    # scalar Θ' partial

            @pl.when(pid_k == 0)
            def _first_tile(partial=partial, c=c):
                out_ref[c, 0] = partial

            @pl.when(pid_k != 0)
            def _later_tiles(partial=partial, c=c):
                out_ref[c, 0] += partial


@functools.partial(
    jax.jit,
    static_argnames=("v_max", "n_bins", "delta", "bc", "bk", "bg",
                     "interpret"),
)
def sweep_theta_pallas(
    x_t: jnp.ndarray,      # [nc, G] int32 — pre-transposed candidate slab
    r_ids: jnp.ndarray,    # [G]     int32 — shared class ids of U/R
    wd: jnp.ndarray,       # [G, M] float32 — w ⊙ one-hot(d), M lane-padded
    *,
    v_max: int,
    n_bins: int,
    delta: str,
    bc: int = DEFAULT_BC,
    bk: int = DEFAULT_BK,
    bg: int = DEFAULT_BG,
    interpret: bool = True,
) -> jnp.ndarray:
    """Unnormalized Θ' partials [nc]; see module docstring for the schedule.

    The caller applies the measure's sign/|U| normalization
    (``ops.sweep_theta``).
    """
    if delta not in EPILOGUES:
        raise ValueError(f"unknown measure: {delta}")
    nc, g = x_t.shape
    m = wd.shape[1]

    c_pad = -(-nc // bc) * bc
    g_pad = -(-g // bg) * bg
    k_pad = -(-n_bins // bk) * bk
    if c_pad != nc:
        x_t = jnp.pad(x_t, ((0, c_pad - nc), (0, 0)))
    if g_pad != g:
        # Sentinel pack on padding granules: r = -1 puts p = -V + v below
        # every bin for any v ∈ [0, V); their wd rows are zero anyway.
        x_t = jnp.pad(x_t, ((0, 0), (0, g_pad - g)))
        r_ids = jnp.pad(r_ids, ((0, g_pad - g),), constant_values=-1)
        wd = jnp.pad(wd, ((0, g_pad - g), (0, 0)))

    grid = (c_pad // bc, k_pad // bk, g_pad // bg)

    out = pl.pallas_call(
        functools.partial(_sweep_kernel, bc=bc, bk=bk, v_max=v_max,
                          delta=delta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bg), lambda b, k, g_: (b, g_)),
            pl.BlockSpec((1, bg), lambda b, k, g_: (0, g_)),
            pl.BlockSpec((bg, m), lambda b, k, g_: (g_, 0)),
        ],
        out_specs=pl.BlockSpec((bc, 1), lambda b, k, g_: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((c_pad, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bc, bk, m), jnp.float32)],
        cost_estimate=_cost_estimate(
            model.sweep_cost(nc, g, n_bins, m, bc, bk, bg, v_max=v_max,
                             delta=delta)),
        interpret=interpret,
    )(x_t, r_ids.reshape(1, -1), wd)
    return out[:nc, 0]
