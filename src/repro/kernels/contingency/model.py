"""Analytic roofline cost model for the contingency kernels (DESIGN.md §5.2).

The timing autotuner is meaningless on this host (interpret-mode Pallas) and
expensive on real hardware (a compile per candidate tiling).  This module is
the alternative production kernel libraries converge on: a *closed-form* cost
model per kernel — FLOPs, HBM bytes moved, and the per-grid-step VMEM working
set as a function of the problem shape ``(nc, G, K, V, m)`` and the tiling
``(BC, BK, BG)`` — ranked on the roofline of :mod:`repro.launch.roofline`.
The analytic rank is the default tile selector (``ops.py``) and prunes the
timing autotuner's candidate grid to a top-k (``autotune.py``), so timing
becomes an opt-in refinement instead of the default 9-compile sweep.

Model shapes (validated against ``compiled.cost_analysis()`` by
tests/test_kernel_cost_model.py; grid = one step keeps the count exact —
XLA's analysis counts a ``while`` body once, the roofline.py caveat):

* **contingency** (``kernel.py``, grid ``(nc, K̂/BK, Ĝ/BG)``): per step one
  ``[BK, BG]`` compare + a ``[BK, BG] @ [BG, m]`` MXU dot → total
  ``nc·K̂·Ĝ·(1+2m)`` FLOPs.  HBM: ``packed``/``wd`` are re-streamed once per
  bin tile (the G walk restarts for every k), the ``[nc, K̂, m]`` counts
  tensor is written once.
* **fused** (``fused.py``): same accumulation + the θ' epilogue
  (≈ 8 FLOPs/cell on the finished ``[BK, m]`` tile) and an O(nc) output —
  the ``[nc, K̂, m]`` HBM write disappears.
* **sweep** (``sweep.py``, grid ``(Ĉ/BC, K̂/BK, Ĝ/BG)``): per candidate the
  fused kernel's work plus the in-register pack (2 FLOPs/granule per bin
  tile); the shared ``r_ids``/``wd`` granule tiles are loaded **once per
  candidate block** — their stream traffic carries a 1/BC factor, the reuse
  ``autotune.working_set_bytes`` alone cannot express.

A modeled execution time adds a per-grid-step dispatch overhead to the
roofline bound, so tiny-granule tables rank as dispatch-bound (many grid
steps, little traffic) — the regime the ``autotune`` benchmark preset
measures end-to-end.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence, Tuple

from repro.launch.roofline import roofline_terms

__all__ = [
    "KernelCost",
    "contingency_cost",
    "fused_cost",
    "sweep_cost",
    "kernel_cost",
    "working_set_bytes",
    "sweep_working_set_bytes",
    "modeled_time_s",
    "feasible_tiles",
    "rank_tiles",
    "select_tiles",
    "prune_ladder_rungs",
    "rung_eval_cost_bytes",
    "LANE",
    "SUBLANE",
    "VMEM_BUDGET_BYTES",
    "GRID_STEP_OVERHEAD_S",
    "LADDER_MIN_SAVING",
]

LANE = 128
SUBLANE = 8
VMEM_BUDGET_BYTES = 4 * 1024 * 1024   # per-step working set cap (¼ of VMEM)

# Fixed cost of one grid step beyond its data movement (DMA issue, loop
# bookkeeping).  Small enough to be invisible on streaming shapes, large
# enough that tiny-granule tables (many steps, tiny tiles) rank as
# dispatch-bound and the selector prefers fewer/larger tiles on ties.
GRID_STEP_OVERHEAD_S = 1e-7

# Candidate tile axes the analytic selector searches (supersets of the old
# timing grid: the model is free, so smaller-than-MXU tiles for small tables
# cost nothing to consider).
CANDIDATE_BK = (8, 16, 32, 64, 128, 256, 512)
CANDIDATE_BG = (128, 256, 512, 1024)
CANDIDATE_BC = (1, 2, 4, 8, 16)

_EPILOGUE_FLOPS_PER_CELL = 8   # θ' row math: where/log/mul/add per count cell


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """Closed-form cost of one kernel launch.

    ``hbm_bytes`` is the modeled HBM stream traffic; ``vmem_bytes`` the
    per-grid-step resident working set (the feasibility constraint);
    ``grid_steps`` the grid size (the dispatch-overhead multiplier).
    ``transcendentals`` counts the log evaluations of the θ' epilogue
    (0 for the unfused kernel and for Θ_PR).
    """

    flops: float
    hbm_bytes: float
    vmem_bytes: int
    grid_steps: int
    transcendentals: float = 0.0


def working_set_bytes(bk: int, bg: int, m: int) -> int:
    """f32/int32 bytes resident per grid step (contingency/fused kernels).

    packed tile + double-buffered wd stream + output/accumulator tile + the
    [BK, BG] one-hot intermediate (the largest term for big tiles).
    """
    packed = 4 * bg
    wd = 2 * 4 * bg * m          # double-buffered stream
    acc = 4 * bk * m             # output/accumulator tile
    onehot = 4 * bk * bg         # materialized before the dot
    return packed + wd + acc + onehot


def sweep_working_set_bytes(bc: int, bk: int, bg: int, m: int) -> int:
    """Per-step VMEM bytes of the sweep kernel: the fused kernel's tiles with
    a BC-row candidate slab and a ``[BC, BK, m]`` accumulator."""
    xt = 4 * bc * bg
    r = 4 * bg
    wd = 2 * 4 * bg * m
    acc = 4 * bc * bk * m
    onehot = 4 * bk * bg
    return xt + r + wd + acc + onehot


def contingency_cost(nc: int, g: int, n_bins: int, m: int,
                     bk: int, bg: int) -> KernelCost:
    """Cost of one unfused contingency launch (``kernel.py``)."""
    k_hat = _round_up(n_bins, bk)
    g_hat = _round_up(g, bg)
    k_tiles = k_hat // bk
    steps = nc * k_tiles * (g_hat // bg)
    flops = float(nc) * k_hat * g_hat * (1 + 2 * m)
    # packed and wd are re-streamed once per (candidate, bin-tile) pair —
    # the G-axis walk restarts for every k — and the counts tensor lands once.
    hbm = (4.0 * g_hat * nc * k_tiles            # packed
           + 4.0 * g_hat * m * nc * k_tiles      # wd
           + 4.0 * nc * k_hat * m)               # counts out
    return KernelCost(flops, hbm, working_set_bytes(bk, bg, m), steps)


def fused_cost(nc: int, g: int, n_bins: int, m: int, bk: int, bg: int,
               delta: str = "SCE") -> KernelCost:
    """Cost of one fused contingency→Θ launch (``fused.py``)."""
    k_hat = _round_up(n_bins, bk)
    g_hat = _round_up(g, bg)
    k_tiles = k_hat // bk
    steps = nc * k_tiles * (g_hat // bg)
    flops = (float(nc) * k_hat * g_hat * (1 + 2 * m)
             + float(_EPILOGUE_FLOPS_PER_CELL) * nc * k_hat * m)
    hbm = (4.0 * g_hat * nc * k_tiles            # packed
           + 4.0 * g_hat * m * nc * k_tiles      # wd
           + 4.0 * nc)                           # θ' scalars out
    trans = 0.0 if delta == "PR" else float(nc) * k_hat * m
    return KernelCost(flops, hbm, working_set_bytes(bk, bg, m), steps, trans)


def sweep_cost(nc: int, g: int, n_bins: int, m: int,
               bc: int, bk: int, bg: int, v_max: int = 1,
               delta: str = "SCE") -> KernelCost:
    """Cost of one multi-candidate sweep launch (``sweep.py``).

    The load-bearing term: the shared ``r_ids``/``wd`` tiles are DMA'd once
    per candidate *block*, so their stream traffic is ``Ĉ/BC`` × per-bin-tile
    — the BC× reuse ``working_set_bytes`` (a pure capacity model) ignores.
    """
    del v_max  # shape-independent: the pack is 2 flops/granule regardless
    c_hat = _round_up(nc, bc)
    k_hat = _round_up(n_bins, bk)
    g_hat = _round_up(g, bg)
    c_blocks = c_hat // bc
    k_tiles = k_hat // bk
    steps = c_blocks * k_tiles * (g_hat // bg)
    flops = (float(c_hat) * k_hat * g_hat * (1 + 2 * m)
             + 2.0 * c_hat * g_hat * k_tiles                 # in-register pack
             + float(_EPILOGUE_FLOPS_PER_CELL) * c_hat * k_hat * m)
    hbm = (4.0 * g_hat * c_hat * k_tiles                     # x_t slab rows
           + 4.0 * g_hat * (1 + m) * c_blocks * k_tiles      # shared r_ids+wd, ÷BC
           + 4.0 * c_hat)                                    # θ' scalars out
    trans = 0.0 if delta == "PR" else float(c_hat) * k_hat * m
    return KernelCost(flops, hbm, sweep_working_set_bytes(bc, bk, bg, m),
                      steps, trans)


def kernel_cost(kernel: str, nc: int, g: int, n_bins: int, m: int,
                tiles: Sequence[int], *, v_max: int = 1,
                delta: str = "SCE") -> KernelCost:
    """Dispatch by kernel name; ``tiles`` is (bk, bg) or (bc, bk, bg)."""
    if kernel == "contingency":
        return contingency_cost(nc, g, n_bins, m, *tiles)
    if kernel == "fused":
        return fused_cost(nc, g, n_bins, m, *tiles, delta=delta)
    if kernel == "sweep":
        return sweep_cost(nc, g, n_bins, m, *tiles, v_max=v_max, delta=delta)
    raise ValueError(
        f"unknown kernel: {kernel!r} (one of: contingency, fused, sweep)")


def modeled_time_s(cost: KernelCost) -> float:
    """Roofline execution-time estimate: max(compute, memory) + dispatch.

    Reuses :func:`repro.launch.roofline.roofline_terms` (the project's one
    home for hardware constants); the added per-grid-step overhead makes the
    dispatch-bound regime — many tiny steps — visible to the ranking.
    """
    terms = roofline_terms(cost.flops, cost.hbm_bytes, 0.0)
    return terms["bound_s"] + cost.grid_steps * GRID_STEP_OVERHEAD_S


def feasible_tiles(kernel: str, nc: int, g: int, n_bins: int, m: int,
                   *, vmem_budget: int = VMEM_BUDGET_BYTES
                   ) -> Tuple[Tuple[int, ...], ...]:
    """Aligned candidate tilings under the VMEM budget.

    BK stays sublane-aligned and never exceeds the padded bin count by more
    than one tile (no all-padding bin tiles); BG is lane-aligned and capped
    one tile above the granule count; sweep BC is capped one block above nc.
    """
    k_cap = _round_up(max(n_bins, 1), SUBLANE)
    g_cap = _round_up(max(g, 1), LANE)
    bks = [bk for bk in CANDIDATE_BK if bk // 2 < k_cap] or [SUBLANE]
    bgs = [bg for bg in CANDIDATE_BG if bg // 2 < g_cap] or [LANE]
    out = []
    if kernel == "sweep":
        bcs = [bc for bc in CANDIDATE_BC if bc // 2 < max(nc, 1)] or [1]
        for bc in bcs:
            for bk in bks:
                for bg in bgs:
                    if sweep_working_set_bytes(bc, bk, bg, m) <= vmem_budget:
                        out.append((bc, bk, bg))
    else:
        for bk in bks:
            for bg in bgs:
                if working_set_bytes(bk, bg, m) <= vmem_budget:
                    out.append((bk, bg))
    # Never empty: the smallest aligned tile is the floor.
    if not out:
        out = [(1, SUBLANE, LANE)] if kernel == "sweep" else [(SUBLANE, LANE)]
    return tuple(out)


def rank_tiles(kernel: str, nc: int, g: int, n_bins: int, m: int, *,
               v_max: int = 1, delta: str = "SCE",
               candidates: Optional[Iterable[Sequence[int]]] = None
               ) -> Tuple[Tuple[Tuple[int, ...], KernelCost, float], ...]:
    """Candidates sorted by modeled time (deterministic tie-break).

    Returns ``((tiles, cost, time_s), ...)`` ascending; ties prefer the
    larger tile area (fewer grid steps on hardware), then the lexicographic
    tiling — so the rank is a pure function of the shape.
    """
    if candidates is None:
        cands = feasible_tiles(kernel, nc, g, n_bins, m)
    else:
        cands = tuple(tuple(int(t) for t in c) for c in candidates)
    scored = []
    for tiles in cands:
        cost = kernel_cost(kernel, nc, g, n_bins, m, tiles,
                           v_max=v_max, delta=delta)
        scored.append((tiles, cost, modeled_time_s(cost)))
    area = lambda t: t[0][-1] * t[0][-2]   # bk·bg (bc excluded: pure reuse)
    scored.sort(key=lambda s: (s[2], -area(s), s[0]))
    return tuple(scored)


def select_tiles(kernel: str, nc: int, g: int, n_bins: int, m: int, *,
                 v_max: int = 1, delta: str = "SCE") -> Tuple[int, ...]:
    """The analytic selector: best modeled tiling for the shape."""
    return rank_tiles(kernel, nc, g, n_bins, m, v_max=v_max, delta=delta)[0][0]


# ---------------------------------------------------------------------------
# ladder-rung pruning (plan.ladder_rungs selector="analytic", DESIGN.md §5.3)
# ---------------------------------------------------------------------------

# Keep a smaller rung only if it saves at least this fraction of the modeled
# per-iteration eval traffic vs the next kept rung above it.
LADDER_MIN_SAVING = 0.15


def rung_eval_cost_bytes(rung: int, g: int, m: int) -> float:
    """Modeled per-candidate eval traffic at bin bound ``rung``.

    Granule-proportional fixed term (read the candidate slab row + shared
    r_ids + wd stream — paid at every rung) plus the bin-proportional term
    (zero-init and θ'-read of the ``[rung, m]`` counts) — the
    padding-vs-traffic tradeoff the ladder exists to manage.  Deliberately a
    function of (G, m) only: nc scales both terms linearly and cancels, so
    host loop, device engine and mesh driver — whose mp_chunks differ —
    derive the *same* rung set from the same granularity (the cross-driver
    parity contract of §5.3).
    """
    fixed = 4.0 * g * (2 + m)
    per_bin = 2.0 * 4.0 * rung * m
    return fixed + per_bin


def prune_ladder_rungs(rungs: Sequence[int], g: int, m: int, *,
                       min_saving: float = LADDER_MIN_SAVING
                       ) -> Tuple[int, ...]:
    """Drop rungs whose modeled eval saving is below ``min_saving``.

    Walks down from the (always kept) exact top rung and keeps a rung only
    if it cuts the modeled eval cost of the last kept rung by at least
    ``min_saving``.  The result is a subset of the input closed over the top
    rung, so every §5.3 structural invariant — pow2-multiple-of-tile rungs,
    exact top, prefix property, shard-count divisibility — is inherited, and
    the rung-invariance lemma makes results byte-identical to the unpruned
    ladder.  Dispatch-bound tables (G·m ≫ K·V·m) collapse to few rungs —
    fewer ``lax.switch`` branches to trace; bin-dominated tables keep the
    full pow2 ladder (each halving saves ~50%).
    """
    rungs = tuple(rungs)
    if len(rungs) <= 1:
        return rungs
    kept = [rungs[-1]]
    for r in reversed(rungs[:-1]):
        if (rung_eval_cost_bytes(r, g, m)
                <= (1.0 - min_saving) * rung_eval_cost_bytes(kept[-1], g, m)):
            kept.append(r)
    return tuple(sorted(kept))
