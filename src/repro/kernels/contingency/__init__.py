from .autotune import autotune_block_sizes, select_block_sizes
from .ops import contingency, fused_theta, sweep_theta, theta_scale
from .ref import contingency_ref, fused_theta_ref, sweep_theta_ref

__all__ = [
    "contingency",
    "contingency_ref",
    "fused_theta",
    "fused_theta_ref",
    "sweep_theta",
    "sweep_theta_ref",
    "theta_scale",
    "select_block_sizes",
    "autotune_block_sizes",
]
