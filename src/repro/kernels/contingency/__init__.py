from .autotune import (
    SELECTOR_MODES,
    autotune_block_sizes,
    autotune_cache_clear,
    autotune_cache_info,
    resolve_tiles,
    select_block_sizes,
)
from .model import KernelCost, kernel_cost, modeled_time_s, rank_tiles
from .ops import contingency, fused_theta, sweep_theta, theta_scale
from .ref import contingency_ref, fused_theta_ref, sweep_theta_ref

__all__ = [
    "contingency",
    "contingency_ref",
    "fused_theta",
    "fused_theta_ref",
    "sweep_theta",
    "sweep_theta_ref",
    "theta_scale",
    "select_block_sizes",
    "autotune_block_sizes",
    "autotune_cache_clear",
    "autotune_cache_info",
    "resolve_tiles",
    "SELECTOR_MODES",
    "KernelCost",
    "kernel_cost",
    "modeled_time_s",
    "rank_tiles",
]
