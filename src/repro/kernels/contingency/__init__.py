from .ops import contingency
from .ref import contingency_ref

__all__ = ["contingency", "contingency_ref"]
