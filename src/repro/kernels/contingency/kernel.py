"""Pallas TPU kernel: batched one-hot contingency reduction (MXU strategy).

The PLAR hot-spot is the paper's ``reduceByKey``: grouping granule weights by
(class-id, decision) for *every candidate attribute at once*.  After the
incremental id-packing of :mod:`repro.core.plan`, every key is a compact
integer ``p ∈ [0, K·V)``, so the grouped count is the contraction

    counts[c, k, j] = Σ_g w_g · 1[packed[c,g] = k] · 1[d_g = j]
                    = Σ_g OneHot(packed)[g, k] · WD[g, j]

i.e. an ``[BK, BG] @ [BG, M]`` matmul per tile — exactly what the MXU runs at
peak.  The GPU analogue would be atomic scatter-adds; TPU has no fast atomics,
so the one-hot-matmul formulation *is* the hardware adaptation (DESIGN.md §2).

Tiling (VMEM working set, per grid step):

    packed tile  [1, BG]           int32     (4·BG bytes)
    wd tile      [BG, M]           float32   (4·BG·M)
    out tile     [1, BK, M]        float32   (4·BK·M, resident across the
                                              G-axis grid walk)

Grid = (nc, n_bins/BK, G/BG); the G axis is innermost so each output tile is
initialized once (``pid_g == 0``) and accumulated in VMEM — no HBM round-trip
between partial sums.  ``M`` is the decision-class count padded to the 128
lane width by ``ops.py``; BK/BG default to 128/512 keeping the working set
< 0.5 MB, far under the ~16 MB/core VMEM budget, leaving room for
double-buffering of the streamed ``wd`` tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import model

DEFAULT_BK = 128   # bin-tile (MXU sublane-aligned output rows)
DEFAULT_BG = 512   # granule-tile (contraction depth per step)


def _cost_estimate(cost: model.KernelCost) -> pl.CostEstimate:
    """Analytic cost (DESIGN.md §5.2) handed to the compiler's scheduler —
    the same closed form the tile selector ranks on."""
    return pl.CostEstimate(
        flops=int(cost.flops),
        transcendentals=int(cost.transcendentals),
        bytes_accessed=int(cost.hbm_bytes),
    )


def _contingency_kernel(packed_ref, wd_ref, out_ref, *, bk: int):
    """One (candidate, bin-tile, granule-tile) grid step."""
    pid_k = pl.program_id(1)
    pid_g = pl.program_id(2)

    p = packed_ref[0, :]                                   # [BG] int32
    bins = pid_k * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, p.shape[0]), 0)
    onehot = (p[None, :] == bins).astype(jnp.float32)       # [BK, BG]
    acc = jnp.dot(onehot, wd_ref[...], preferred_element_type=jnp.float32)  # [BK, M]

    @pl.when(pid_g == 0)
    def _init():
        out_ref[0, :, :] = acc

    @pl.when(pid_g != 0)
    def _accum():
        out_ref[0, :, :] += acc


@functools.partial(
    jax.jit,
    static_argnames=("n_bins", "bk", "bg", "interpret"),
)
def contingency_pallas(
    packed: jnp.ndarray,   # [nc, G] int32
    wd: jnp.ndarray,       # [G, M] float32 — w ⊙ one-hot(d), M lane-padded
    *,
    n_bins: int,
    bk: int = DEFAULT_BK,
    bg: int = DEFAULT_BG,
    interpret: bool = True,
) -> jnp.ndarray:
    """counts[c, k, m] for compact integer keys; see module docstring."""
    nc, g = packed.shape
    m = wd.shape[1]

    # Pad shapes up to tile multiples (padding granules carry w = 0 and a
    # sentinel key outside [0, n_bins), contributing 0 to every bin).
    g_pad = -(-g // bg) * bg
    k_pad = -(-n_bins // bk) * bk
    if g_pad != g:
        packed = jnp.pad(packed, ((0, 0), (0, g_pad - g)), constant_values=-1)
        wd = jnp.pad(wd, ((0, g_pad - g), (0, 0)))

    grid = (nc, k_pad // bk, g_pad // bg)

    out = pl.pallas_call(
        functools.partial(_contingency_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bg), lambda c, k, g_: (c, g_)),
            pl.BlockSpec((bg, m), lambda c, k, g_: (g_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bk, m), lambda c, k, g_: (c, k, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, k_pad, m), jnp.float32),
        cost_estimate=_cost_estimate(
            model.contingency_cost(nc, g, n_bins, m, bk, bg)),
        interpret=interpret,
    )(packed, wd)
    return out[:, :n_bins, :]
