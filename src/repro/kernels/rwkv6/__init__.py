from .kernel import rwkv6_scan
from .ops import rwkv6, rwkv6_diff
from .ref import rwkv6_ref

__all__ = ["rwkv6_scan", "rwkv6", "rwkv6_diff", "rwkv6_ref"]
