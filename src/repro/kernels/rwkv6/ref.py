"""Pure-jnp oracle for the RWKV6 (Finch) token-shift recurrence.

Per head, with state ``S ∈ R^{Dk × Dv}`` and data-dependent per-channel decay
``w_t ∈ (0, 1)^{Dk}`` and bonus ``u ∈ R^{Dk}``:

    o_t = r_tᵀ (S_{t-1} + (u ⊙ k_t) v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

This is the arXiv:2404.05892 recurrence (eq. 19–22) in its per-head matrix
form.  The oracle runs a plain ``lax.scan`` in f64-free f32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rwkv6_ref(
    r: jnp.ndarray,  # [B, H, T, D]
    k: jnp.ndarray,  # [B, H, T, D]
    v: jnp.ndarray,  # [B, H, T, D]
    w: jnp.ndarray,  # [B, H, T, D] decay in (0, 1)
    u: jnp.ndarray,  # [H, D] bonus
    init_state: Optional[jnp.ndarray] = None,  # [B, H, D, D]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, h, t, d = r.shape
    if init_state is None:
        init_state = jnp.zeros((b, h, d, d), jnp.float32)

    def head(r_h, k_h, v_h, w_h, u_h, s0):
        def step(s, inp):
            r_t, k_t, v_t, w_t = inp
            out = r_t @ (s + jnp.outer(u_h * k_t, v_t))
            s_new = w_t[:, None] * s + jnp.outer(k_t, v_t)
            return s_new, out

        s_fin, o = jax.lax.scan(step, s0, (r_h, k_h, v_h, w_h))
        return o, s_fin

    f = jax.vmap(jax.vmap(head, in_axes=(0, 0, 0, 0, 0, 0)), in_axes=(0, 0, 0, 0, None, 0))
    o, s_fin = f(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w.astype(jnp.float32), u.astype(jnp.float32), init_state,
    )
    return o.astype(r.dtype), s_fin
