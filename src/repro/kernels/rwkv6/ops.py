"""Jit'd public wrappers for the RWKV6 recurrence kernel."""
from __future__ import annotations

import functools
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import rwkv6_scan
from .ref import rwkv6_ref


@partial(jax.jit, static_argnames=("impl", "chunk", "interpret"))
def rwkv6(
    r, k, v, w, u,
    init_state: Optional[jnp.ndarray] = None,
    *,
    impl: str = "pallas",
    chunk: int = 128,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV6 recurrence over [B, H, T, D]; returns (outputs, final state)."""
    if impl == "pallas":
        return rwkv6_scan(r, k, v, w, u, init_state, chunk=chunk, interpret=interpret)
    if impl == "xla":
        return rwkv6_ref(r, k, v, w, u, init_state)
    raise ValueError(f"unknown rwkv6 impl: {impl}")


@functools.lru_cache(maxsize=None)
def _diff_rwkv6(chunk: int):
    @jax.custom_vjp
    def f(r, k, v, w, u, s0):
        return rwkv6_scan(r, k, v, w, u, s0, chunk=chunk)

    def fwd(r, k, v, w, u, s0):
        return f(r, k, v, w, u, s0), (r, k, v, w, u, s0)

    def bwd(res, g):
        _, vjp = jax.vjp(lambda *a: rwkv6_ref(*a), *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def rwkv6_diff(r, k, v, w, u, s0, *, chunk: int = 128):
    """Differentiable RWKV6: Pallas fwd, reference-VJP bwd."""
    return _diff_rwkv6(chunk)(r, k, v, w, u, s0)
