"""Pallas TPU kernel: RWKV6 linear recurrence, chunk-streamed through VMEM.

The recurrence is O(T) — the whole point of the attention-free architecture —
but a naive per-token HBM loop is memory-bound at one [D,D] state round-trip
per token.  This kernel restores arithmetic intensity by *chunking*:

    grid = (B·H, T / L):   chunk axis innermost ⇒ sequential on TPU,
    state scratch S [D, D] lives in VMEM across the whole chunk walk,
    r/k/v/w chunk tiles [L, D] are streamed (double-buffered) from HBM.

Per chunk the state is updated token-by-token *inside VMEM* (a fori_loop of
rank-1 updates — VPU work), so HBM traffic is exactly one read of r/k/v/w and
one write of o per token: the memory-roofline optimum for this op.  The decay
is applied in linear space per token (no log-space pairwise matrices), which
keeps the kernel *unconditionally* stable for any w ∈ (0,1) — the fully
matmul'd chunk formulation (FLA-style) overflows for small w and is noted in
EXPERIMENTS.md §Perf as the rejected alternative.

Dh for rwkv6-3b is 64 ⇒ the [64, 64] f32 state is one MXU-aligned tile
(16 KiB), and [L=128, 64] streams align the lane dimension.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sfin_ref, s_scr, *, chunk: int):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)   # [L, D]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)   # [D]

    def step(t, carry):
        s, o = carry
        r_t = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)      # [1, D]
        k_t = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        v_t = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        w_t = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = k_t.T @ v_t                                     # [D, D] rank-1
        o_t = r_t @ (s + u[None, :].T * kv)                  # [1, D]
        s = w_t.T * s + kv
        o = jax.lax.dynamic_update_slice_in_dim(o, o_t, t, 0)
        return s, o

    s, o = jax.lax.fori_loop(0, chunk, step, (s_scr[...], jnp.zeros_like(r)))
    s_scr[...] = s
    o_ref[0] = o.astype(o_ref.dtype)

    @pl.when(ic == nc - 1)
    def _final():
        sfin_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(
    r: jnp.ndarray,  # [B, H, T, D]
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,  # decay in (0, 1)
    u: jnp.ndarray,  # [H, D]
    init_state: Optional[jnp.ndarray] = None,  # [B, H, D, D]
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, h, t, d = r.shape
    l = min(chunk, t)
    t_pad = -(-t // l) * l
    bh = b * h

    def flat(x):
        x = x.reshape(bh, t, d)
        if t_pad != t:
            x = jnp.pad(x, ((0, 0), (0, t_pad - t), (0, 0)))
        return x

    r_, k_, v_ = flat(r), flat(k), flat(v)
    w_ = flat(w)
    if t_pad != t:
        # Padding decays must be 1 (identity) so the final state is exact.
        pad_mask = (jnp.arange(t_pad) < t)[None, :, None]
        w_ = jnp.where(pad_mask, w_, 1.0)
    u_ = jnp.broadcast_to(u[None], (b, h, d)).reshape(bh, d)
    s0 = (jnp.zeros((bh, d, d), jnp.float32) if init_state is None
          else init_state.reshape(bh, d, d).astype(jnp.float32))

    grid = (bh, t_pad // l)
    o, s_fin = pl.pallas_call(
        functools.partial(_rwkv6_kernel, chunk=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, l, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, l, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, l, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, d), lambda i, c: (i, 0)),
            pl.BlockSpec((1, d, d), lambda i, c: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, d, d), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t_pad, d), r.dtype),
            jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r_, k_, v_, w_, u_, s0)
    return o[:, :t].reshape(b, h, t, d), s_fin.reshape(b, h, d, d)
