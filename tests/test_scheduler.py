"""Multi-tenant serving tier (DESIGN.md §3.9): batched dispatch, dedup,
admission control, metrics.

The acceptance contract: K concurrent clients with mixed measures on one
dataset are answered from ≤2 stacked dispatches with results byte-identical
to solo ``query()`` calls; C identical concurrent queries collapse to ONE
engine run; submits above the bounded queue depth fail fast with
``ServerOverloaded`` and the server recovers after the backlog drains; and
``stop()`` fails queued-but-unstarted futures instead of hanging them.
"""
import asyncio

import numpy as np
import pytest

from repro.core.reduction import (
    partition_reduce_params,
    plar_reduce,
    plar_reduce_ensemble,
)
from repro.service import (
    DatasetHandle,
    ReductServer,
    ServerOverloaded,
    repair_reduce_many,
)

DELTAS = ["PR", "SCE", "LCE", "CCE"]


def _table(seed, n, a, vmax=3, m=3, redundancy=0.5):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    for j in range(1, a):
        if rng.random() < redundancy:
            x[:, j] = x[:, rng.integers(0, j)]
    d = rng.integers(0, m, size=(n,)).astype(np.int32)
    return x, d


def _same_result(a, b):
    assert a.reduct == b.reduct
    assert np.array_equal(np.asarray(a.theta_history),
                          np.asarray(b.theta_history))
    assert a.theta_full == b.theta_full


# ---------------------------------------------------------------------------
# ensemble driver: per-config warm_start (the batched-repair enabler)
# ---------------------------------------------------------------------------


def test_ensemble_warm_start_matches_solo_warm():
    """A stacked member with ``warm_start`` is byte-identical to the solo
    ``plar_reduce(warm_start=...)`` run it batches — full prefix, partial
    prefix, and a cold member in the same grid."""
    x, d = _table(0, 500, 8)
    solo_cold = plar_reduce(x, d, delta="PR")
    prefix = solo_cold.reduct[:2]
    grid = [
        {"delta": "PR", "warm_start": solo_cold.reduct},
        {"delta": "PR", "warm_start": prefix},
        {"delta": "SCE"},
    ]
    stacked = plar_reduce_ensemble(x, d, configs=grid)
    _same_result(stacked[0], plar_reduce(x, d, delta="PR",
                                         warm_start=solo_cold.reduct))
    _same_result(stacked[1], plar_reduce(x, d, delta="PR",
                                         warm_start=prefix))
    _same_result(stacked[2], plar_reduce(x, d, delta="SCE"))


def test_ensemble_warm_start_validation():
    x, d = _table(1, 200, 5)
    with pytest.raises(ValueError, match="warm_start"):
        plar_reduce_ensemble(
            x, d, configs=[{"delta": "PR", "warm_start": [0, 0]}])
    with pytest.raises(ValueError, match="warm_start"):
        plar_reduce(x, d, delta="PR", warm_start=[99])


def test_partition_reduce_params_split():
    """Per-config knobs route to the stacked grid, shared knobs to the
    dispatch; anything the ensemble cannot express refuses to split."""
    split = partition_reduce_params("PR", {"tol": 1e-4, "backend": "segment"})
    assert split is not None
    config, shared = split
    assert config == {"delta": "PR", "tol": 1e-4}
    assert shared == {"backend": "segment"}
    assert partition_reduce_params("PR", {"engine": "host"}) is None
    assert partition_reduce_params("PR", {"backend": "fused"}) is None
    assert partition_reduce_params("PR", {"mode": "sprak"}) is None


def test_repair_reduce_many_matches_sequential_repair():
    """Stacked warm repair over mixed measures == each measure's solo
    repair, byte for byte, including a member whose prefix is trimmed."""
    x, d = _table(2, 700, 9)
    h = DatasetHandle.create(x[:500], d[:500], n_dec=3, v_max=3)
    h2 = DatasetHandle.create(x[:500], d[:500], n_dec=3, v_max=3)
    prevs = {m: h.reduce(m) for m in DELTAS}
    for m in DELTAS:
        h2.reduce(m)
    for hh in (h, h2):
        hh.update(x[500:], d[500:])
    results, kept = repair_reduce_many(
        h.gran, [{"delta": m} for m in DELTAS],
        [prevs[m].reduct for m in DELTAS], exact=True)
    for m, r, k in zip(DELTAS, results, kept):
        solo = h2.reduce(m)      # solo warm path (repair_reduce)
        _same_result(r, solo)
        assert k <= len(prevs[m].reduct)


# ---------------------------------------------------------------------------
# scheduler: batched dispatch + parity
# ---------------------------------------------------------------------------


def test_concurrent_clients_batched_into_stacked_dispatches():
    """K clients × mixed measures/params on one dataset are served from ≤2
    stacked dispatches, byte-identical to solo query() calls — through a
    streaming update too (stacked warm repair)."""
    x, d = _table(3, 800, 10)

    async def drive():
        specs = [("PR", {}), ("SCE", {}), ("LCE", {}), ("CCE", {}),
                 ("PR", {"tol": 1e-4}), ("SCE", {"max_features": 3})]
        async with ReductServer() as srv, ReductServer(batching=False) as ref:
            for s in (srv, ref):
                await s.submit("s", x[:600], d[:600], n_dec=3, v_max=3)
            rs = await asyncio.gather(
                *[srv.query("s", m, **p) for m, p in specs])
            runs_cold = srv.stats["engine_runs"]
            # cold twins from the single-flight reference server
            for (m, p), r in zip(specs, rs):
                _same_result(r, await ref.query("s", m, **p))
            # firehose round: update lands, then another concurrent window
            for s in (srv, ref):
                await s.update("s", x[600:], d[600:])
            rs2 = await asyncio.gather(
                *[srv.query("s", m, **p) for m, p in specs])
            runs_warm = srv.stats["engine_runs"] - runs_cold
            # warm twins: solo warm repair vs stacked warm repair
            for (m, p), r2 in zip(specs, rs2):
                _same_result(r2, await ref.query("s", m, **p))
            assert runs_cold <= 2
            assert runs_warm <= 2
            occ = srv.metrics.mean_occupancy()
            assert occ > 1.0  # real cross-query batching happened
            assert srv.metrics.counters["engine_dispatches"] == \
                srv.stats["engine_runs"]

    asyncio.run(drive())


def test_unbatchable_params_fall_back_to_solo():
    """Params the stacked engine cannot express (engine='host') still work —
    they take the solo path inside the same window."""
    x, d = _table(4, 400, 6)

    async def drive():
        async with ReductServer() as srv:
            await srv.submit("s", x, d, n_dec=3, v_max=3)
            r_host, r_dev = await asyncio.gather(
                srv.query("s", "PR", engine="host"),
                srv.query("s", "SCE"))
            solo = plar_reduce(x, d, delta="PR", engine="host")
            assert r_host.reduct == solo.reduct
            assert r_dev.reduct  # served, from the same window

    asyncio.run(drive())


def test_inflight_dedup_collapses_identical_queries():
    """C identical concurrent queries → exactly 1 engine run; every caller
    gets the same result object.  Numpy-scalar params dedup with python
    floats (normalized keys)."""
    x, d = _table(5, 500, 8)

    async def drive():
        async with ReductServer() as srv:
            await srv.submit("s", x, d, n_dec=3, v_max=3)
            tols = [1e-4, np.float32(1e-4), np.float64(1e-4), 1e-4, 1e-4]
            rs = await asyncio.gather(
                *[srv.query("s", "PR", tol=t) for t in tols])
            assert srv.stats["engine_runs"] == 1
            assert srv.stats["dedup_hits"] == len(tols) - 1
            assert all(r is rs[0] for r in rs)

    asyncio.run(drive())


def test_result_cache_key_normalization():
    """Sequential repeats with numpy-scalar params hit the result cache
    instead of minting distinct entries."""
    x, d = _table(6, 400, 6)

    async def drive():
        async with ReductServer() as srv:
            await srv.submit("s", x, d, n_dec=3, v_max=3)
            await srv.query("s", "PR", tol=1e-4, max_features=4)
            r2 = await srv.query("s", "PR", tol=np.float32(1e-4),
                                 max_features=np.int64(4))
            assert srv.stats["cache_hits"] == 1
            assert len(srv._cache) == 1
            assert r2.reduct

    asyncio.run(drive())


def test_stale_eviction_uses_per_dataset_index():
    """A merge evicts exactly the updated dataset's superseded entries; the
    other dataset's cache and the index stay consistent."""
    x1, d1 = _table(7, 500, 7)
    x2, d2 = _table(8, 500, 7)

    async def drive():
        async with ReductServer() as srv:
            await srv.submit("a", x1[:400], d1[:400], n_dec=3, v_max=3)
            await srv.submit("b", x2, d2, n_dec=3, v_max=3)
            await asyncio.gather(srv.query("a", "PR"), srv.query("a", "SCE"),
                                 srv.query("b", "PR"))
            assert len(srv._cache) == 3
            await srv.update("a", x1[400:], d1[400:])
            await srv.query("a", "PR")
            keys = set(srv._cache)
            assert {k[0] for k in keys} == {"a", "b"}
            # b untouched; a's stale-fingerprint entries gone
            assert sum(1 for k in keys if k[0] == "b") == 1
            assert sum(1 for k in keys if k[0] == "a") == 1
            # index mirrors the cache exactly
            indexed = {k for by_fp in srv._cache_index.values()
                       for ks in by_fp.values() for k in ks}
            assert indexed == keys

    asyncio.run(drive())


# ---------------------------------------------------------------------------
# admission control + lifecycle
# ---------------------------------------------------------------------------


def test_backpressure_rejects_above_depth_and_recovers():
    x, d = _table(9, 400, 6)

    async def drive():
        async with ReductServer(max_queue=3) as srv:
            await srv.submit("s", x, d, n_dec=3, v_max=3)
            # distinct params so dedup cannot absorb them; created together
            # so all submits land before the scheduler drains the window
            tasks = [asyncio.create_task(
                srv.query("s", "PR", max_features=i + 1)) for i in range(5)]
            done = await asyncio.gather(*tasks, return_exceptions=True)
            rejected = [r for r in done if isinstance(r, ServerOverloaded)]
            served = [r for r in done if not isinstance(r, Exception)]
            assert len(rejected) == 2 and len(served) == 3
            assert srv.stats["rejected"] == 2
            # queue drained: the server admits again
            r = await srv.query("s", "SCE")
            assert r.reduct
            assert srv.metrics.counters["rejected"] == 2

    asyncio.run(drive())


def test_stop_fails_queued_requests():
    """stop() drains the queue and fails pending futures with a clear
    RuntimeError instead of leaving them hanging forever."""
    x, d = _table(10, 300, 5)

    async def drive():
        srv = ReductServer()
        await srv.start()
        await srv.submit("s", x, d, n_dec=3, v_max=3)
        t1 = asyncio.create_task(srv.query("s", "PR"))
        t2 = asyncio.create_task(srv.query("s", "SCE"))
        await asyncio.sleep(0)   # both enqueue; scheduler not yet dispatched
        await srv.stop()
        for t in (t1, t2):
            with pytest.raises(RuntimeError, match="server stopped"):
                await t
        # queries during/after stop are refused, not hung
        with pytest.raises(RuntimeError):
            await srv.query("s", "PR")

    asyncio.run(drive())


def test_metrics_timing_and_summary_shape():
    x, d = _table(11, 300, 5)

    async def drive():
        async with ReductServer() as srv:
            await srv.submit("s", x, d, n_dec=3, v_max=3)
            await asyncio.gather(srv.query("s", "PR"), srv.query("s", "SCE"))
            s = srv.summary()
            for k in ("completed", "engine_dispatches", "qps_sustained",
                      "mean_batch_occupancy", "queue_wait_p50_s",
                      "latency_p99_s", "queries", "engine_runs"):
                assert k in s
            assert s["completed"] == 2
            req = srv.requests[-1]
            assert req.timing.t_done >= req.timing.t_start >= \
                req.timing.t_enqueue > 0.0
            assert req.latency_s == pytest.approx(req.timing.service_s)

    asyncio.run(drive())
