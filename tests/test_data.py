"""Data pipeline invariants: determinism, sharding, duplication, featsel."""
import numpy as np
import pytest

from repro.core import plar_reduce
from repro.data import (
    FeatureSelectedStream, TabularStream, TokenStream,
    paper_dataset, scaled_paper_dataset,
)


def test_token_stream_restart_safe():
    """batch(step) is a pure function — restart/elastic safety (DESIGN §3.4)."""
    s = TokenStream(vocab=1000, seq_len=16, global_batch=8, seed=7)
    a, b = s.batch(123), s.batch(123)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(s.batch(123)["tokens"], s.batch(124)["tokens"])


def test_token_stream_shards_partition_global_batch():
    s = TokenStream(vocab=100, seq_len=8, global_batch=12, seed=1)
    full = s.batch(3)["tokens"]
    parts = [s.shard(3, i, 3)["tokens"] for i in range(3)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_token_stream_labels_shifted():
    s = TokenStream(vocab=50, seq_len=8, global_batch=2, seed=2)
    b = s.batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_tabular_stream_deterministic():
    t = TabularStream(n_rows=100, n_attrs=6, seed=5)
    x1, d1 = t.table()
    x2, d2 = t.table()
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(d1, d2)


def test_distinct_fraction_controls_duplication():
    """KDD99-style redundancy: few distinct rows (the GrC payoff, Fig. 9)."""
    dense = TabularStream(n_rows=5000, n_attrs=8, v_max=10, distinct_fraction=0.02,
                          seed=3)
    x, d = dense.table()
    distinct = len(np.unique(np.concatenate([x, d[:, None]], axis=1), axis=0))
    assert distinct <= 110     # ≈ 2% of 5000 prototypes
    sparse = TabularStream(n_rows=5000, n_attrs=8, v_max=10, distinct_fraction=1.0,
                           seed=3)
    x2, _ = sparse.table()
    distinct2 = len(np.unique(x2, axis=0))
    assert distinct2 > 4000


def test_paper_dataset_shapes_match_table5():
    for name, rows, attrs in [("mushroom", 5644, 22), ("gisette", 6000, 5000),
                              ("sdss", 320_000, 5201), ("kdd99", 5_000_000, 41)]:
        t = paper_dataset(name)
        assert (t.n_rows, t.n_attrs) == (rows, attrs), name


def test_scaled_dataset_caps_dims():
    t = scaled_paper_dataset("sdss", max_rows=1000, max_attrs=32)
    x, d = t.table()
    assert x.shape == (1000, 32)


def test_feature_selected_stream_preserves_discernibility():
    """The end-to-end contract: projecting onto the reduct keeps Θ(D|B)."""
    from repro.core.oracle import theta_oracle

    base = TabularStream(n_rows=300, n_attrs=8, redundancy=0.5, noise=0.0, seed=9)
    x, d = base.table()
    r = plar_reduce(x, d, delta="SCE")
    xr, dr = FeatureSelectedStream(base, r.reduct).table()
    assert xr.shape[1] == len(r.reduct)
    np.testing.assert_allclose(
        theta_oracle("SCE", xr, dr, list(range(xr.shape[1]))),
        theta_oracle("SCE", x, d, list(range(x.shape[1]))),
        rtol=1e-6, atol=1e-8,
    )


def test_grc_capacity_shrink_effective():
    """After GrC init the working shapes track |U/A|, not |U| (§Perf fix)."""
    import jax.numpy as jnp
    from repro.core import build_granularity
    from repro.core.reduction import plar_reduce as pr

    t = TabularStream(n_rows=4000, n_attrs=6, v_max=3, distinct_fraction=0.01,
                      seed=11)
    x, d = t.table()
    g = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3)
    assert int(g.num) < 100
    res = pr(x, d, delta="PR")          # runs through the shrunken capacity
    from repro.core.oracle import reduct_oracle
    assert res.reduct == reduct_oracle("PR", x, d)
