"""Shape/dtype sweeps + property tests: RWKV6 recurrence kernel vs oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim: property tests skip on bare envs

from repro.kernels.rwkv6 import rwkv6_ref, rwkv6_scan


def _inputs(rng, b, h, t, d, dtype=np.float32):
    r = (rng.standard_normal((b, h, t, d)) * 0.5).astype(dtype)
    k = (rng.standard_normal((b, h, t, d)) * 0.5).astype(dtype)
    v = (rng.standard_normal((b, h, t, d)) * 0.5).astype(dtype)
    w = (1.0 / (1.0 + np.exp(-rng.standard_normal((b, h, t, d))))).astype(dtype)
    u = (rng.standard_normal((h, d)) * 0.5).astype(dtype)
    s0 = (rng.standard_normal((b, h, d, d)) * 0.1).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (r, k, v, w, u, s0))


@pytest.mark.parametrize(
    "b,h,t,d,chunk",
    [
        (2, 2, 64, 16, 16),
        (1, 3, 128, 32, 32),
        (2, 1, 33, 8, 16),      # T not a chunk multiple
        (1, 2, 256, 64, 128),   # production head_dim
        (1, 1, 7, 4, 8),        # T < chunk
    ],
)
def test_rwkv6_matches_ref(b, h, t, d, chunk):
    rng = np.random.default_rng(t * 31 + d)
    r, k, v, w, u, s0 = _inputs(rng, b, h, t, d)
    o, sf = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk)
    oref, sref = rwkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 16, 64, 128])
def test_rwkv6_chunk_invariance(chunk):
    rng = np.random.default_rng(9)
    r, k, v, w, u, s0 = _inputs(rng, 1, 2, 128, 16)
    o, sf = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk)
    oref, sref = rwkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sref), rtol=1e-4, atol=1e-4)


def test_rwkv6_state_chaining():
    """Running [0:T/2] then [T/2:T] with the carried state == full run.

    This is the invariant that makes the kernel usable for decode (state in,
    state out) and for sequence-parallel long-context.
    """
    rng = np.random.default_rng(10)
    r, k, v, w, u, s0 = _inputs(rng, 1, 2, 64, 16)
    o_full, s_full = rwkv6_scan(r, k, v, w, u, s0, chunk=16)
    h = 32
    o1, s1 = rwkv6_scan(r[:, :, :h], k[:, :, :h], v[:, :, :h], w[:, :, :h], u, s0, chunk=16)
    o2, s2 = rwkv6_scan(r[:, :, h:], k[:, :, h:], v[:, :, h:], w[:, :, h:], u, s1, chunk=16)
    np.testing.assert_allclose(np.asarray(o_full[:, :, :h]), np.asarray(o1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(o_full[:, :, h:]), np.asarray(o2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_rwkv6_zero_decay_forgets():
    """w == 0 wipes the state: output at t depends only on token t (+bonus)."""
    rng = np.random.default_rng(12)
    r, k, v, w, u, s0 = _inputs(rng, 1, 1, 8, 4)
    w0 = jnp.zeros_like(w)
    o, sf = rwkv6_scan(r, k, v, w0, u, jnp.zeros_like(s0), chunk=8)
    # manual: o_t = r_t @ (k_{t-1} v_{t-1}^T + u⊙k_t v_t^T), S wiped each step
    oref, _ = rwkv6_ref(r, k, v, w0, u, jnp.zeros_like(s0))
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(1, 70),
    d=st.sampled_from([4, 8, 16]),
    chunk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_rwkv6_property(t, d, chunk, seed):
    rng = np.random.default_rng(seed)
    r, k, v, w, u, s0 = _inputs(rng, 1, 2, t, d)
    o, sf = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk)
    oref, sref = rwkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sref), rtol=2e-4, atol=2e-4)
