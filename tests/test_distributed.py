"""Multi-device semantics (subprocess-isolated: device count locks at init).

Each test spawns a fresh python with XLA_FLAGS=--xla_force_host_platform_
device_count=8 and asserts inside the subprocess; the parent only checks the
exit code.  Covered:

* distributed PLAR == serial PLAR == oracle, on ('data','model') and
  ('pod','data','model') meshes, all three collective schedules
  (all_reduce / reduce_scatter / fused — DESIGN.md §3.2, §5.2);
* the device-resident shard_map(while_loop) engine == the legacy host
  driver on a real multi-device mesh (DESIGN.md §3.5);
* int8 compressed psum with error feedback tracks the exact mean;
* GPipe pipeline == sequential stack, forward and gradient;
* elastic checkpoint restore across mesh shapes (4 devices → 8 devices).
"""
import os
import subprocess
import sys

import pytest

ENV = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": "src"}


def _run(script: str):
    r = subprocess.run([sys.executable, "-c", script], env=ENV,
                       capture_output=True, text=True, timeout=540,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"


def test_distributed_plar_matches_oracle():
    _run("""
import numpy as np, jax
from repro.core.distributed import plar_reduce_distributed
from repro.core.oracle import reduct_oracle
from repro.distributed.api import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
x = rng.integers(0, 3, size=(300, 8)).astype(np.int32)
for j in range(1, 8):
    if rng.random() < 0.4:
        x[:, j] = x[:, rng.integers(0, j)]
d = rng.integers(0, 2, size=(300,)).astype(np.int32)
for delta in ["PR", "SCE", "LCE", "CCE"]:
    want = reduct_oracle(delta, x, d)
    for coll in ["all_reduce", "reduce_scatter", "fused"]:
        got = plar_reduce_distributed(x, d, mesh, delta=delta, collective=coll).reduct
        assert got == want, (delta, coll, got, want)
""")


def test_distributed_engine_device_matches_host_loop():
    """shard_map(while_loop) engine == legacy host driver == oracle, on a
    real multi-device mesh, both device-capable collective schedules."""
    _run("""
import numpy as np, jax
from repro.core.distributed import plar_reduce_distributed
from repro.core.oracle import reduct_oracle
from repro.distributed.api import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(5)
x = rng.integers(0, 3, size=(320, 9)).astype(np.int32)
for j in range(1, 9):
    if rng.random() < 0.4:
        x[:, j] = x[:, rng.integers(0, j)]
d = rng.integers(0, 2, size=(320,)).astype(np.int32)
for delta in ["PR", "SCE"]:
    want = reduct_oracle(delta, x, d)
    for coll in ["all_reduce", "reduce_scatter"]:
        dev = plar_reduce_distributed(x, d, mesh, delta=delta, collective=coll,
                                      engine="device")
        host = plar_reduce_distributed(x, d, mesh, delta=delta, collective=coll,
                                       engine="host")
        assert dev.reduct == host.reduct == want, (delta, coll, dev.reduct)
        assert dev.core == host.core
        np.testing.assert_allclose(dev.theta_history, host.theta_history,
                                   rtol=1e-6, atol=1e-7)
""")


def test_distributed_sweep_ladder_matches_baseline():
    """§5.3 on a real multi-device mesh: the bin ladder (collectives inside
    lax.switch rung branches) and the sweep_xla backend reproduce the
    baseline mesh engine on both device-capable collective schedules, for
    both drivers."""
    _run("""
import numpy as np, jax
from repro.core.distributed import plar_reduce_distributed
from repro.distributed.api import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(7)
x = rng.integers(0, 4, size=(2000, 12)).astype(np.int32)
for j in range(1, 12):
    if rng.random() < 0.4:
        x[:, j] = x[:, rng.integers(0, j)]
d = rng.integers(0, 2, size=(2000,)).astype(np.int32)
base = plar_reduce_distributed(x, d, mesh, delta="SCE", engine="device")
base_host = plar_reduce_distributed(x, d, mesh, delta="SCE", engine="host")
for coll in ["all_reduce", "reduce_scatter"]:
    for backend, ladder in [("segment", True), ("sweep_xla", False),
                            ("sweep_xla", True)]:
        for engine in ["device", "host"]:
            r = plar_reduce_distributed(x, d, mesh, delta="SCE",
                                        collective=coll, backend=backend,
                                        ladder=ladder, engine=engine)
            assert r.reduct == base.reduct, (coll, backend, ladder, engine)
            assert r.core == base.core
            # within each driver the advance bound is ladder/backend-
            # independent, so theta histories are byte-identical
            want = base if engine == "device" else base_host
            assert r.theta_history == want.theta_history, (
                coll, backend, ladder, engine)
""")


def test_distributed_streaming_source_matches_array_path():
    """Granularity-first mesh ingestion (DESIGN.md §3.6): per-shard streaming
    build == sharded full-table build == single-process reduct, and a
    prebuilt host Granularity placed on the mesh agrees too."""
    _run("""
import numpy as np, jax.numpy as jnp
from repro.core import build_granularity, plar_reduce
from repro.core.distributed import plar_reduce_distributed
from repro.data import TabularStream
from repro.distributed.api import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
t = TabularStream(n_rows=3000, n_attrs=9, v_max=3, n_dec=2,
                  distinct_fraction=0.2, seed=1)
x, d = t.table()
for delta in ["SCE", "PR"]:
    want = plar_reduce(x, d, delta=delta).reduct
    arr = plar_reduce_distributed(x, d, mesh, delta=delta).reduct
    src = plar_reduce_distributed(mesh=mesh, source=t, chunk_rows=512,
                                  delta=delta).reduct
    g = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3)
    pre = plar_reduce_distributed(mesh=mesh, source=g, delta=delta).reduct
    assert arr == src == pre == want, (delta, arr, src, pre, want)
""")


def test_distributed_plar_multipod_mesh():
    _run("""
import numpy as np, jax
from repro.core.distributed import plar_reduce_distributed
from repro.core.oracle import reduct_oracle

from repro.distributed.api import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(1)
x = rng.integers(0, 3, size=(200, 6)).astype(np.int32)
d = rng.integers(0, 2, size=(200,)).astype(np.int32)
for coll in ["all_reduce", "fused"]:
    got = plar_reduce_distributed(x, d, mesh, delta="SCE", collective=coll).reduct
    assert got == reduct_oracle("SCE", x, d), (coll, got)
""")


def test_compressed_psum_error_feedback():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed import compressed_psum_mean
from repro.distributed.api import make_mesh, shard_map

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
xs = rng.standard_normal((8, 64)).astype(np.float32)
f = jax.jit(shard_map(
    lambda x, e: compressed_psum_mean(x + e, ("data",), n_shards=8),
    mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")),
    check_vma=False))
err = jnp.zeros((8, 64), jnp.float32)
exact = xs.mean(0)
acc_c = np.zeros(64); acc_e = np.zeros(64)
for _ in range(20):
    mean, err = f(jnp.asarray(xs), err)
    acc_c += np.asarray(mean)[0]
    acc_e += exact
rel = np.abs(acc_c - acc_e).max() / np.abs(acc_e).max()
assert rel < 0.01, rel   # error feedback keeps long-run drift ≈ 0
single = np.abs(np.asarray(f(jnp.asarray(xs), jnp.zeros_like(err))[0][0]) - exact).max()
assert single < 0.05     # one int8 round is within quantization error
""")


def test_pipeline_parallel_equivalence_and_grads():
    _run("""
import jax, jax.numpy as jnp
from repro.distributed import pipeline_apply, pipeline_loss
from repro.distributed.api import make_mesh

mesh = make_mesh((4,), ("pipe",))
S, M, mb, D = 4, 8, 2, 16
Ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.3
stage = lambda w, x: jnp.tanh(x @ w)
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))
y_pipe = pipeline_apply(stage, mesh)(Ws, x)
y_seq = x
for s in range(S):
    y_seq = jnp.tanh(y_seq @ Ws[s])
assert float(jnp.max(jnp.abs(y_pipe - y_seq))) < 1e-5

lossfn = pipeline_loss(stage, lambda ys, lab: jnp.mean((ys - lab) ** 2), mesh)
g_pipe = jax.grad(lossfn)(Ws, x, jnp.ones_like(x))
def seq_loss(Ws_):
    y = x
    for s in range(S):
        y = jnp.tanh(y @ Ws_[s])
    return jnp.mean((y - jnp.ones_like(x)) ** 2)
g_seq = jax.grad(seq_loss)(Ws)
assert float(jnp.max(jnp.abs(g_pipe - g_seq))) < 1e-5
""")


def test_elastic_checkpoint_restore_across_meshes():
    _run("""
import tempfile, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import CheckpointManager
from repro.distributed.api import make_mesh

devs = jax.devices()
mesh4 = make_mesh((4,), ("data",), devices=np.array(devs[:4]))
mesh8 = make_mesh((8,), ("data",))
w = jax.device_put(np.arange(64.0).reshape(8, 8), NamedSharding(mesh4, P("data")))
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(1, {"w": w})
    _, restored, _ = mgr.restore(1, shardings={"w": NamedSharding(mesh8, P("data"))})
    assert restored["w"].sharding.mesh.shape["data"] == 8
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
""")


def test_moe_ep_shard_map_matches_single_device():
    """Expert-parallel MoE (4-way model axis) == unsharded reference."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.distributed.api import use_mesh

cfg = get_config("qwen3-moe-235b-a22b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}

ref = model.forward(params, batch)   # no mesh: single-shard semantics

from repro.distributed.api import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
with use_mesh(mesh):
    sharded = jax.jit(model.forward)(params, batch)
err = float(jnp.max(jnp.abs(ref - sharded)))
assert err < 1e-3, err
""")
