"""Shape/dtype sweeps + property tests: contingency Pallas kernel vs oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim: property tests skip on bare envs

from repro.core.plan import candidate_contingency
from repro.kernels.contingency import contingency, contingency_ref


def _case(rng, nc, g, n_bins, m, zero_tail=0):
    packed = rng.integers(0, n_bins, size=(nc, g)).astype(np.int32)
    d = rng.integers(0, m, size=(g,)).astype(np.int32)
    w = rng.integers(1, 5, size=(g,)).astype(np.float32)
    if zero_tail:
        w[-zero_tail:] = 0.0
    return jnp.asarray(packed), jnp.asarray(d), jnp.asarray(w)


@pytest.mark.parametrize(
    "nc,g,n_bins,m",
    [
        (1, 64, 8, 2),
        (3, 700, 37, 5),
        (8, 1024, 128, 2),       # tile-aligned
        (2, 1000, 130, 26),      # bins just over one tile
        (5, 513, 300, 3),        # G just over one tile
        (1, 33, 1, 2),           # single bin
        (4, 2048, 512, 17),
    ],
)
def test_contingency_matches_ref(nc, g, n_bins, m):
    rng = np.random.default_rng(nc * 1000 + g)
    packed, d, w = _case(rng, nc, g, n_bins, m, zero_tail=g // 10)
    out = contingency(packed, d, w, n_bins=n_bins, n_dec=m)
    ref = contingency_ref(packed, d, w, n_bins=n_bins, n_dec=m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("bk,bg", [(8, 64), (128, 128), (64, 512), (256, 1024)])
def test_contingency_block_shape_invariance(bk, bg):
    """Result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(7)
    packed, d, w = _case(rng, 3, 500, 77, 4)
    out = contingency(packed, d, w, n_bins=77, n_dec=4, bk=bk, bg=bg)
    ref = contingency_ref(packed, d, w, n_bins=77, n_dec=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-5)


def test_contingency_total_mass():
    """Σ_k Σ_j counts == Σ w for every candidate (nothing lost in tiling)."""
    rng = np.random.default_rng(11)
    packed, d, w = _case(rng, 6, 900, 41, 7, zero_tail=100)
    out = contingency(packed, d, w, n_bins=41, n_dec=7)
    total = np.asarray(out.sum(axis=(1, 2)))
    np.testing.assert_allclose(total, np.full(6, float(np.asarray(w).sum())), rtol=1e-6)


def test_backends_bit_equivalent_paths():
    """segment / onehot / pallas backends agree (DESIGN.md §3.1 invariant)."""
    rng = np.random.default_rng(13)
    packed, d, w = _case(rng, 4, 600, 50, 3)
    valid = w > 0
    outs = {
        b: np.asarray(
            candidate_contingency(packed, d, w, valid, n_bins=50, m=3, backend=b)
        )
        for b in ("segment", "onehot", "pallas")
    }
    np.testing.assert_allclose(outs["segment"], outs["onehot"], rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(outs["segment"], outs["pallas"], rtol=1e-6, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    nc=st.integers(1, 4),
    g=st.integers(1, 300),
    n_bins=st.integers(1, 64),
    m=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_contingency_property(nc, g, n_bins, m, seed):
    rng = np.random.default_rng(seed)
    packed, d, w = _case(rng, nc, g, n_bins, m)
    out = contingency(packed, d, w, n_bins=n_bins, n_dec=m)
    ref = contingency_ref(packed, d, w, n_bins=n_bins, n_dec=m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-5)
