"""Roofline machinery: HLO collective parsing, extrapolation, model FLOPs."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import roofline as rl
from repro.models.config import SHAPES

HLO_SAMPLE = """
HloModule jit_step
  %x.1 = bf16[256,1024]{1,0} all-reduce(bf16[256,1024]{1,0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %y.2 = f32[64,128]{1,0} all-gather(f32[64,32]{1,0} %p1), replica_groups=[2,4]<=[8], dimensions={1}
  %z.3 = (f32[16,16]{1,0}, f32[16,16]{1,0}) reduce-scatter(f32[64,16]{1,0} %a, f32[64,16]{1,0} %b), replica_groups={{0,1,2,3}}
  %w.4 = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %c), source_target_pairs={{0,1},{1,0}}
  %v.5 = bf16[32]{0} all-to-all(bf16[32]{0} %d), replica_groups=[1,8]<=[8]
"""


def test_parse_collectives_counts_and_bytes():
    stats = rl.parse_collectives(HLO_SAMPLE)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1, "reduce-scatter": 1,
                            "collective-permute": 1, "all-to-all": 1}
    assert stats.result_bytes["all-reduce"] == 256 * 1024 * 2
    assert stats.result_bytes["all-gather"] == 64 * 128 * 4
    assert stats.result_bytes["reduce-scatter"] == 2 * 16 * 16 * 4
    # ring model: all-reduce over groups of 4 → 2·(3/4)·bytes
    np.testing.assert_allclose(stats.wire_bytes["all-reduce"],
                               2 * 0.75 * 256 * 1024 * 2)
    # all-gather group size from iota form [2,4]<=[8] → 4
    np.testing.assert_allclose(stats.wire_bytes["all-gather"],
                               0.75 * 64 * 128 * 4)


def test_extrapolation_linear():
    assert rl.extrapolate(10.0, 14.0, 5) == 10.0 + 4 * 4.0
    assert rl.extrapolate(10.0, 9.0, 5) == 10.0  # negative delta clamps


def test_roofline_terms_dominance():
    t = rl.roofline_terms(197e12, 819e9 * 2, 50e9 * 0.5)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["collective_s"] == pytest.approx(0.5)
    assert t["dominant"] == "memory"


def test_model_flops_train_scales_6nd():
    cfg = get_config("tinyllama-1.1b")
    shape = SHAPES["train_4k"]
    mf = rl.model_flops(cfg, shape)
    nd6 = 6.0 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert mf > nd6  # includes attention
    assert mf < nd6 * 1.5


def test_model_flops_moe_uses_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    shape = SHAPES["train_4k"]
    mf = rl.model_flops(cfg, shape)
    active = 6.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len
    total = 6.0 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert mf < total * 0.25      # far below dense-equivalent
    assert mf > active * 0.9


def test_model_flops_decode_is_tiny_vs_train():
    cfg = get_config("mistral-nemo-12b")
    assert (rl.model_flops(cfg, SHAPES["decode_32k"])
            < rl.model_flops(cfg, SHAPES["train_4k"]) / 1000)


def test_window_caps_attention_span():
    cfg = get_config("jamba-1.5-large-398b")
    mf_500k = rl.model_flops(cfg, SHAPES["long_500k"])
    # one token, window 32k on 9 attention layers: far below a dense-attn arch
    assert mf_500k < 2.5 * cfg.active_param_count()
