"""Analytic kernel-cost model + selector/autotune tests (DESIGN.md §5.2).

Three concerns:

* the closed-form FLOPs/bytes of :mod:`repro.kernels.contingency.model`
  against XLA's own ``compiled.cost_analysis()`` — on *single-grid-step*
  shapes, because XLA counts a ``while`` body once (the roofline.py caveat),
  so multi-step grids under-report by the step count;
* the selector seam: byte-identical reducts and Θ histories across every
  selector mode × Θ backend (tiles and ladder rungs must never change bits,
  only speed);
* the autotune caches: platform-scoped keys, bounded LRU, disk round-trip,
  and the top-k pruned (opt-in) timing refinement.
"""
import json
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import ladder_rungs
from repro.core.reduction import plar_reduce
from repro.kernels.contingency import autotune
from repro.kernels.contingency.autotune import (
    SELECTOR_MODES,
    autotune_block_sizes,
    autotune_cache_clear,
    autotune_cache_info,
    resolve_tiles,
    shape_bucket,
)
from repro.kernels.contingency.fused import fused_theta_pallas
from repro.kernels.contingency.kernel import contingency_pallas
from repro.kernels.contingency.model import (
    KernelCost,
    VMEM_BUDGET_BYTES,
    contingency_cost,
    feasible_tiles,
    fused_cost,
    modeled_time_s,
    prune_ladder_rungs,
    rank_tiles,
    rung_eval_cost_bytes,
    select_tiles,
    sweep_cost,
    sweep_working_set_bytes,
    working_set_bytes,
)
from repro.kernels.contingency.sweep import sweep_theta_pallas


def _xla_cost(lowered):
    """(flops, bytes accessed) from XLA's analysis of a lowered computation."""
    ca = lowered.compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def _operands(nc, g, n_bins, m, v_max=1, seed=0):
    rng = np.random.default_rng(seed)
    packed = jnp.asarray(rng.integers(0, n_bins, (nc, g)), jnp.int32)
    x_t = jnp.asarray(rng.integers(0, v_max, (nc, g)), jnp.int32)
    r_ids = jnp.asarray(rng.integers(0, max(n_bins // v_max, 1), (g,)),
                        jnp.int32)
    wd = jnp.zeros((g, m), jnp.float32).at[
        jnp.arange(g), jnp.asarray(rng.integers(0, m, (g,)))].set(1.0)
    return packed, x_t, r_ids, wd


# ---------------------------------------------------------------------------
# model vs compiled.cost_analysis()
# ---------------------------------------------------------------------------


def test_contingency_cost_vs_xla():
    # single grid step: nc=1, K̂/bk = 1, Ĝ/bg = 1 — XLA's while-once count
    # is then exact, so FLOPs must match tightly and bytes within 2×.
    nc, g, nb, m, bk, bg = 1, 1024, 8, 128, 8, 1024
    packed, _, _, wd = _operands(nc, g, nb, m)
    low = contingency_pallas.lower(packed, wd, n_bins=nb, bk=bk, bg=bg,
                                   interpret=True)
    flops_x, bytes_x = _xla_cost(low)
    cost = contingency_cost(nc, g, nb, m, bk, bg)
    assert 0.9 <= cost.flops / flops_x <= 1.1
    assert 0.5 <= cost.hbm_bytes / bytes_x <= 2.0
    assert cost.grid_steps == 1
    assert cost.transcendentals == 0.0


def test_fused_cost_vs_xla():
    nc, g, nb, m, bk, bg = 1, 1024, 8, 128, 8, 1024
    packed, _, _, wd = _operands(nc, g, nb, m)
    low = fused_theta_pallas.lower(packed, wd, n_bins=nb, delta="SCE",
                                   bk=bk, bg=bg, interpret=True)
    flops_x, bytes_x = _xla_cost(low)
    cost = fused_cost(nc, g, nb, m, bk, bg, delta="SCE")
    assert 0.9 <= cost.flops / flops_x <= 1.2   # epilogue ≈8 flops/cell
    assert 0.5 <= cost.hbm_bytes / bytes_x <= 2.0
    assert cost.transcendentals > 0           # SCE logs
    assert fused_cost(nc, g, nb, m, bk, bg, delta="PR").transcendentals == 0


@pytest.mark.parametrize("nb,v_max", [(8, 2), (16, 4)])
def test_sweep_cost_vs_xla(nb, v_max):
    # bc=1 keeps XLA's per-op operand counting aligned with the stream model
    # (at bc>1 XLA charges the reused wd tile once per candidate, which the
    # read-once schedule does not pay — the reuse test below covers that).
    nc, g, m, bc, bk, bg = 1, 1024, 128, 1, nb, 1024
    _, x_t, r_ids, wd = _operands(nc, g, nb, m, v_max=v_max)
    low = sweep_theta_pallas.lower(x_t, r_ids, wd, v_max=v_max, n_bins=nb,
                                   delta="SCE", bc=bc, bk=bk, bg=bg,
                                   interpret=True)
    flops_x, bytes_x = _xla_cost(low)
    cost = sweep_cost(nc, g, nb, m, bc, bk, bg, v_max=v_max, delta="SCE")
    assert 0.9 <= cost.flops / flops_x <= 1.2
    assert 0.5 <= cost.hbm_bytes / bytes_x <= 2.0


def test_sweep_bc_reuse_model_property():
    # The whole point of the sweep kernel: shared r_ids/wd traffic carries a
    # 1/BC factor.  Larger candidate blocks must strictly cut modeled HBM.
    nc, g, nb, m = 64, 4096, 1024, 128
    b1 = sweep_cost(nc, g, nb, m, 1, 128, 256).hbm_bytes
    b8 = sweep_cost(nc, g, nb, m, 8, 128, 256).hbm_bytes
    assert b8 < b1
    # and the saving is the shared-stream term, ≈ (1 - 1/8) of it
    shared1 = 4.0 * 4096 * (1 + m) * nc * (1024 // 128)
    assert b1 - b8 == pytest.approx(shared1 * (1 - 1 / 8), rel=1e-6)


def test_feasible_tiles_respect_budget_and_alignment():
    for kernel in ("contingency", "fused", "sweep"):
        cands = feasible_tiles(kernel, 64, 3000, 1024, 128)
        assert cands
        for tiles in cands:
            if kernel == "sweep":
                bc, bk, bg = tiles
                assert sweep_working_set_bytes(bc, bk, bg, 128) <= VMEM_BUDGET_BYTES
            else:
                bk, bg = tiles
                assert working_set_bytes(bk, bg, 128) <= VMEM_BUDGET_BYTES
            assert bk % 8 == 0 and bg % 128 == 0
    # tiny table: no tile more than one step beyond the padded shape
    for bk, bg in feasible_tiles("contingency", 2, 300, 40, 128):
        assert bk // 2 < 40 + 7 and bg // 2 < 384


def test_rank_is_deterministic_and_sorted():
    r1 = rank_tiles("fused", 64, 3000, 1024, 128)
    r2 = rank_tiles("fused", 64, 3000, 1024, 128)
    assert r1 == r2
    times = [t for _, _, t in r1]
    assert times == sorted(times)
    assert select_tiles("fused", 64, 3000, 1024, 128) == r1[0][0]
    assert all(isinstance(c, KernelCost) and modeled_time_s(c) == t
               for _, c, t in r1[:3])


# ---------------------------------------------------------------------------
# analytic ladder-rung pruning
# ---------------------------------------------------------------------------


def test_prune_ladder_rungs_invariants():
    rungs = ladder_rungs(4096)                      # (256, 512, ..., 4096)
    pruned = prune_ladder_rungs(rungs, 4096, 8)
    assert set(pruned) <= set(rungs)                # subset of the pow2 family
    assert pruned[-1] == rungs[-1]                  # exact top always kept
    assert list(pruned) == sorted(pruned)
    # bin-dominated regime (tiny fixed term): every halving saves ~50% > 15%
    assert prune_ladder_rungs((256, 512, 1024), 256, 23) == (256, 512, 1024)


def test_prune_ladder_dispatch_bound_collapse():
    # granule-dominated regime: the fixed G·m term dwarfs the per-bin term,
    # so small rungs save nothing and collapse away.
    pruned = prune_ladder_rungs((256, 512), 4096, 128)
    assert pruned == (512,)
    # monotonicity of the underlying cost
    assert rung_eval_cost_bytes(256, 4096, 128) < rung_eval_cost_bytes(512, 4096, 128)


def test_ladder_rungs_selector_modes():
    # default (heuristic) is the unchanged pow2 ladder — pinned by test_sweep
    assert ladder_rungs(1024) == (256, 512, 1024)
    pruned = ladder_rungs(4096, selector="analytic", g=4096, m=128)
    full = ladder_rungs(4096)
    assert set(pruned) <= set(full) and pruned[-1] == full[-1]
    # without shape context the analytic mode degrades to the full ladder
    assert ladder_rungs(4096, selector="analytic") == full


# ---------------------------------------------------------------------------
# selector parity: tiles/rungs must never change bits
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["segment", "fused_xla", "sweep_xla"])
def test_selector_parity_matrix(backend):
    rng = np.random.default_rng(7)
    x = rng.integers(0, 3, (300, 10)).astype(np.int32)
    d = rng.integers(0, 3, (300,)).astype(np.int32)
    ref = None
    for sel in SELECTOR_MODES:
        r = plar_reduce(x, d, delta="SCE", backend=backend, ladder=True,
                        selector=sel)
        key = (tuple(r.reduct),
               tuple(np.float32(t).tobytes() for t in r.theta_history))
        if ref is None:
            ref = key
        assert key == ref, f"selector={sel} backend={backend} changed bits"


def test_ops_default_tiles_match_analytic():
    # bk/bg=None routes through the analytic selector; explicit tiles with
    # the same values must agree exactly.
    from repro.kernels.contingency.ops import contingency

    nc, g, nb, m = 4, 600, 32, 3
    rng = np.random.default_rng(1)
    packed = jnp.asarray(rng.integers(0, nb, (nc, g)), jnp.int32)
    d = jnp.asarray(rng.integers(0, m, (g,)), jnp.int32)
    w = jnp.ones((g,), jnp.float32)
    auto = contingency(packed, d, w, n_bins=nb, n_dec=m)
    bk, bg = resolve_tiles("contingency", nc=nc, g=g, n_bins=nb, m=128,
                           selector="analytic")
    manual = contingency(packed, d, w, n_bins=nb, n_dec=m, bk=bk, bg=bg)
    assert jnp.array_equal(auto, manual)


def test_resolve_tiles_modes():
    kw = dict(nc=8, g=3000, n_bins=1024, m=128)
    assert resolve_tiles("contingency", **kw, selector="pinned") == (128, 512)
    assert resolve_tiles("sweep", **kw, selector="pinned") == (8, 128, 256)
    heur = resolve_tiles("fused", **kw, selector="heuristic")
    assert heur == autotune.select_block_sizes(1024, 3000, 128)
    ana = resolve_tiles("fused", **kw)     # None → analytic default
    assert ana == select_tiles("fused", 8, 3000, 1024, 128)
    with pytest.raises(ValueError, match="unknown tile selector"):
        resolve_tiles("fused", **kw, selector="nope")


# ---------------------------------------------------------------------------
# caches: platform key, LRU bound, disk round-trip
# ---------------------------------------------------------------------------


@pytest.fixture()
def tmp_disk(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune_cache_clear(disk=True)
    yield path
    autotune_cache_clear(disk=True)


def test_cache_key_includes_platform(tmp_disk):
    shape = dict(nc=2, g=256, n_bins=64, m=8)
    autotune_block_sizes(**shape)                       # default platform
    info0 = autotune_cache_info()
    autotune_block_sizes(**shape, platform="tpu")       # distinct key
    info1 = autotune_cache_info()
    assert info1["misses"] == info0["misses"] + 1
    autotune_block_sizes(**shape, platform="tpu")       # now a hit
    assert autotune_cache_info()["hits"] == info1["hits"] + 1


def test_cache_clear_and_info(tmp_disk):
    autotune_block_sizes(2, 256, 64, 8)
    info = autotune_cache_info()
    assert info["size"] >= 1 and info["disk_entries"] >= 1
    assert info["disk_path"] == str(tmp_disk)
    autotune_cache_clear(disk=True)
    info = autotune_cache_info()
    assert info["size"] == 0 and info["hits"] == 0 and info["misses"] == 0
    assert info["disk_entries"] == 0 and not tmp_disk.exists()


def test_cache_lru_bounded(tmp_disk, monkeypatch):
    monkeypatch.setattr(autotune, "_CACHE_MAXSIZE", 4)
    autotune_cache_clear()
    for i in range(8):
        autotune_block_sizes(2, 256 + 128 * i, 64, 8)
    assert autotune_cache_info()["size"] <= 4


def test_disk_cache_roundtrip(tmp_disk):
    pick = autotune_block_sizes(2, 300, 40, 3)
    assert tmp_disk.exists()
    data = json.loads(tmp_disk.read_text())
    key = autotune._disk_key(jax.default_backend(), "contingency",
                             shape_bucket(2, 300, 40, 3))
    assert tuple(data[key]) == pick
    # a fresh "process" (memory cleared) resolves the persisted tuning
    autotune_cache_clear()
    assert resolve_tiles("contingency", nc=2, g=300, n_bins=40, m=3) == pick


def test_disk_tuned_overrides_model(tmp_disk):
    kw = dict(nc=8, g=3000, n_bins=1024, m=128)
    model_pick = select_tiles("fused", 8, 3000, 1024, 128)
    custom = (8, 128)
    assert custom != model_pick
    key = autotune._disk_key(jax.default_backend(), "fused",
                             shape_bucket(8, 3000, 1024, 128))
    tmp_disk.write_text(json.dumps({key: list(custom)}))
    autotune._disk_state["data"] = None                 # force reload
    assert resolve_tiles("fused", **kw) == custom
    # other modes ignore the disk cache
    assert resolve_tiles("fused", **kw, selector="heuristic") != custom


def test_restricted_candidates_not_persisted(tmp_disk):
    # a rank over a caller-pinned candidate list is not a shape tuning and
    # must not shadow the model for the whole bucket
    autotune_block_sizes(2, 300, 40, 3, delta="SCE",
                         candidates=((8, 128), (16, 256)))
    assert not tmp_disk.exists()


# ---------------------------------------------------------------------------
# timing refinement: top-k pruning + failed-compile skip
# ---------------------------------------------------------------------------


def test_refine_compiles_at_most_topk(tmp_disk, monkeypatch):
    built = []

    def fake_build(kernel, tiles, *a, **kw):
        built.append(tiles)
        return lambda: jnp.zeros(())

    monkeypatch.setattr(autotune, "_build_candidate_fn", fake_build)
    pick = autotune_block_sizes(4, 2000, 512, 16, delta="SCE", refine=True,
                                reps=1, top_k=3)
    assert len(built) <= 3                       # analytic pruning before timing
    assert pick in built                          # winner came from the timed set
    assert len(feasible_tiles("fused", 4, 2000, 512, 128)) > 3  # pruning real


def test_refine_default_is_zero_compiles(tmp_disk, monkeypatch):
    def boom(*a, **kw):  # pragma: no cover - must not be reached
        raise AssertionError("refine=False must never build a candidate")

    monkeypatch.setattr(autotune, "_build_candidate_fn", boom)
    pick = autotune_block_sizes(4, 2000, 512, 16, delta="SCE")
    assert pick == select_tiles("fused", 4, 2000, 512, 128)


def test_refine_skips_failed_compile(tmp_disk, monkeypatch, caplog):
    calls = []

    def flaky_build(kernel, tiles, *a, **kw):
        calls.append(tiles)
        if len(calls) == 1:
            def dead():
                raise RuntimeError("XLA compile exploded")
            return dead
        return lambda: jnp.zeros(())

    monkeypatch.setattr(autotune, "_build_candidate_fn", flaky_build)
    with caplog.at_level(logging.WARNING,
                         logger="repro.kernels.contingency.autotune"):
        pick = autotune_block_sizes(4, 2000, 512, 16, delta="SCE",
                                    refine=True, reps=1, top_k=2)
    assert pick == calls[1]                      # survivor wins
    assert any("failed to compile" in r.message for r in caplog.records)
