"""Optional-hypothesis shim: property tests *skip* (never error) on bare envs.

``hypothesis`` is a dev extra (requirements-dev.txt), not a runtime
dependency.  Importing it unconditionally made tier-1 collection abort on a
bare environment, taking every non-property test in the module down with it.
Test modules import ``given``/``settings``/``st`` from here instead: with
hypothesis installed these are the real objects; without it, ``@given`` turns
the test into a single skip and the rest of the module still runs.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # bare env — degrade property tests to skips
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every call returns None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        if args and callable(args[0]):  # bare @settings
            return args[0]
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            def skipper():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            skipper.__name__ = f.__name__
            skipper.__doc__ = f.__doc__
            return skipper

        return deco
