"""§Perf A1 correctness: weight-stationary MoE island == unsharded reference."""
import os
import subprocess
import sys

ENV = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": "src"}


def _run(script: str):
    r = subprocess.run([sys.executable, "-c", script], env=ENV,
                       capture_output=True, text=True, timeout=540,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"


def test_weight_stationary_moe_matches_reference():
    _run("""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.distributed.api import use_mesh

cfg = get_config("qwen3-moe-235b-a22b").reduced()
model_ref = build_model(cfg)
params = model_ref.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 8)), jnp.int32)}
ref = model_ref.forward(params, batch)

from repro.distributed.api import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
model_ws = build_model(dataclasses.replace(cfg, moe_weight_stationary=True))
with use_mesh(mesh):
    out = jax.jit(model_ws.forward)(params, batch)
err = float(jnp.max(jnp.abs(ref - out)))
assert err < 1e-3, err

# decode path
toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 10)), jnp.int32)
lg_ref, cache, lengths = model_ref.prefill(params, {"tokens": toks[:, :8]}, cache_len=12)
d_ref, _, _ = model_ref.decode(params, cache, toks[:, 8:9], lengths)
with use_mesh(mesh):
    lg_ws, cache_ws, lengths_ws = jax.jit(
        lambda p, b: model_ws.prefill(p, b, cache_len=12))(params, {"tokens": toks[:, :8]})
    d_ws, _, _ = jax.jit(model_ws.decode)(params, cache_ws, toks[:, 8:9], lengths_ws)
err = float(jnp.max(jnp.abs(d_ref - d_ws)))
assert err < 1e-3, err
""")
