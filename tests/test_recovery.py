"""Resilience layer (DESIGN.md §3.10): lineage recovery, durable
checkpoints, fault injection, and the hardened serving path.

The acceptance contract: a build that loses a shard and recovers it by
re-folding ONLY that shard's lineage is **bitwise identical** to the
unfailed build — granularity arrays, fingerprint, and downstream reducts
and Θ histories across ≥3 measures; a killed-and-restarted server restores
its handles from the checkpoint and answers its first query warm.
"""
import asyncio
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import plar_reduce
from repro.core.recovery import (
    ChunkSlice,
    ShardLineage,
    build_sharded,
    merge_shards,
    recover,
    refold_shard,
)
from repro.data import TabularStream
from repro.service import (
    CheckpointCorrupt,
    DatasetHandle,
    FaultInjected,
    FaultPlan,
    FaultSpec,
    QueryPoisoned,
    ReductServer,
    RetryPolicy,
    ServerStopped,
    ServiceCheckpointer,
    ShardLost,
    granularity_fingerprint,
    repair_reduce,
)
from repro.train.checkpoint import CheckpointManager

PARITY_DELTAS = ["PR", "SCE", "LCE"]


def _stream(n=900, a=8, seed=0):
    return TabularStream(n_rows=n, n_attrs=a, v_max=3, n_dec=2,
                         distinct_fraction=0.3, seed=seed)


def _gran_equal(g1, g2):
    """Bitwise equality of the live prefix + static metadata."""
    n1, n2 = int(g1.num), int(g2.num)
    assert n1 == n2
    np.testing.assert_array_equal(np.asarray(g1.x)[:n1], np.asarray(g2.x)[:n1])
    np.testing.assert_array_equal(np.asarray(g1.d)[:n1], np.asarray(g2.d)[:n1])
    np.testing.assert_array_equal(np.asarray(g1.w)[:n1], np.asarray(g2.w)[:n1])
    assert int(g1.n_total) == int(g2.n_total)
    assert granularity_fingerprint(g1) == granularity_fingerprint(g2)


# ---------------------------------------------------------------------------
# shard lineage + re-fold recovery
# ---------------------------------------------------------------------------


def test_refold_shard_bitwise_identical():
    """Replaying one shard's lineage reproduces its granularity exactly."""
    src = _stream()
    build = build_sharded(src, 4, chunk_rows=256)
    assert build.n_shards == 4 and not build.lost
    for s in range(4):
        lin = build.lineages[s]
        assert lin.shard_index == s and lin.slices
        _gran_equal(refold_shard(src, lin), build.shards[s])


def test_recover_reproduces_unfailed_build_and_downstream():
    """Lost shard → re-fold + re-merge == the unfailed build, bitwise —
    and therefore byte-identical reducts and Θ histories across ≥3
    measures (the §3.10 parity contract)."""
    src = _stream()
    unfailed = build_sharded(src, 3, chunk_rows=256)
    failed = build_sharded(src, 3, chunk_rows=256)
    failed.drop(1)
    assert failed.lost == [1]
    assert recover(failed, src) == [1]
    _gran_equal(failed.merged, unfailed.merged)
    for delta in PARITY_DELTAS:
        a = plar_reduce(source=unfailed.merged, delta=delta)
        b = plar_reduce(source=failed.merged, delta=delta)
        assert a.reduct == b.reduct
        assert a.core == b.core
        assert a.theta_history == b.theta_history
        assert a.theta_full == b.theta_full


def test_sharded_matches_monolithic_build():
    """The sharded path itself is a parity-preserving build: merged shards
    == one-shard build == the engine's own resolve path."""
    src = _stream(n=700, a=6)
    _gran_equal(build_sharded(src, 5, chunk_rows=200).merged,
                build_sharded(src, 1, chunk_rows=200).merged)


def test_recover_with_cascading_drops_converges():
    """A shard dying *during* recovery is re-folded again — the loop
    converges once the (finite) plan is exhausted."""
    src = _stream()
    unfailed = build_sharded(src, 3, chunk_rows=256)
    plan = FaultPlan.parse("shard_drop@0:2,shard_drop@1:0")
    failed = build_sharded(src, 3, chunk_rows=256, fault_plan=plan)
    assert failed.lost == [2]  # the build-time drop
    recovered = recover(failed, src, fault_plan=plan)
    # shard 2 re-folded, then the plan killed shard 0 mid-recovery
    assert sorted(recovered) == [0, 2] and not failed.lost
    _gran_equal(failed.merged, unfailed.merged)
    assert plan.fired == [("shard_drop", 0), ("shard_drop", 1)]


def test_merge_shards_refuses_lost_shards():
    src = _stream(n=300, a=5)
    build = build_sharded(src, 2, chunk_rows=128)
    build.drop(0)
    with pytest.raises(ValueError, match="recover lost shards first"):
        merge_shards(build.shards)


def test_lineage_dict_roundtrip():
    lin = ShardLineage(shard_index=1, n_shards=4, chunk_rows=256, n_dec=2,
                       v_max=3, exact=True,
                       slices=(ChunkSlice(0, 64, 128), ChunkSlice(1, 64, 128)))
    assert ShardLineage.from_dict(lin.to_dict()) == lin


def test_handle_sharded_lifecycle():
    """DatasetHandle wraps the same machinery: drop → recover keeps the
    fingerprint; an online update retires the lineage (not replayable)."""
    src = _stream(n=600, a=6)
    h = DatasetHandle.create_sharded(src, 3, chunk_rows=200)
    fp = h.fingerprint
    r0 = h.reduce("PR")
    h.drop_shard(0)
    assert h.lost_shards == [0]
    assert h.recover_shards(src) == [0]
    assert h.fingerprint == fp
    assert h.reduce("PR").reduct == r0.reduct
    h.update(*src.chunk(0, 64))  # streamed rows: lineage no longer covers
    assert h.lineage is None
    with pytest.raises(ShardLost):
        h.drop_shard(0)


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------


def test_fault_plan_parse_grammar():
    plan = FaultPlan.parse("shard_drop@0:1,dispatch@2x3,merge!@0")
    assert plan.specs[0] == FaultSpec("shard_drop", 0, arg=1)
    assert plan.specs[1] == FaultSpec("dispatch", 2, count=3)
    assert plan.specs[2] == FaultSpec("merge", 0, transient=False)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("frobnicate@0")
    with pytest.raises(ValueError, match="KIND@STEP"):
        FaultPlan.parse("dispatch")


def test_fault_plan_fires_deterministically():
    plan = FaultPlan.parse("dispatch@1x2")
    assert plan.fire("dispatch") is None            # occurrence 0
    with pytest.raises(FaultInjected) as e1:
        plan.inject("dispatch")                     # occurrence 1
    assert e1.value.transient and e1.value.step == 1
    with pytest.raises(FaultInjected):
        plan.inject("dispatch")                     # occurrence 2
    assert plan.fire("dispatch") is None            # occurrence 3: exhausted
    assert plan.fired == [("dispatch", 1), ("dispatch", 2)]
    plan.reset()
    assert plan.fire("dispatch") is None and plan.fired == []


def test_fault_plan_seeded_replayable():
    a = FaultPlan.seeded(7, horizon=16, n_faults=3)
    b = FaultPlan.seeded(7, horizon=16, n_faults=3)
    assert a.specs == b.specs
    assert a.specs != FaultPlan.seeded(8, horizon=16, n_faults=3).specs


# ---------------------------------------------------------------------------
# durable checkpoints
# ---------------------------------------------------------------------------


def _handle(seed=0, n=500, a=6):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 3, (n, a)).astype(np.int32)
    d = rng.integers(0, 2, (n,)).astype(np.int32)
    return DatasetHandle.create(x, d, n_dec=2, v_max=3), x, d


def test_service_checkpoint_roundtrip(tmp_path):
    h, _x, _d = _handle()
    r = h.reduce("PR")
    h.reduce("SCE", tol=1e-5)
    ck = ServiceCheckpointer(str(tmp_path))
    assert ck.save({"ds": h}) is not None
    step, handles = ck.restore()
    h2 = handles["ds"]
    _gran_equal(h2.gran, h.gran)
    assert h2.fingerprint == h.fingerprint
    assert set(h2._results) == set(h._results)
    got = h2._results[("PR", (("exact", True),))]
    assert got.reduct == r.reduct and got.theta_history == r.theta_history
    # restored handle answers warm, and its repair is byte-identical to the
    # live handle's repair from the same state
    live = h.reduce("PR")
    restored = h2.reduce("PR")
    assert h.last_was_warm and h2.last_was_warm
    assert restored.reduct == live.reduct
    assert restored.theta_history == live.theta_history


def test_sharded_handle_checkpoint_keeps_lineage(tmp_path):
    src = _stream(n=600, a=6)
    h = DatasetHandle.create_sharded(src, 3, chunk_rows=200)
    ck = ServiceCheckpointer(str(tmp_path))
    ck.save({"ds": h})
    _step, handles = ck.restore()
    h2 = handles["ds"]
    assert h2.lineage is not None and len(h2.lineage) == 3
    assert h2.lineage == h.lineage
    assert h2.fingerprint == h.fingerprint


def test_checkpoint_crash_leaves_previous_step_restorable(tmp_path):
    """An injected crash between staging and commit aborts the step with
    nothing committed — the previous step still restores."""
    h, _x, _d = _handle()
    h.reduce("PR")
    ck = ServiceCheckpointer(str(tmp_path),
                             fault_plan=FaultPlan.parse("checkpoint@1"))
    assert ck.save({"ds": h}) is not None          # step 1 commits
    h.update(*_handle(seed=1)[1:])                  # change content
    assert ck.save({"ds": h}) is None               # step 2: injected crash
    assert ck.failed_saves == 1
    assert isinstance(ck.last_error, FaultInjected)
    step, handles = ck.restore()
    assert step == 1                                # pre-crash state survives
    assert handles["ds"].fingerprint != h.fingerprint


def test_checkpoint_fingerprint_mismatch_is_corrupt(tmp_path):
    import json
    h, _x, _d = _handle()
    ck = ServiceCheckpointer(str(tmp_path))
    path = ck.save({"ds": h})
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["extra"]["datasets"]["ds"]["fingerprint"] ^= 0xDEAD
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(CheckpointCorrupt, match="fingerprint"):
        ck.restore()


def test_train_restore_skips_corrupt_step(tmp_path):
    """S1: auto-pick restore degrades to the next older committed step when
    the newest is corrupt (truncated npz), with a warning; an explicitly
    requested corrupt step still raises."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, {"w": np.arange(4)})
    mgr.save(2, {"w": np.arange(8)})
    npz = os.path.join(mgr._path(2), "arrays.npz")
    with open(npz, "wb") as f:
        f.write(b"\x00" * 16)  # committed but garbage
    with pytest.warns(UserWarning, match="skipping corrupt checkpoint"):
        step, tree, _extra = mgr.restore()
    assert step == 1 and len(tree["w"]) == 4
    with pytest.raises(Exception):
        mgr.restore(step=2)


def test_train_restore_all_corrupt_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": np.arange(4)})
    npz = os.path.join(mgr._path(1), "arrays.npz")
    with open(npz, "wb") as f:
        f.write(b"junk")
    with pytest.warns(UserWarning):
        with pytest.raises(FileNotFoundError, match="all 1 committed"):
            mgr.restore()


# ---------------------------------------------------------------------------
# hardened server: restart, flush, retry, quarantine, stale
# ---------------------------------------------------------------------------


def test_server_restart_restores_and_answers_warm(tmp_path):
    """Kill + restart: the new server restores the checkpointed handle and
    serves its first query through the warm repair path."""
    rng = np.random.default_rng(3)
    x = rng.integers(0, 3, (600, 6)).astype(np.int32)
    d = rng.integers(0, 2, (600,)).astype(np.int32)
    ckdir = str(tmp_path)

    async def first_life():
        async with ReductServer(checkpoint_dir=ckdir) as srv:
            await srv.submit("ds", x, d, n_dec=2, v_max=3)
            await srv.query("ds", delta="PR")          # cold
            # run one warm repair on the handle so the checkpoint persists
            # the repair fixed point — exactly what the restarted server's
            # first (warm) query must reproduce byte-for-byte
            r = await asyncio.to_thread(srv.handle("ds").reduce, "PR")
            return r, srv.handle("ds").fingerprint

    r1, fp1 = asyncio.run(first_life())

    async def second_life():
        async with ReductServer(checkpoint_dir=ckdir) as srv:
            assert srv.stats["restored_datasets"] == 1
            assert srv.handle("ds").fingerprint == fp1
            r = await srv.query("ds", delta="PR")
            warm = srv.stats["warm"]
            # and the restored state keeps absorbing updates
            await srv.update("ds", x[:50], d[:50])
            r2 = await srv.query("ds", delta="PR")
            return r, warm, r2

    r2, warm, r3 = asyncio.run(second_life())
    assert r2.reduct == r1.reduct
    assert r2.theta_history == r1.theta_history
    assert warm == 1  # first post-restart query repaired, not recomputed
    assert r3.reduct  # post-restore update still serves


def test_server_stop_flushes_pending_updates(tmp_path):
    """S2: updates buffered but never demanded by a query are merged by
    stop() — an orderly shutdown never drops accepted updates."""
    rng = np.random.default_rng(4)
    x = rng.integers(0, 3, (400, 6)).astype(np.int32)
    d = rng.integers(0, 2, (400,)).astype(np.int32)
    ckdir = str(tmp_path)

    async def drive():
        srv = ReductServer(checkpoint_dir=ckdir)
        async with srv:
            await srv.submit("ds", x[:200], d[:200], n_dec=2, v_max=3)
            await srv.update("ds", x[200:300], d[200:300])
            await srv.update("ds", x[300:], d[300:])
            # no query: the batches are still buffered at stop()
        return srv.summary(), srv._handles["ds"].fingerprint

    stats, fp = asyncio.run(drive())
    assert stats["flushed_batches"] == 2
    assert stats["merges"] == 1  # both batches in ONE coalesced merge
    full = DatasetHandle.create(x, d, n_dec=2, v_max=3)
    assert fp == full.fingerprint
    # and the final checkpoint captured the flushed state
    _step, handles = ServiceCheckpointer(ckdir).restore()
    assert handles["ds"].fingerprint == full.fingerprint


def test_transient_dispatch_fault_is_retried():
    rng = np.random.default_rng(5)
    x = rng.integers(0, 3, (300, 6)).astype(np.int32)
    d = rng.integers(0, 2, (300,)).astype(np.int32)

    async def drive():
        async with ReductServer(
                fault_plan=FaultPlan.parse("dispatch@0"),
                retry=RetryPolicy(base_delay_s=0.001)) as srv:
            await srv.submit("ds", x, d, n_dec=2, v_max=3)
            r = await srv.query("ds", delta="PR")
            return r, dict(srv.stats)

    r, stats = asyncio.run(drive())
    assert r.reduct and not r.stale
    assert stats["retries"] == 1
    assert stats["quarantined"] == 0


def test_fatal_faults_quarantine_then_content_change_clears():
    """A config failing `quarantine_after` times is poisoned: followers get
    QueryPoisoned without re-running the dispatch; a content change (merge)
    clears the quarantine."""
    rng = np.random.default_rng(6)
    x = rng.integers(0, 3, (300, 6)).astype(np.int32)
    d = rng.integers(0, 2, (300,)).astype(np.int32)

    async def drive():
        async with ReductServer(
                fault_plan=FaultPlan.parse("dispatch!@0x2"),
                retry=RetryPolicy(base_delay_s=0.001,
                                  quarantine_after=2)) as srv:
            await srv.submit("ds", x[:250], d[:250], n_dec=2, v_max=3)
            with pytest.raises(FaultInjected):   # fatal: not retried
                await srv.query("ds", delta="PR")
            with pytest.raises(FaultInjected):
                await srv.query("ds", delta="PR")
            assert srv.stats["quarantined"] == 1
            with pytest.raises(QueryPoisoned, match="quarantined"):
                await srv.query("ds", delta="PR")
            runs_before = srv.stats["engine_runs"]
            # content change clears the slate; plan is exhausted → success
            await srv.update("ds", x[250:], d[250:])
            r = await srv.query("ds", delta="PR")
            return r, runs_before, dict(srv.stats)

    r, runs_before, stats = asyncio.run(drive())
    assert r.reduct
    assert runs_before == 0          # poisoned follower never hit the engine
    assert stats["retries"] == 0     # fatal faults are not retried


def test_serve_stale_degrades_to_last_good():
    """serve_stale=True: a failed dispatch serves the last known-good
    result flagged stale=True instead of erroring."""
    rng = np.random.default_rng(7)
    x = rng.integers(0, 3, (300, 6)).astype(np.int32)
    d = rng.integers(0, 2, (300,)).astype(np.int32)

    async def drive():
        async with ReductServer(
                fault_plan=FaultPlan.parse("dispatch!@1x3"),
                retry=RetryPolicy(base_delay_s=0.001),
                serve_stale=True) as srv:
            await srv.submit("ds", x[:250], d[:250], n_dec=2, v_max=3)
            good = await srv.query("ds", delta="PR")   # occurrence 0: fine
            await srv.update("ds", x[250:], d[250:])   # cache now misses
            degraded = await srv.query("ds", delta="PR")
            return good, degraded, dict(srv.stats)

    good, degraded, stats = asyncio.run(drive())
    assert not good.stale
    assert degraded.stale
    assert degraded.reduct == good.reduct
    assert stats["stale_served"] == 1


def test_stopped_server_raises_typed_error():
    async def drive():
        srv = ReductServer()
        async with srv:
            await srv.submit("ds", np.zeros((4, 2), np.int32),
                             np.zeros((4,), np.int32), n_dec=2, v_max=2)
        from repro.service import ServiceError
        with pytest.raises(ServiceError, match="not started"):
            srv._ensure_running()  # fully stopped == not started
        srv._stopping = True
        with pytest.raises(ServerStopped, match="server stopped"):
            srv._ensure_running()  # mid-shutdown: the typed stop error
        srv._stopping = False
        # the hierarchy: every typed error is still a RuntimeError
        assert issubclass(ServerStopped, RuntimeError)
        assert issubclass(QueryPoisoned, RuntimeError)

    asyncio.run(drive())


# ---------------------------------------------------------------------------
# S4: repair_reduce under adversarial inputs
# ---------------------------------------------------------------------------


def test_repair_empty_previous_is_cold_run():
    h, x, d = _handle(seed=8)
    cold = plar_reduce(x, d, delta="PR", n_dec=2, v_max=3)
    r, kept = repair_reduce(h.gran, [], delta="PR")
    assert kept == 0
    assert r.reduct == cold.reduct and r.theta_history == cold.theta_history


def test_repair_out_of_range_previous_is_sanitized():
    """A reduct referencing attributes beyond the table (a checkpoint from
    a wider schema) must not crash or corrupt the result: bad attributes
    are dropped from the warm hint, the answer matches the cold run."""
    h, x, d = _handle(seed=9)
    cold = plar_reduce(x, d, delta="PR", n_dec=2, v_max=3)
    bad = list(cold.reduct) + [h.gran.n_attrs + 3, -1, cold.reduct[0]]
    r, _kept = repair_reduce(h.gran, bad, delta="PR")
    assert r.reduct == cold.reduct
    assert r.theta_history == cold.theta_history
    # entirely-garbage previous degrades to a cold run
    r2, kept2 = repair_reduce(h.gran, [99, 99, -5], delta="PR")
    assert kept2 == 0 and r2.reduct == cold.reduct


def test_noop_update_racing_checkpoint_restore(tmp_path):
    """S4: a fingerprint-unchanged no-op update between checkpoint and
    restore must leave the restored handle fully consistent — same
    fingerprint, warm repair still valid."""
    h, _x, _d = _handle(seed=10)
    r = h.reduce("PR")
    ck = ServiceCheckpointer(str(tmp_path))
    ck.save({"ds": h})
    # empty batch: counted, but content (and fingerprint) unchanged
    h.update(np.zeros((0, h.gran.n_attrs), np.int32), np.zeros((0,), np.int32))
    assert h.n_updates == 1
    _step, handles = ck.restore()
    h2 = handles["ds"]
    assert h2.fingerprint == h.fingerprint
    # both warm-repair from the same persisted state → identical answers
    live, restored = h.reduce("PR"), h2.reduce("PR")
    assert h.last_was_warm and h2.last_was_warm
    assert restored.reduct == live.reduct
    assert restored.theta_history == live.theta_history
