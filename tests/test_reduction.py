"""End-to-end attribute reduction: PLAR/HAR/FSPA vs the Algorithm-1 oracle.

The paper's effectiveness claim (Tables 6–9): all three algorithms select the
*same* feature subsets.  We assert exactly that, across measures and modes.
"""
import numpy as np
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim: property tests skip on bare envs

from repro.core import fspa_reduce, har_reduce, plar_reduce
from repro.core.oracle import reduct_oracle, theta_oracle

DELTAS = ["PR", "SCE", "LCE", "CCE"]


def _table(rng, n, a, vmax=3, m=2, redundancy=0.5):
    """Random decision table with some redundant (duplicated) attributes."""
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    # make some columns copies of others → non-trivial reducts
    for j in range(a):
        if rng.random() < redundancy and j > 0:
            x[:, j] = x[:, rng.integers(0, j)]
    d = rng.integers(0, m, size=(n,)).astype(np.int32)
    return x, d


@pytest.mark.parametrize("delta", DELTAS)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_plar_matches_oracle(delta, seed):
    rng = np.random.default_rng(seed)
    x, d = _table(rng, 150, 7)
    assert plar_reduce(x, d, delta=delta).reduct == reduct_oracle(delta, x, d)


@pytest.mark.parametrize("delta", DELTAS)
def test_har_fspa_plar_agree(delta):
    """Paper Tables 6–9: identical 'Selected features' column."""
    rng = np.random.default_rng(17)
    x, d = _table(rng, 250, 9)
    r_plar = plar_reduce(x, d, delta=delta).reduct
    r_har = har_reduce(x, d, delta=delta).reduct
    r_fspa = fspa_reduce(x, d, delta=delta).reduct
    assert r_plar == r_har == r_fspa


@pytest.mark.parametrize("delta", DELTAS)
def test_spark_mode_equals_incremental(delta):
    """Paper-faithful re-key path == beyond-paper incremental path."""
    rng = np.random.default_rng(23)
    x, d = _table(rng, 200, 8)
    a = plar_reduce(x, d, delta=delta, mode="incremental").reduct
    b = plar_reduce(x, d, delta=delta, mode="spark").reduct
    assert a == b


@pytest.mark.parametrize("backend", ["segment", "onehot", "pallas"])
def test_contingency_backends_same_reduct(backend):
    rng = np.random.default_rng(29)
    x, d = _table(rng, 150, 6)
    got = plar_reduce(x, d, delta="SCE", backend=backend).reduct
    want = reduct_oracle("SCE", x, d)
    assert got == want


@pytest.mark.parametrize("mp_chunk", [1, 3, 16, 64])
def test_mp_level_invariance(mp_chunk):
    """Model-parallelism level (paper Table 12 knob) must not change results."""
    rng = np.random.default_rng(31)
    x, d = _table(rng, 150, 8)
    got = plar_reduce(x, d, delta="LCE", mp_chunk=mp_chunk).reduct
    want = reduct_oracle("LCE", x, d)
    assert got == want


def test_grc_init_invariance():
    """Fig. 9 knob: GrC on/off changes cost, never the reduct."""
    rng = np.random.default_rng(37)
    x, d = _table(rng, 200, 7)
    for delta in DELTAS:
        a = plar_reduce(x, d, delta=delta, grc_init=True).reduct
        b = plar_reduce(x, d, delta=delta, grc_init=False).reduct
        assert a == b, delta


def test_reduct_preserves_discernibility():
    """The defining property: Θ(D|reduct) == Θ(D|C) for every measure."""
    rng = np.random.default_rng(41)
    x, d = _table(rng, 180, 8)
    for delta in DELTAS:
        r = plar_reduce(x, d, delta=delta)
        theta_r = theta_oracle(delta, x, d, r.reduct)
        np.testing.assert_allclose(theta_r, r.theta_full, rtol=1e-5, atol=1e-6)


def test_core_subset_of_reduct():
    """Core ⊆ Reduct (paper Fig. 2)."""
    rng = np.random.default_rng(43)
    x, d = _table(rng, 150, 8, redundancy=0.3)
    for delta in DELTAS:
        r = plar_reduce(x, d, delta=delta)
        assert set(r.core) <= set(r.reduct)


def test_max_features_stop_criterion():
    rng = np.random.default_rng(47)
    x, d = _table(rng, 200, 10, redundancy=0.0)
    r = plar_reduce(x, d, delta="SCE", max_features=3, compute_core=False)
    assert len(r.reduct) <= 3


def test_deterministic_across_runs():
    rng = np.random.default_rng(53)
    x, d = _table(rng, 150, 7)
    a = plar_reduce(x, d, delta="CCE").reduct
    b = plar_reduce(x, d, delta="CCE").reduct
    assert a == b


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(20, 120),
    a=st.integers(2, 6),
    delta=st.sampled_from(DELTAS),
    seed=st.integers(0, 2**16),
)
def test_reduction_property(n, a, delta, seed):
    rng = np.random.default_rng(seed)
    x, d = _table(rng, n, a)
    got = plar_reduce(x, d, delta=delta).reduct
    want = reduct_oracle(delta, x, d)
    assert got == want
