"""§Perf optimization correctness: streaming flash backward == autodiff ref."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import flash_xla_attention


@pytest.mark.parametrize(
    "b,hq,hkv,s,dh,causal,window",
    [
        (2, 4, 2, 96, 32, True, None),
        (1, 2, 2, 100, 16, True, None),
        (1, 4, 1, 64, 32, False, None),
        (1, 4, 2, 128, 32, True, 40),
    ],
)
def test_flash_bwd_matches_ref(b, hq, hkv, s, dh, causal, window):
    rng = np.random.default_rng(s * 7 + dh)
    q = jnp.asarray(rng.standard_normal((b, hq, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, dh)), jnp.float32)

    def loss(fn):
        return lambda q_, k_, v_: jnp.sum(jnp.sin(fn(q_, k_, v_)) * 0.5)

    flash = lambda q_, k_, v_: flash_xla_attention(
        q_, k_, v_, causal=causal, window=window, q_chunk=32, kv_chunk=32)
    ref = lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal, window=window)

    np.testing.assert_allclose(np.asarray(flash(q, k, v)), np.asarray(ref(q, k, v)),
                               rtol=2e-5, atol=2e-5)
    g1 = jax.grad(loss(flash), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss(ref), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-5, atol=5e-5)


def test_flash_bwd_config_path():
    """cfg.flash_bwd=True trains with finite grads and the same loss value."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("tinyllama-1.1b").reduced()
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 24)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 24)), jnp.int32)}

    losses = {}
    for flag in (False, True):
        model = build_model(dataclasses.replace(cfg, flash_bwd=flag))
        params = model.init(jax.random.PRNGKey(0))
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
        losses[flag] = float(loss)
    assert abs(losses[True] - losses[False]) < 1e-3, losses
