"""Serving engine: batching, continuous refill, correctness vs step-by-step."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_generate(model, params, prompt, n_new, cache_len):
    toks = jnp.asarray(prompt[None], jnp.int32)
    logits, cache, lengths = model.prefill(params, {"tokens": toks}, cache_len=cache_len)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_new - 1):
        lg, cache, lengths = model.decode(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32), lengths)
        out.append(int(jnp.argmax(lg[0, -1])))
    return out


def test_engine_matches_reference_decoding(engine_setup):
    cfg, model, params = engine_setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n) for n in (5, 9, 3)]
    engine = ServingEngine(cfg, params, max_batch=2, cache_len=48)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    done = engine.serve(reqs)
    for req in done:
        want = _reference_generate(model, params, req.prompt, 5, 48)
        assert req.output == want, (req.rid, req.output, want)


def test_engine_continuous_batching_admits_all(engine_setup):
    cfg, model, params = engine_setup
    rng = np.random.default_rng(1)
    engine = ServingEngine(cfg, params, max_batch=2, cache_len=48)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4),
                    max_new_tokens=int(rng.integers(2, 7))) for i in range(6)]
    done = engine.serve(reqs)
    assert all(r.output is not None for r in done)
    assert all(len(r.output) == r.max_new_tokens for r in done)


def test_engine_eos_stops_early(engine_setup):
    cfg, model, params = engine_setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 6)
    ref = _reference_generate(model, params, prompt, 8, 48)
    eos = ref[2]  # force an early stop at the 3rd generated token
    engine = ServingEngine(cfg, params, max_batch=1, cache_len=48)
    done = engine.serve([Request(rid=0, prompt=prompt, max_new_tokens=8, eos_id=eos)])
    assert done[0].output[-1] == eos
    assert len(done[0].output) <= 8
