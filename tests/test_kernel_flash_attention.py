"""Shape/dtype sweeps + property tests: flash attention kernel vs oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim: property tests skip on bare envs

from repro.kernels.flash_attention import attention_ref, flash_attention


def _qkv(rng, b, hq, hkv, sq, skv, dh, dtype=np.float32):
    q = rng.standard_normal((b, hq, sq, dh)).astype(dtype)
    k = rng.standard_normal((b, hkv, skv, dh)).astype(dtype)
    v = rng.standard_normal((b, hkv, skv, dh)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,dh,causal,window",
    [
        (2, 4, 2, 128, 128, 64, True, None),    # GQA 2:1
        (1, 8, 1, 192, 192, 64, True, None),    # MQA
        (2, 4, 4, 96, 160, 32, True, None),     # chunked prefill: sq < skv
        (1, 4, 2, 256, 256, 64, True, 48),      # sliding window (jamba long-ctx)
        (1, 2, 2, 64, 64, 128, False, None),    # encoder (bidirectional)
        (1, 2, 1, 64, 64, 256, True, None),     # gemma head_dim=256
        (1, 4, 4, 1, 160, 64, True, None),      # single-token decode
    ],
)
def test_flash_matches_ref(b, hq, hkv, sq, skv, dh, causal, window):
    rng = np.random.default_rng(b * 100 + sq)
    q, k, v = _qkv(rng, b, hq, hkv, sq, skv, dh)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=64, bkv=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    rng = np.random.default_rng(5)
    q, k, v = _qkv(rng, 1, 4, 2, 128, 128, 64)
    q, k, v = q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=64, bkv=64)
    ref = attention_ref(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=0.05, atol=0.05
    )


@pytest.mark.parametrize("bq,bkv", [(32, 32), (64, 128), (128, 64), (256, 256)])
def test_flash_block_shape_invariance(bq, bkv):
    rng = np.random.default_rng(6)
    q, k, v = _qkv(rng, 1, 4, 2, 200, 200, 64)
    out = flash_attention(q, k, v, causal=True, bq=bq, bkv=bkv)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_rows_are_convex_combinations():
    """Each output row lies in the convex hull of V rows (softmax property)."""
    rng = np.random.default_rng(8)
    q, k, v = _qkv(rng, 1, 2, 2, 64, 64, 32)
    out = np.asarray(flash_attention(q, k, v, causal=True, bq=32, bkv=32))
    vmin, vmax = np.asarray(v).min(), np.asarray(v).max()
    assert out.min() >= vmin - 1e-4 and out.max() <= vmax + 1e-4


@settings(max_examples=20, deadline=None)
@given(
    sq=st.integers(1, 96),
    extra_kv=st.integers(0, 64),
    dh=st.sampled_from([16, 32, 64]),
    group=st.sampled_from([1, 2, 4]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_flash_property(sq, extra_kv, dh, group, causal, seed):
    rng = np.random.default_rng(seed)
    skv = sq + extra_kv
    q, k, v = _qkv(rng, 1, 2 * group, 2, sq, skv, dh)
    out = flash_attention(q, k, v, causal=causal, bq=32, bkv=32)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)
