"""Online reduct service (DESIGN.md §3.7): state, repair, serving.

The acceptance contract: a dataset created from the first half of a paper
table and streamed the second half in ≥4 update batches ends with the same
reduct as a batch ``plar_reduce`` over the full table, for all four
measures — while every update costs one monoid merge plus a warm-started
repair, never a from-scratch recompute.
"""
import asyncio

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import build_granularity, plar_reduce, with_capacity
from repro.data import scaled_paper_dataset
from repro.service import (
    DatasetHandle,
    ReductServer,
    granularity_fingerprint,
    repair_reduce,
    valid_prefix_len,
)

DELTAS = ["PR", "SCE", "LCE", "CCE"]


def _table(rng, n, a, vmax=3, m=2, redundancy=0.5):
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    for j in range(1, a):
        if rng.random() < redundancy:
            x[:, j] = x[:, rng.integers(0, j)]
    d = rng.integers(0, m, size=(n,)).astype(np.int32)
    return x, d


# ---------------------------------------------------------------------------
# DatasetHandle: state + updates + fingerprint
# ---------------------------------------------------------------------------


def test_handle_update_matches_batch_granularity():
    """Half + streamed updates == monolithic build (live prefix and
    fingerprint), and capacity follows the pow2 policy."""
    rng = np.random.default_rng(0)
    x, d = _table(rng, 600, 6, vmax=4, m=3)
    h = DatasetHandle.create(x[:300], d[:300], n_dec=3, v_max=4)
    for lo in range(300, 600, 100):
        h.update(x[lo:lo + 100], d[lo:lo + 100])
    mono = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=3, v_max=4)
    num = int(mono.num)
    assert h.n_granules == num
    np.testing.assert_array_equal(np.asarray(h.gran.x)[:num],
                                  np.asarray(mono.x)[:num])
    np.testing.assert_array_equal(np.asarray(h.gran.w)[:num],
                                  np.asarray(mono.w)[:num])
    assert h.gran.capacity == (1 << (num - 1).bit_length())
    assert h.n_updates == 3 and h.rows_absorbed == 600
    assert h.fingerprint == granularity_fingerprint(mono)


def test_fingerprint_content_invariance():
    """Fingerprint is a pure function of live content: invariant to padding
    capacity and build path, sensitive to rows and to multiplicities."""
    rng = np.random.default_rng(1)
    x, d = _table(rng, 200, 5)
    g = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3)
    assert granularity_fingerprint(g) == granularity_fingerprint(
        with_capacity(g, 4 * g.capacity))
    g2 = build_granularity(jnp.asarray(x[:199]), jnp.asarray(d[:199]),
                           n_dec=2, v_max=3)
    assert granularity_fingerprint(g) != granularity_fingerprint(g2)
    # duplicating a row changes only a weight — still a different content
    xd = np.concatenate([x, x[:1]])
    dd = np.concatenate([d, d[:1]])
    g3 = build_granularity(jnp.asarray(xd), jnp.asarray(dd), n_dec=2, v_max=3)
    assert granularity_fingerprint(g) != granularity_fingerprint(g3)


def test_handle_create_and_update_validation():
    rng = np.random.default_rng(2)
    x, d = _table(rng, 100, 4)
    with pytest.raises(ValueError, match="n_dec and v_max"):
        DatasetHandle.create(x, d)
    h = DatasetHandle.create(x, d, n_dec=2, v_max=3)
    with pytest.raises(ValueError, match="attributes"):
        h.update(x[:, :3], d)
    with pytest.raises(ValueError, match="decision shape"):
        h.update(x, d[:-1])
    with pytest.raises(ValueError, match="v_max"):
        h.update(np.full((2, 4), 3, np.int32), np.zeros((2,), np.int32))
    with pytest.raises(ValueError, match="n_dec"):
        h.update(np.zeros((2, 4), np.int32), np.full((2,), 2, np.int32))
    # negative codes would scatter out of segment_sum range downstream —
    # rejected here, before they can corrupt the merged granularity
    with pytest.raises(ValueError, match="v_max"):
        h.update(np.full((2, 4), -1, np.int32), np.zeros((2,), np.int32))
    with pytest.raises(ValueError, match="n_dec"):
        h.update(np.zeros((2, 4), np.int32), np.full((2,), -1, np.int32))
    # empty batch is identity on the granularity
    before = h.fingerprint
    h.update(np.zeros((0, 4), np.int32), np.zeros((0,), np.int32))
    assert h.fingerprint == before


# ---------------------------------------------------------------------------
# repair: validate (fold) → trim → resume
# ---------------------------------------------------------------------------


def test_valid_prefix_len():
    # every fold improves, target unreached → keep all
    assert valid_prefix_len([0.5, 0.3, 0.1], theta_full=0.0) == 3
    # third fold no longer improves beyond tie_tol → trim it and the tail
    assert valid_prefix_len([0.5, 0.3, 0.3, 0.1], theta_full=0.0) == 2
    # stopping target reached mid-prefix → later attributes are redundant
    assert valid_prefix_len([0.5, 0.3, 0.1], theta_full=0.3) == 2
    assert valid_prefix_len([], theta_full=0.0) == 0


def test_repair_is_noop_on_unchanged_data():
    """Full prefix valid + target reached → the probe IS the result: zero
    greedy iterations, byte-identical Θ history."""
    rng = np.random.default_rng(3)
    x, d = _table(rng, 250, 8)
    cold = plar_reduce(x, d, delta="SCE")
    gran = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3)
    r, kept = repair_reduce(gran, cold.reduct, delta="SCE")
    assert kept == len(cold.reduct)
    assert r.reduct == cold.reduct
    assert r.theta_history == cold.theta_history
    assert r.iterations == 0


def test_repair_trims_redundant_prefix():
    """A prefix attribute that no longer improves Θ (a copy of an earlier
    one) is dropped, and the resumed greedy never re-selects it."""
    rng = np.random.default_rng(4)
    x, d = _table(rng, 250, 8, redundancy=0.0)
    x[:, 3] = x[:, 2]  # attr 3 is redundant once 2 is selected
    gran = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3)
    r, kept = repair_reduce(gran, [2, 3], delta="SCE")
    assert kept == 1
    assert r.reduct[0] == 2 and 3 not in r.reduct


@pytest.mark.parametrize("delta", DELTAS)
def test_handle_reduce_warm_matches_cold(delta):
    """After an update, the warm repair and a cold run on the same handle
    agree.  Prefix stability is a property of the data, not a theorem —
    near-ties can legitimately reorder greedy picks — so this uses a paper
    stand-in whose attribute significances are well separated (the regime
    the service targets; see DESIGN.md §3.7 repair semantics)."""
    stream = scaled_paper_dataset("breast-cancer-wisconsin", max_rows=683,
                                  max_attrs=9)
    x, d = stream.table()
    h = DatasetHandle.create(x[:500], d[:500], n_dec=stream.n_dec,
                             v_max=stream.v_max)
    h.reduce(delta)
    h.update(x[500:], d[500:])
    warm = h.reduce(delta)
    assert h.last_was_warm
    cold = h.reduce(delta, warm=False)
    assert warm.reduct == cold.reduct
    assert warm.theta_history == cold.theta_history


# ---------------------------------------------------------------------------
# end-to-end acceptance: stream a paper dataset through the server
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("delta", DELTAS)
def test_service_streaming_matches_batch(delta):
    """First half creates the dataset, second half streams in 4 update
    batches; the final reduct equals batch ``plar_reduce`` on the full
    table — for all four measures."""
    stream = scaled_paper_dataset("shuttle", max_rows=4000, max_attrs=9)
    x, d = stream.table()
    half = len(x) // 2
    rest = len(x) - half

    async def drive():
        async with ReductServer() as srv:
            await srv.submit("s", x[:half], d[:half],
                             n_dec=stream.n_dec, v_max=stream.v_max)
            r = await srv.query("s", delta=delta)
            for i in range(4):
                lo = half + i * rest // 4
                hi = half + (i + 1) * rest // 4
                await srv.update("s", x[lo:hi], d[lo:hi])
                r = await srv.query("s", delta=delta)
            return r, srv.stats.copy(), list(srv.requests)

    r, stats, reqs = asyncio.run(drive())
    full = plar_reduce(x, d, delta=delta, n_dec=stream.n_dec,
                       v_max=stream.v_max)
    assert r.reduct == full.reduct
    # equal reducts over equal content (same live granules, same pow2
    # capacity) fold the same sequence → byte-identical Θ histories
    assert r.theta_history == full.theta_history
    assert stats["cold"] == 1 and stats["warm"] == 4
    assert stats["merges"] == 4
    assert all(q.warm for q in reqs[1:])


def test_server_coalesces_pending_updates():
    """k buffered update batches drain as ONE merge at the next query."""
    rng = np.random.default_rng(6)
    x, d = _table(rng, 400, 6)

    async def drive():
        async with ReductServer() as srv:
            await srv.submit("c", x[:100], d[:100], n_dec=2, v_max=3)
            await srv.query("c", delta="SCE")
            for lo in (100, 200, 300):
                await srv.update("c", x[lo:lo + 100], d[lo:lo + 100])
            r = await srv.query("c", delta="SCE")
            return r, srv.stats.copy(), srv.handle("c")

    r, stats, handle = asyncio.run(drive())
    assert stats["updates"] == 3
    assert stats["merges"] == 1              # coalesced into one fold
    assert stats["coalesced_batches"] == 3
    assert handle.n_updates == 1             # the handle saw one batch
    assert handle.rows_absorbed == 400
    # the coalesced merge is exact: same reduct as batch over all rows
    full = plar_reduce(x, d, delta="SCE", n_dec=2, v_max=3)
    assert r.reduct == full.reduct


def test_server_result_cache_and_param_keys():
    """Repeat query on unchanged content is a cache hit; params and content
    changes both miss."""
    rng = np.random.default_rng(7)
    x, d = _table(rng, 300, 6)

    async def drive():
        async with ReductServer() as srv:
            await srv.submit("k", x[:200], d[:200], n_dec=2, v_max=3)
            r1 = await srv.query("k", delta="SCE")
            r2 = await srv.query("k", delta="SCE")          # hit
            r3 = await srv.query("k", delta="SCE", max_features=1)  # params miss
            await srv.update("k", x[200:], d[200:])
            r4 = await srv.query("k", delta="SCE")          # content miss
            return (r1, r2, r3, r4), srv.stats.copy(), list(srv.requests)

    (r1, r2, r3, r4), stats, reqs = asyncio.run(drive())
    assert stats["queries"] == 4 and stats["cache_hits"] == 1
    assert reqs[1].cached and r2 is r1
    assert not reqs[2].cached and r3.reduct != r1.reduct
    assert not reqs[3].cached


def test_server_validation_and_lifecycle():
    rng = np.random.default_rng(8)
    x, d = _table(rng, 100, 4)

    async def drive():
        async with ReductServer() as srv:
            await srv.submit("v", x, d, n_dec=2, v_max=3)
            with pytest.raises(ValueError, match="already exists"):
                await srv.submit("v", x, d, n_dec=2, v_max=3)
            with pytest.raises(KeyError, match="unknown dataset"):
                await srv.query("nope")
            with pytest.raises(KeyError, match="unknown dataset"):
                await srv.update("nope", x, d)
            with pytest.raises(ValueError, match="rows"):
                await srv.update("v", x, d[:-1])
            # errors inside the worker propagate to the awaiting caller
            with pytest.raises(ValueError, match="unknown mode"):
                await srv.query("v", delta="SCE", mode="sprak")
            return await srv.query("v", delta="SCE")

    r = asyncio.run(drive())
    assert r.reduct  # server still serves after a failed request

    async def no_start():
        srv = ReductServer()
        await srv.submit("w", x, d, n_dec=2, v_max=3)  # no queue needed
        with pytest.raises(RuntimeError, match="not started"):
            await srv.query("w")

    asyncio.run(no_start())


def test_server_concurrent_submit_same_name():
    """Concurrent same-name submits: exactly one wins, the other gets the
    documented ValueError (the name is reserved before the build awaits)."""
    rng = np.random.default_rng(9)
    x, d = _table(rng, 120, 4)

    async def drive():
        async with ReductServer() as srv:
            results = await asyncio.gather(
                srv.submit("dup", x[:60], d[:60], n_dec=2, v_max=3),
                srv.submit("dup", x[60:], d[60:], n_dec=2, v_max=3),
                return_exceptions=True)
            errors = [r for r in results if isinstance(r, BaseException)]
            assert len(errors) == 1 and isinstance(errors[0], ValueError)
            assert srv.handle("dup") is not None
            return await srv.query("dup", delta="SCE")

    assert asyncio.run(drive()).reduct is not None
