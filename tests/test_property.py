"""Property-test hardening pass (ISSUE 6 satellite): algebraic laws the
drivers rely on, checked over randomized inputs.

* ``merge_granularity`` is a monoid up to padding: associative, commutative,
  and any chunking of a table folds to the monolithic build (the §3.6
  streaming-ingestion correctness argument).
* ``DatasetHandle`` fingerprints are a pure function of content: invariant
  to row order and to how rows are split across create/update batches.

Each law lives in a plain checker function driven twice: by a deterministic
pinned test (runs on bare envs) and by a hypothesis ``@given`` test (skips
without hypothesis — see ``_hyp.py``), so the invariants are always
exercised and CI additionally explores the input space.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _hyp import given, settings, st  # optional-hypothesis shim: property tests skip on bare envs

from repro.core import build_granularity, fold_chunk, merge_granularity
from repro.service import DatasetHandle, granularity_fingerprint


@pytest.fixture(scope="module", autouse=True)
def _free_compile_state():
    """Randomized shapes compile one executable per distinct (n, a) — drop
    them when the module finishes so long full-suite runs don't accumulate
    compile state (see test_ensemble.py's twin fixture)."""
    yield
    import jax

    jax.clear_caches()


def _table(rng, n, a, vmax, m):
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    d = rng.integers(0, m, size=(n,)).astype(np.int32)
    return x, d


def _assert_same_content(ga, gb):
    """Equal up to padding: same live prefix (the merge emits it globally
    sorted, so prefix equality is well-defined) and same fingerprint."""
    na, nb = int(ga.num), int(gb.num)
    assert na == nb
    assert int(ga.n_total) == int(gb.n_total)
    np.testing.assert_array_equal(np.asarray(ga.x)[:na], np.asarray(gb.x)[:na])
    np.testing.assert_array_equal(np.asarray(ga.d)[:na], np.asarray(gb.d)[:na])
    np.testing.assert_array_equal(np.asarray(ga.w)[:na], np.asarray(gb.w)[:na])
    assert granularity_fingerprint(ga) == granularity_fingerprint(gb)


# ---------------------------------------------------------------------------
# merge_granularity is a monoid (up to padding)
# ---------------------------------------------------------------------------


def _check_merge_monoid(n, a, vmax, m, cut1, cut2, seed):
    rng = np.random.default_rng(seed)
    x, d = _table(rng, n, a, vmax, m)
    i, j = sorted((cut1 % (n + 1), cut2 % (n + 1)))
    parts = [(x[:i], d[:i]), (x[i:j], d[i:j]), (x[j:], d[j:])]
    kw = dict(n_dec=m, v_max=vmax)
    mono = build_granularity(jnp.asarray(x), jnp.asarray(d), **kw)

    # any chunking folds to the monolithic build (empty chunks included:
    # fold_chunk skips them, the identity element of the fold)
    acc = None
    for xc, dc in parts:
        acc = fold_chunk(acc, jnp.asarray(xc), jnp.asarray(dc), **kw)
    _assert_same_content(acc, mono)

    gs = [build_granularity(jnp.asarray(xc), jnp.asarray(dc), **kw)
          for xc, dc in parts if len(xc)]
    if len(gs) == 3:
        g1, g2, g3 = gs
        left = merge_granularity(merge_granularity(g1, g2), g3)
        right = merge_granularity(g1, merge_granularity(g2, g3))
        _assert_same_content(left, right)           # associativity
        _assert_same_content(left, mono)
    if len(gs) >= 2:
        _assert_same_content(merge_granularity(gs[0], gs[1]),
                             merge_granularity(gs[1], gs[0]))  # commutativity


@pytest.mark.parametrize("n,cut1,cut2,seed", [
    (120, 40, 80, 0),
    (97, 0, 97, 1),      # degenerate cuts: empty first and last chunk
    (50, 13, 13, 2),     # empty middle chunk
    (3, 1, 2, 3),        # single-row chunks
])
def test_merge_monoid_pinned(n, cut1, cut2, seed):
    _check_merge_monoid(n, 5, 4, 3, cut1, cut2, seed)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 150),
    a=st.integers(1, 6),
    vmax=st.integers(1, 5),
    m=st.integers(1, 3),
    cut1=st.integers(0, 150),
    cut2=st.integers(0, 150),
    seed=st.integers(0, 2**16),
)
def test_merge_monoid_property(n, a, vmax, m, cut1, cut2, seed):
    _check_merge_monoid(n, a, vmax, m, cut1, cut2, seed)


# ---------------------------------------------------------------------------
# DatasetHandle fingerprint: pure function of content
# ---------------------------------------------------------------------------


def _check_fingerprint_invariance(n, a, vmax, m, cut_a, cut_b, seed):
    rng = np.random.default_rng(seed)
    x, d = _table(rng, n, a, vmax, m)
    perm = rng.permutation(n)
    i = 1 + cut_a % (n - 1) if n > 1 else 1
    j = 1 + cut_b % (n - 1) if n > 1 else 1

    def handle(xs, ds, cut):
        h = DatasetHandle.create(xs[:cut], ds[:cut], n_dec=m, v_max=vmax)
        if cut < len(xs):
            h.update(xs[cut:], ds[cut:])
        return h

    h1 = handle(x, d, i)
    h2 = handle(x[perm], d[perm], j)    # permuted rows, different batching
    assert h1.fingerprint == h2.fingerprint
    assert h1.n_granules == h2.n_granules

    # sensitivity: dropping a row (when that changes the content multiset)
    # must change the fingerprint
    if n > 1:
        h3 = handle(x[:-1], d[:-1], min(i, n - 1))
        same_content = any(
            np.array_equal(x[k], x[-1]) and d[k] == d[-1]
            for k in range(n - 1))
        if not same_content:
            assert h1.fingerprint != h3.fingerprint


@pytest.mark.parametrize("n,cut_a,cut_b,seed", [
    (200, 100, 37, 0),
    (2, 1, 1, 1),
    (64, 63, 1, 2),
])
def test_fingerprint_invariance_pinned(n, cut_a, cut_b, seed):
    _check_fingerprint_invariance(n, 5, 4, 3, cut_a, cut_b, seed)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 120),
    a=st.integers(1, 6),
    vmax=st.integers(1, 4),
    m=st.integers(1, 3),
    cut_a=st.integers(0, 120),
    cut_b=st.integers(0, 120),
    seed=st.integers(0, 2**16),
)
def test_fingerprint_invariance_property(n, a, vmax, m, cut_a, cut_b, seed):
    _check_fingerprint_invariance(n, a, vmax, m, cut_a, cut_b, seed)
