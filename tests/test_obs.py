"""Observability layer (DESIGN.md §3.11): flight-recorder tracing, the
metrics registry, and their contracts.

The acceptance surface: a disabled tracer costs *nothing* (singleton no-op
span, zero net allocations in the hot path); an enabled one records into a
bounded ring that exports valid Perfetto/Chrome-trace JSON; a fired fault
plan dumps the recorder next to the checkpoints; and the registry re-base
of ``ServiceMetrics`` keeps ``summary()`` byte-compatible with the plain
dict counters it replaced.
"""
import json
import threading
import tracemalloc

import pytest

from repro import obs
from repro.obs import trace as trace_mod
from repro.obs.registry import (
    Counter,
    CounterMap,
    Gauge,
    Histogram,
    MetricsRegistry,
    sanitize,
)
from repro.service import FaultPlan
from repro.service.metrics import RequestTiming, ServiceMetrics


@pytest.fixture(autouse=True)
def _clean_tracer_state():
    """Every test starts disabled with no dump dir and leaves no residue."""
    tracer = obs.get_tracer()
    was_enabled = tracer.enabled
    was_dir = trace_mod._dump_state["dir"]
    tracer.disable()
    yield
    tracer.clear()
    tracer.enabled = was_enabled
    obs.set_dump_dir(was_dir)


# ---------------------------------------------------------------------------
# tracer: spans, ring, threads
# ---------------------------------------------------------------------------

def test_span_nesting_records_inner_first():
    t = obs.Tracer(enabled=True)
    with t.span("scheduler.window", requests=2):
        with t.span("engine.dispatch", n_attrs=8) as sp:
            sp.set(k=3, compiled=True)
    recs = t.records()
    # inner span closes (and records) before the outer one
    assert [r.name for r in recs] == ["engine.dispatch", "scheduler.window"]
    inner, outer = recs
    assert inner.cat == "engine" and outer.cat == "scheduler"
    assert inner.args == {"n_attrs": 8, "k": 3, "compiled": True}
    assert outer.args == {"requests": 2}
    assert inner.ph == outer.ph == "X"
    assert inner.dur >= 0.0
    # nesting is by interval containment (how Perfetto reconstructs stacks)
    assert outer.t_start <= inner.t_start
    assert inner.t_start + inner.dur <= outer.t_start + outer.dur + 1e-9


def test_span_records_exception_and_propagates():
    t = obs.Tracer(enabled=True)
    with pytest.raises(ValueError):
        with t.span("checkpoint.write"):
            raise ValueError("disk on fire")
    (rec,) = t.records()
    assert rec.args["error"] == "ValueError"


def test_event_is_instant():
    t = obs.Tracer(enabled=True)
    t.event("scheduler.retry", site="dispatch", attempt=1)
    (rec,) = t.records()
    assert rec.ph == "i" and rec.dur == 0.0
    assert rec.cat == "scheduler"


def test_ring_is_bounded_keeps_newest():
    t = obs.Tracer(capacity=8, enabled=True)
    for i in range(20):
        t.event("x.e", i=i)
    assert len(t) == 8
    assert t.recorded == 20
    assert t.dropped == 12
    assert [r.args["i"] for r in t.records()] == list(range(12, 20))
    assert [r.args["i"] for r in t.records(last_n=3)] == [17, 18, 19]


def test_tracer_thread_safety():
    t = obs.Tracer(capacity=100_000, enabled=True)
    n_threads, per = 8, 500
    gate = threading.Barrier(n_threads)   # all alive at once → distinct tids

    def work():
        gate.wait()
        for _ in range(per):
            with t.span("pipeline.fold_chunk"):
                pass

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert t.recorded == n_threads * per
    assert len(t) == n_threads * per
    assert len({r.tid for r in t.records()}) == n_threads


def test_enable_resize_preserves_tail():
    t = obs.Tracer(capacity=16, enabled=True)
    for i in range(10):
        t.event("x.e", i=i)
    t.enable(capacity=4)
    assert t.capacity == 4
    assert [r.args["i"] for r in t.records()] == [6, 7, 8, 9]


# ---------------------------------------------------------------------------
# the zero-overhead-when-disabled contract
# ---------------------------------------------------------------------------

def test_disabled_span_is_the_singleton():
    assert not obs.get_tracer().enabled
    s = obs.span("engine.dispatch")
    assert s is obs.span("scheduler.window")
    assert s is trace_mod._NULL_SPAN
    # full live-span surface, still a no-op
    with s as inner:
        assert inner.set(k=1) is s
    assert obs.get_tracer().recorded == 0
    obs.event("x.y")        # also a no-op
    assert obs.get_tracer().recorded == 0


def test_disabled_span_allocates_nothing():
    # no-kwargs call sites (what the hot paths use) must not allocate:
    # the null span is a process singleton and event() returns early
    for _ in range(1000):            # warm-up: interned frames, caches
        with obs.span("bench.noop"):
            pass
        obs.event("bench.noop")
    tracemalloc.start()
    try:
        snap1 = tracemalloc.take_snapshot()
        for _ in range(10_000):
            with obs.span("bench.noop"):
                pass
            obs.event("bench.noop")
        snap2 = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    # nothing attributable to the tracing module may grow with the call
    # count: 10k disabled spans must leave only the O(1) snapshot-time
    # residue (the last call's transient **kwargs dict on a free list),
    # never per-call retained objects
    leaks = [s for s in snap2.compare_to(snap1, "filename")
             if s.traceback[0].filename == trace_mod.__file__
             and s.size_diff > 0]
    assert sum(s.count_diff for s in leaks) <= 8, leaks
    assert sum(s.size_diff for s in leaks) < 1024, leaks


# ---------------------------------------------------------------------------
# Perfetto export + dump-on-failure
# ---------------------------------------------------------------------------

def test_export_writes_valid_chrome_trace(tmp_path):
    import numpy as np

    t = obs.Tracer(enabled=True)
    with t.span("engine.dispatch", n_attrs=np.int64(16), tiles=(8, 128)):
        pass
    t.event("faults.fired", kind="dispatch")
    out = t.export(str(tmp_path / "trace.json"), meta={"run": "unit"})
    assert out == str(tmp_path / "trace.json")
    with open(out) as f:
        doc = json.load(f)
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["recorded"] == 2
    assert doc["otherData"]["dropped"] == 0
    assert doc["otherData"]["run"] == "unit"
    span_ev, inst_ev = doc["traceEvents"]
    assert span_ev["ph"] == "X" and "dur" in span_ev
    assert inst_ev["ph"] == "i" and inst_ev["s"] == "t"
    for ev in doc["traceEvents"]:
        assert {"name", "cat", "ph", "ts", "pid", "tid"} <= set(ev)
    # numpy scalar collapsed via item(), tuple went through repr()
    assert span_ev["args"]["n_attrs"] == 16
    assert span_ev["args"]["tiles"] == "(8, 128)"


def test_request_dump_noop_unless_armed(tmp_path):
    assert obs.request_dump("why") is None          # no dir, disabled
    obs.set_dump_dir(str(tmp_path))
    assert obs.request_dump("why") is None          # dir set, still disabled
    obs.enable()
    obs.event("x.y")
    path = obs.request_dump("why not/here", meta={"step": 3})
    assert path is not None
    with open(path) as f:
        doc = json.load(f)
    assert doc["otherData"]["reason"] == "why not/here"
    assert doc["otherData"]["step"] == 3
    assert "/" not in path.rsplit("flightrec-", 1)[1]   # reason sanitized


def test_fault_plan_firing_dumps_flight_recorder(tmp_path):
    obs.enable()
    obs.set_dump_dir(str(tmp_path))
    plan = FaultPlan.parse("dispatch@1")
    assert plan.fire("dispatch") is None            # step 0: nothing fires
    assert not list(tmp_path.glob("flightrec-*.json"))
    spec = plan.fire("dispatch")                    # step 1: scheduled fault
    assert spec is not None and spec.transient
    dumps = list(tmp_path.glob("flightrec-*.json"))
    assert len(dumps) == 1
    doc = json.loads(dumps[0].read_text())
    assert doc["otherData"]["kind"] == "dispatch"
    assert doc["otherData"]["step"] == 1
    # the firing itself is on the recorded timeline
    assert any(ev["name"] == "faults.fired" for ev in doc["traceEvents"])


def test_dump_gc_keeps_newest(tmp_path):
    obs.enable()
    obs.set_dump_dir(str(tmp_path))
    paths = [obs.request_dump("storm") for _ in range(trace_mod._MAX_DUMPS + 5)]
    assert all(p is not None for p in paths)
    left = sorted(f.name for f in tmp_path.glob("flightrec-*.json"))
    assert len(left) == trace_mod._MAX_DUMPS
    assert left[-1] == paths[-1].rsplit("/", 1)[1]  # newest survived


# ---------------------------------------------------------------------------
# registry: instruments + exposition
# ---------------------------------------------------------------------------

def test_counter_is_monotonic():
    reg = MetricsRegistry()
    c = reg.counter("plar_x_total", "help text")
    c.inc()
    c.inc(4)
    assert c.value == 5
    c.set(7)
    assert c.value == 7
    with pytest.raises(ValueError):
        c.inc(-1)
    with pytest.raises(ValueError):
        c.set(3)
    assert reg.counter("plar_x_total") is c         # get-or-create


def test_gauge_and_histogram():
    reg = MetricsRegistry()
    g = reg.gauge("plar_last_k")
    g.set(12)
    g.inc(-2)
    assert g.value == 10
    h = reg.histogram("plar_lat_seconds", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count == 4 and h.sum == pytest.approx(5.555)
    cum = h.cumulative()
    assert cum == [("0.01", 1), ("0.1", 2), ("1", 3), ("+Inf", 4)]
    snap = reg.snapshot()
    assert snap["plar_last_k"] == 10
    assert snap["plar_lat_seconds_count"] == 4
    assert snap["plar_lat_seconds_sum"] == pytest.approx(5.555)


def test_registry_kind_clash_raises():
    reg = MetricsRegistry()
    reg.counter("plar_thing")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("plar_thing")


def test_sanitize_names():
    assert sanitize("plar_ok_total") == "plar_ok_total"
    assert sanitize("bad name-1") == "bad_name_1"
    assert sanitize("0starts_bad") == "_0starts_bad"


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("plar_runs_total", "engine runs").inc(3)
    reg.gauge("plar_k").set(4)
    h = reg.histogram("plar_s", buckets=(0.5, 1.0))
    h.observe(0.25)
    h.observe(2.0)
    text = reg.render_prometheus()
    lines = text.splitlines()
    assert "# HELP plar_runs_total engine runs" in lines
    assert "# TYPE plar_runs_total counter" in lines
    assert "plar_runs_total 3" in lines
    assert "# TYPE plar_k gauge" in lines
    assert "plar_k 4" in lines
    assert "# TYPE plar_s histogram" in lines
    assert 'plar_s_bucket{le="0.5"} 1' in lines
    assert 'plar_s_bucket{le="1"} 1' in lines
    assert 'plar_s_bucket{le="+Inf"} 2' in lines
    assert "plar_s_sum 2.25" in lines
    assert "plar_s_count 2" in lines
    assert text.endswith("\n")
    # the merged view reduce_server --metrics-port serves
    merged = obs.render_prometheus(extra=[reg])
    assert "plar_runs_total 3" in merged.splitlines()


def test_counter_map_keeps_dict_semantics():
    reg = MetricsRegistry()
    m = CounterMap(reg, prefix="plar_srv_", initial=("queries", "merges"))
    assert dict(m) == {"queries": 0, "merges": 0}    # insertion-ordered
    m["queries"] += 1
    m["queries"] += 2
    assert m["queries"] == 3
    assert m.get("queries") == 3
    assert m.get("never", 0) == 0
    assert "never" not in m                          # .get did not register
    m["late"] += 1                                   # defaultdict(int) read
    assert list(m) == ["queries", "merges", "late"]
    assert len(m) == 3
    snap = m.copy()                                  # dict.copy() surface
    assert snap == {"queries": 3, "merges": 0, "late": 1}
    assert isinstance(snap, dict)
    with pytest.raises(TypeError):
        del m["queries"]
    with pytest.raises(ValueError):
        m["queries"] = 1                             # counters can't decrease
    # the same bumps are visible on the registry under the prefix
    assert reg.snapshot()["plar_srv_queries"] == 3


# ---------------------------------------------------------------------------
# ServiceMetrics re-base: summary() byte-compatibility
# ---------------------------------------------------------------------------

def test_service_metrics_summary_byte_compat():
    m = ServiceMetrics()
    for wait, total in ((0.001, 0.004), (0.002, 0.01)):
        t = RequestTiming(t_enqueue=0.0, t_start=wait, t_done=total)
        m.observe(t)
    m.observe_dispatch(3)
    m.inc("dedup_hits")
    m.inc("engine_runs", 2)                          # a caller-added counter
    s = m.summary()
    assert list(s) == [
        "completed", "engine_dispatches", "batched_queries", "dedup_hits",
        "rejected", "qps_sustained", "mean_batch_occupancy",
        "queue_wait_p50_s", "queue_wait_p99_s", "latency_p50_s",
        "latency_p99_s", "engine_runs",
    ]
    assert s["completed"] == 2
    assert s["engine_dispatches"] == 1
    assert s["batched_queries"] == 3
    assert s["dedup_hits"] == 1
    assert s["rejected"] == 0
    assert s["mean_batch_occupancy"] == 3.0
    assert s["latency_p50_s"] == pytest.approx(0.007)
    assert s["engine_runs"] == 2
    # the registry view carries the identical numbers
    snap = m.registry.snapshot()
    assert snap["plar_service_completed"] == 2
    assert snap["plar_service_latency_seconds_count"] == 2
    assert snap["plar_service_last_batch_occupancy"] == 3
