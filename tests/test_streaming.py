"""Streaming GrC ingestion (DESIGN.md §3.6): monoid merge + bit-exact parity.

The contract under test: the decision table never has to exist whole —
granulating row chunks and folding them through ``merge_granularity`` gives
the *same* granularity (live prefix element-wise, any chunk size), the same
capacity after the pow2 shrink, and therefore byte-identical reducts and
Θ histories out of every driver.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    build_granularity,
    build_granularity_streaming,
    fold_chunk,
    merge_granularity,
    plar_reduce,
    fspa_reduce,
    resolve_granularity,
    with_capacity,
)
from repro.data import GranuleSource, TabularStream, paper_dataset, scaled_paper_dataset

DELTAS = ["PR", "SCE", "LCE", "CCE"]


def _live(g):
    num = int(g.num)
    return (np.asarray(g.x)[:num], np.asarray(g.d)[:num], np.asarray(g.w)[:num])


def _assert_same_granularity(a, b):
    """Equal live prefixes (the 'modulo padding' equivalence)."""
    assert int(a.num) == int(b.num)
    assert int(a.n_total) == int(b.n_total)
    for ga, gb in zip(_live(a), _live(b)):
        np.testing.assert_array_equal(ga, gb)


def _chunk_grans(x, d, sizes, v_max, n_dec):
    out = []
    lo = 0
    for s in sizes:
        out.append(build_granularity(
            jnp.asarray(x[lo:lo + s]), jnp.asarray(d[lo:lo + s]),
            n_dec=n_dec, v_max=v_max))
        lo += s
    assert lo == len(x)
    return out


def test_merge_monoid_associativity():
    """(a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) == monolithic, up to padding."""
    rng = np.random.default_rng(0)
    x = rng.integers(0, 4, size=(300, 5)).astype(np.int32)
    d = rng.integers(0, 3, size=(300,)).astype(np.int32)
    a, b, c = _chunk_grans(x, d, [120, 97, 83], v_max=4, n_dec=3)
    left = merge_granularity(merge_granularity(a, b), c)
    right = merge_granularity(a, merge_granularity(b, c))
    mono = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=3, v_max=4)
    _assert_same_granularity(left, right)
    _assert_same_granularity(left, mono)
    # commutativity rides along: the merged sort order ignores operand order
    _assert_same_granularity(merge_granularity(c, a), merge_granularity(a, c))


def test_merge_rejects_mismatched_metadata():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 3, size=(50, 4)).astype(np.int32)
    d = rng.integers(0, 2, size=(50,)).astype(np.int32)
    a = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3)
    b = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=4)
    with pytest.raises(ValueError, match="metadata"):
        merge_granularity(a, b)


@pytest.mark.parametrize("chunk_rows", [7, 64, 4096])
def test_streaming_build_chunk_size_invariant(chunk_rows):
    """Any chunking → identical Granularity modulo padding (and identical
    live *order*: the final merge re-sorts the full distinct-key set)."""
    t = TabularStream(n_rows=5000, n_attrs=10, v_max=4, n_dec=3,
                      distinct_fraction=0.1, seed=3)
    x, d = t.table()
    mono = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=3, v_max=4)
    stream = build_granularity_streaming(t.chunks(chunk_rows), n_dec=3, v_max=4)
    _assert_same_granularity(stream, mono)


def test_capacity_doubling_growth():
    """Merging two full-to-capacity disjoint tables doubles the capacity;
    a fold over all-distinct rows keeps doubling as the live set grows."""
    x = np.arange(128, dtype=np.int32).reshape(128, 1) % 127
    x = np.stack([np.arange(128, dtype=np.int32), x[:, 0]], axis=1)
    d = np.zeros((128,), np.int32)
    a = build_granularity(jnp.asarray(x[:64]), jnp.asarray(d[:64]), n_dec=1, v_max=128)
    b = build_granularity(jnp.asarray(x[64:]), jnp.asarray(d[64:]), n_dec=1, v_max=128)
    assert a.capacity == b.capacity == 64
    m = merge_granularity(a, b)
    assert m.capacity == 128 and int(m.num) == 128

    # streaming fold over fully-distinct rows: capacity tracks next_pow2(seen)
    t = TabularStream(n_rows=1000, n_attrs=6, v_max=8, n_dec=2,
                      distinct_fraction=1.0, redundancy=0.0, seed=9)
    g = build_granularity_streaming(t.chunks(16), n_dec=2, v_max=8)
    assert g.capacity >= int(g.num)
    assert g.capacity <= 2 * int(g.num)  # pow2 policy: never more than 2× live


def test_fold_empty_chunk_is_identity():
    """An empty row chunk folds to the accumulator itself — the monoid
    identity — and an all-empty stream raises instead of returning nothing."""
    rng = np.random.default_rng(12)
    x = rng.integers(0, 3, size=(80, 4)).astype(np.int32)
    d = rng.integers(0, 2, size=(80,)).astype(np.int32)
    g = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3)
    empty_x = np.zeros((0, 4), np.int32)
    empty_d = np.zeros((0,), np.int32)
    assert fold_chunk(g, empty_x, empty_d, n_dec=2, v_max=3) is g
    assert fold_chunk(None, empty_x, empty_d, n_dec=2, v_max=3) is None
    # empty chunks interleaved in a stream do not disturb the fold
    chunks = [(x[:40], d[:40]), (empty_x, empty_d), (x[40:], d[40:])]
    _assert_same_granularity(
        build_granularity_streaming(iter(chunks), n_dec=2, v_max=3), g)
    with pytest.raises(ValueError, match="no non-empty chunks"):
        build_granularity_streaming(iter([(empty_x, empty_d)]), n_dec=2,
                                    v_max=3)


def test_merge_with_self_doubles_weights():
    """g ⊕ g: same granules (count and representatives), doubled
    multiplicities and |U| — weights merge additively, keys set-merge."""
    rng = np.random.default_rng(13)
    x = rng.integers(0, 4, size=(200, 5)).astype(np.int32)
    d = rng.integers(0, 3, size=(200,)).astype(np.int32)
    g = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=3, v_max=4)
    m = merge_granularity(g, g)
    num = int(g.num)
    assert int(m.num) == num                      # granule count preserved
    assert int(m.n_total) == 2 * int(g.n_total)
    np.testing.assert_array_equal(np.asarray(m.x)[:num], np.asarray(g.x)[:num])
    np.testing.assert_array_equal(np.asarray(m.d)[:num], np.asarray(g.d)[:num])
    np.testing.assert_array_equal(np.asarray(m.w)[:num],
                                  2 * np.asarray(g.w)[:num])
    assert int(np.asarray(m.w)[num:].sum()) == 0  # padding stays zero-weight


def test_with_capacity_guard():
    rng = np.random.default_rng(4)
    x = rng.integers(0, 3, size=(100, 4)).astype(np.int32)
    d = rng.integers(0, 2, size=(100,)).astype(np.int32)
    g = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3)
    grown = with_capacity(g, 256)
    assert grown.capacity == 256 and int(grown.w[int(g.num):].sum()) == 0
    _assert_same_granularity(grown, g)
    with pytest.raises(ValueError, match="capacity"):
        with_capacity(g, int(g.num) // 2)


# The acceptance matrix: ≥4 paper datasets × 4 measures, chunk_rows=4096,
# byte-identical reduct / core / Θ history between source= and (x, d).
PARITY_DATASETS = ["mushroom", "shuttle", "kdd99", "weka15360"]


@pytest.mark.parametrize("delta", DELTAS)
@pytest.mark.parametrize("name", PARITY_DATASETS)
def test_streaming_reduction_bit_parity(name, delta):
    t = scaled_paper_dataset(name, max_rows=6000, max_attrs=16)
    assert t.n_rows > 4096  # ≥2 chunks, or the test proves nothing
    x, d = t.table()
    # pin n_dec/v_max to the stream's declared metadata: the array adapter
    # would otherwise infer them from realized data, and a seed where some
    # class never materializes would change n_bins and break byte parity
    mono = plar_reduce(x, d, delta=delta, n_dec=t.n_dec, v_max=t.v_max)
    stream = plar_reduce(source=t, chunk_rows=4096, delta=delta)
    assert stream.reduct == mono.reduct
    assert stream.core == mono.core
    assert stream.theta_full == mono.theta_full        # byte-identical f32
    assert stream.theta_history == mono.theta_history  # byte-identical f32


def test_prebuilt_granularity_source():
    t = scaled_paper_dataset("mushroom", max_rows=3000, max_attrs=12)
    x, d = t.table()
    g = build_granularity(jnp.asarray(x), jnp.asarray(d),
                          n_dec=t.n_dec, v_max=t.v_max)
    a = plar_reduce(x, d, delta="SCE")
    b = plar_reduce(source=g, delta="SCE")
    assert a.reduct == b.reduct and a.theta_history == b.theta_history


def test_source_materializes_for_raw_baselines():
    """grc_init=False (HAR/FSPA cost model) can't stream — the thin adapter
    materializes the chunks and the reduct matches the array path."""
    t = TabularStream(n_rows=900, n_attrs=6, v_max=3, n_dec=2,
                      distinct_fraction=0.3, seed=7)
    x, d = t.table()
    assert fspa_reduce(source=t, chunk_rows=128, delta="SCE").reduct == \
        fspa_reduce(x, d, delta="SCE").reduct


def test_resolve_granularity_validation():
    t = TabularStream(n_rows=100, n_attrs=4, seed=0)
    x, d = t.table()
    with pytest.raises(ValueError, match="not both"):
        resolve_granularity(x, d, source=t)
    with pytest.raises(ValueError, match="source="):
        resolve_granularity()
    with pytest.raises(TypeError, match="GranuleSource"):
        resolve_granularity(source=object())


def test_tabular_stream_is_granule_source():
    t = TabularStream(n_rows=100, n_attrs=4, seed=0)
    assert isinstance(t, GranuleSource)  # runtime attr/method check


def test_tabular_chunks_partition_table():
    """chunk(step) is pure in (seed, step) and chunk-size invariant."""
    t = TabularStream(n_rows=2500, n_attrs=5, distinct_fraction=0.2, seed=11)
    x, d = t.table()
    for cr in (7, 100, 4096):
        xs, ds = zip(*t.chunks(cr))
        np.testing.assert_array_equal(np.concatenate(xs), x)
        np.testing.assert_array_equal(np.concatenate(ds), d)
    x0a, _ = t.chunk(2, 100)
    x0b, _ = t.chunk(2, 100)
    np.testing.assert_array_equal(x0a, x0b)
    with pytest.raises(IndexError):
        t.chunk(t.n_chunks(100), 100)


def test_tabular_shard_partitions_chunk():
    """TokenStream's elastic contract, closed for TabularStream."""
    t = TabularStream(n_rows=2000, n_attrs=5, distinct_fraction=0.5, seed=13)
    full_x, full_d = t.chunk(0, 1024)
    for n_shards in (2, 3, 8):
        xs, ds = zip(*(t.shard(0, i, n_shards, 1024) for i in range(n_shards)))
        np.testing.assert_array_equal(np.concatenate(xs), full_x)
        np.testing.assert_array_equal(np.concatenate(ds), full_d)


def test_paper_dataset_unknown_name_lists_valid():
    with pytest.raises(ValueError, match="kdd99"):
        paper_dataset("no-such-dataset")
    with pytest.raises(ValueError, match="mushroom"):
        scaled_paper_dataset("also-missing")
