"""Device-resident selection engine (core/engine.py) vs the legacy host loop.

The engine's contract is *bit-identical* results: same reduct, same core,
same theta_history floats as ``engine="host"`` — across all four measures,
with shrink, with max_features, without core computation, and in spark mode.
Plus the perf contract: the whole greedy loop is ONE jitted while_loop (a
single trace/compile, no per-iteration recompiles or host transfers).

The distributed twin (1×1 mesh == single process; multi-device parity lives
in test_distributed.py's subprocess tests).
"""
import numpy as np
import pytest

from repro.core import fspa_reduce, har_reduce, plar_reduce
from repro.core.engine import make_engine_run
from repro.core.oracle import reduct_oracle

DELTAS = ["PR", "SCE", "LCE", "CCE"]


def _table(rng, n, a, vmax=3, m=2, redundancy=0.5):
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    for j in range(a):
        if rng.random() < redundancy and j > 0:
            x[:, j] = x[:, rng.integers(0, j)]
    d = rng.integers(0, m, size=(n,)).astype(np.int32)
    return x, d


def _assert_same(rh, rd):
    assert rh.reduct == rd.reduct
    assert rh.core == rd.core
    assert rh.theta_history == rd.theta_history  # bit-identical floats
    assert rh.iterations == rd.iterations
    # the device engine evaluates all A candidates per iteration (masked
    # argmin), the host loop only the shrinking remaining set
    assert rd.n_evaluations >= rh.n_evaluations


@pytest.mark.parametrize("delta", DELTAS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_engine_parity_all_measures(delta, seed):
    rng = np.random.default_rng(seed)
    x, d = _table(rng, 180, 8)
    rh = plar_reduce(x, d, delta=delta, engine="host")
    rd = plar_reduce(x, d, delta=delta, engine="device")
    _assert_same(rh, rd)
    assert rd.reduct == reduct_oracle(delta, x, d)


@pytest.mark.parametrize("delta", DELTAS)
def test_engine_parity_shrink(delta):
    """FSPA shrinking folds into SelectionState (active mask + PR scalar)."""
    rng = np.random.default_rng(7)
    x, d = _table(rng, 200, 8)
    rh = plar_reduce(x, d, delta=delta, shrink=True, engine="host")
    rd = plar_reduce(x, d, delta=delta, shrink=True, engine="device")
    _assert_same(rh, rd)


def test_engine_parity_max_features_and_no_core():
    rng = np.random.default_rng(11)
    x, d = _table(rng, 200, 10, redundancy=0.0)
    for kw in [dict(max_features=3, compute_core=False),
               dict(compute_core=False)]:
        rh = plar_reduce(x, d, delta="SCE", engine="host", **kw)
        rd = plar_reduce(x, d, delta="SCE", engine="device", **kw)
        _assert_same(rh, rd)
        if "max_features" in kw:
            assert len(rd.reduct) <= 3


def test_engine_parity_spark_mode_and_baselines():
    """HAR/FSPA (mode='spark', no GrC) run on the same engine step."""
    rng = np.random.default_rng(13)
    x, d = _table(rng, 150, 7)
    for reduce_fn in (har_reduce, fspa_reduce):
        rh = reduce_fn(x, d, delta="PR", engine="host")
        rd = reduce_fn(x, d, delta="PR", engine="device")
        _assert_same(rh, rd)


def test_engine_auto_resolution_and_validation():
    rng = np.random.default_rng(17)
    x, d = _table(rng, 80, 5)
    # auto == device for device-capable backends: identical results
    r_auto = plar_reduce(x, d, delta="SCE")
    r_dev = plar_reduce(x, d, delta="SCE", engine="device")
    _assert_same(r_auto, r_dev)
    with pytest.raises(ValueError, match="unknown engine"):
        plar_reduce(x, d, engine="gpu")
    with pytest.raises(ValueError, match="engine='device'"):
        plar_reduce(x, d, backend="pallas", engine="device")


def test_unknown_mode_and_backend_raise():
    """An unknown mode used to fall silently into the incremental branch."""
    rng = np.random.default_rng(19)
    x, d = _table(rng, 50, 4)
    with pytest.raises(ValueError, match="unknown mode.*incremental.*spark"):
        plar_reduce(x, d, mode="sprak")
    with pytest.raises(ValueError, match="unknown Θ backend.*segment"):
        plar_reduce(x, d, backend="sgement")


def test_engine_single_compile():
    """The whole greedy loop is ONE jit trace (the while_loop), and a second
    run on different same-shape data adds zero traces — the acceptance
    criterion "at most 2 XLA compilations (step + while_loop)"; the run
    needs just the one because the step body is inlined into the loop."""
    rng = np.random.default_rng(23)
    n, a, vmax, m = 160, 8, 3, 2
    x1, d1 = _table(rng, n, a, vmax=vmax, m=m)
    x2, d2 = _table(rng, n, a, vmax=vmax, m=m)
    # pin v_max/n_dec so both tables resolve to the same static config
    for x, d in ((x1, d1), (x2, d2)):
        x[0, :] = vmax - 1
        d[0] = m - 1
    # grc_init=False ⇒ capacity == n exactly, so the engine-cache key is known
    r1 = plar_reduce(x1, d1, delta="SCE", engine="device", grc_init=False)
    runner = make_engine_run(
        "SCE", "incremental", "segment", a, n, m, vmax, 1e-6, 1e-5, False, a,
        64)
    assert runner._cache_size() == 1          # one trace for the whole loop
    r2 = plar_reduce(x2, d2, delta="SCE", engine="device", grc_init=False)
    assert runner._cache_size() == 1          # warm rerun: zero new traces
    assert r1.reduct == reduct_oracle("SCE", x1, d1)
    assert r2.reduct == reduct_oracle("SCE", x2, d2)


def test_engine_step_matches_run_prefix():
    """make_engine_step (the exposed single-iteration entry point) drives the
    same body engine_run inlines: stepping it N times from a fresh state
    reproduces the full while_loop reduction exactly."""
    import jax.numpy as jnp

    from repro.core.engine import init_state, make_engine_step

    rng = np.random.default_rng(41)
    n, a, vmax, m = 120, 6, 3, 2
    x, d = _table(rng, n, a, vmax=vmax, m=m)
    x[0, :] = vmax - 1
    d[0] = m - 1
    r = plar_reduce(x, d, delta="SCE", engine="device", grc_init=False,
                    compute_core=False)
    step = make_engine_step(
        "SCE", "incremental", "segment", a, n, m, vmax, 1e-6, 1e-5, False, a,
        64)
    st = init_state(n, a, np.ones((n,), bool))
    xs, ds_ = jnp.asarray(x), jnp.asarray(d)
    ws = jnp.ones((n,), jnp.int32)
    no_core = jnp.zeros((a,), jnp.int32)
    for _ in range(r.iterations):
        st = step(st, xs, ds_, ws, jnp.int32(n), jnp.float32(r.theta_full),
                  no_core, jnp.int32(0))
    nsel = int(st.n_selected)
    assert [int(v) for v in np.asarray(st.order)[:nsel]] == r.reduct
    hist = [float(t) for t in np.asarray(st.theta_history)[:nsel]]
    assert hist == r.theta_history


@pytest.mark.parametrize("delta", ["PR", "LCE"])
def test_engine_distributed_1x1_mesh_matches_single_process(delta):
    """A 1×1 ('data','model') mesh engine == the single-process engine."""
    import jax

    from repro.core.distributed import plar_reduce_distributed
    from repro.distributed.api import make_mesh

    rng = np.random.default_rng(29)
    x, d = _table(rng, 250, 8)
    mesh = make_mesh((1, 1), ("data", "model"),
                     devices=np.array(jax.devices()[:1]))
    r_mesh = plar_reduce_distributed(x, d, mesh, delta=delta, engine="device")
    r_sp = plar_reduce(x, d, delta=delta, engine="device")
    assert r_mesh.reduct == r_sp.reduct
    assert r_mesh.core == r_sp.core
    # mesh capacity padding differs from the single-process pow2 shrink, so
    # float32 summation grouping may differ in the last ulp — values agree
    np.testing.assert_allclose(
        r_mesh.theta_history, r_sp.theta_history, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(
        r_mesh.theta_full, r_sp.theta_full, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("delta", DELTAS)
@pytest.mark.parametrize("engine", ["host", "device"])
def test_warm_start_parity(delta, engine):
    """For static data, ``plar_reduce(warm_start=prefix)`` with a prefix the
    cold run itself selected yields the same reduct and a byte-identical Θ
    history — prefix folds *and* the greedy tail — on both engines.

    Prefixes must cover the core (the cold run force-folds core attributes
    before greedy, so a shorter warm prefix is a different — legal but not
    comparable — trajectory)."""
    rng = np.random.default_rng(37)
    x, d = _table(rng, 180, 8)
    cold = plar_reduce(x, d, delta=delta, engine=engine)
    ks = sorted({len(cold.core),
                 (len(cold.core) + len(cold.reduct)) // 2,
                 len(cold.reduct)})
    for k in ks:
        warm = plar_reduce(x, d, delta=delta, engine=engine,
                           warm_start=cold.reduct[:k])
        assert warm.reduct == cold.reduct
        assert warm.theta_history == cold.theta_history  # byte-identical
        assert warm.core == []           # the prefix stands in for the core
        assert warm.iterations == len(cold.reduct) - k
        assert warm.theta_full == cold.theta_full


@pytest.mark.parametrize("engine", ["host", "device"])
def test_warm_start_parity_no_core(engine):
    """Without core computation every greedy prefix is resumable: state
    after folding ``reduct[:k]`` equals the cold run's state at step k."""
    rng = np.random.default_rng(43)
    x, d = _table(rng, 150, 7)
    cold = plar_reduce(x, d, delta="SCE", engine=engine, compute_core=False)
    for k in range(len(cold.reduct) + 1):
        warm = plar_reduce(x, d, delta="SCE", engine=engine,
                           compute_core=False, warm_start=cold.reduct[:k])
        assert warm.reduct == cold.reduct
        assert warm.theta_history == cold.theta_history


def test_warm_start_seed_resume_single_compile():
    """A warm run is seed + resume dispatches of the SAME compiled
    while_loop as the cold run (theta_full is a traced operand): zero new
    traces."""
    rng = np.random.default_rng(47)
    n, a, vmax, m = 160, 8, 3, 2
    x, d = _table(rng, n, a, vmax=vmax, m=m)
    x[0, :] = vmax - 1
    d[0] = m - 1
    cold = plar_reduce(x, d, delta="LCE", engine="device", grc_init=False)
    runner = make_engine_run(
        "LCE", "incremental", "segment", a, n, m, vmax, 1e-6, 1e-5, False, a,
        64)
    traces = runner._cache_size()
    assert traces == 1
    warm = plar_reduce(x, d, delta="LCE", engine="device", grc_init=False,
                       warm_start=cold.reduct[: len(cold.core) or None])
    assert warm.reduct == cold.reduct
    assert runner._cache_size() == traces  # seed + resume reused the trace


def test_warm_start_seed_state_carries_prefix():
    """init_state_from_reduct records the prefix fold-by-fold: order, Θ
    history, remaining mask — the validation signal the service trims on."""
    import jax.numpy as jnp

    from repro.core.engine import engine_resume, init_state_from_reduct

    rng = np.random.default_rng(53)
    n, a, vmax, m = 140, 6, 3, 2
    x, d = _table(rng, n, a, vmax=vmax, m=m)
    x[0, :] = vmax - 1
    d[0] = m - 1
    cold = plar_reduce(x, d, delta="SCE", engine="device", grc_init=False,
                       compute_core=False)
    k = max(len(cold.reduct) - 1, 1)
    runner = make_engine_run(
        "SCE", "incremental", "segment", a, n, m, vmax, 1e-6, 1e-5, False, a,
        64)
    xs, ds = jnp.asarray(x), jnp.asarray(d)
    ws = jnp.ones((n,), jnp.int32)
    valid = np.ones((n,), bool)
    st = init_state_from_reduct(runner, n, a, valid, xs, ds, ws,
                                jnp.int32(n), cold.reduct[:k])
    assert int(st.n_selected) == k
    assert [int(v) for v in np.asarray(st.order)[:k]] == cold.reduct[:k]
    assert [float(t) for t in np.asarray(st.theta_history)[:k]] \
        == cold.theta_history[:k]
    assert not any(np.asarray(st.remaining)[cold.reduct[:k]])
    fin = engine_resume(runner, st, xs, ds, ws, jnp.int32(n),
                        cold.theta_full)
    nsel = int(fin.n_selected)
    assert [int(v) for v in np.asarray(fin.order)[:nsel]] == cold.reduct


def test_warm_start_validation():
    rng = np.random.default_rng(59)
    x, d = _table(rng, 80, 5)
    with pytest.raises(ValueError, match="duplicates"):
        plar_reduce(x, d, warm_start=[1, 1])
    with pytest.raises(ValueError, match="out of range"):
        plar_reduce(x, d, warm_start=[0, 7])
    with pytest.raises(ValueError, match="out of range"):
        plar_reduce(x, d, warm_start=[-1])
    with pytest.raises(ValueError, match="integral"):
        plar_reduce(x, d, warm_start=[0.5])
    # a warm prefix folds unconditionally (like a forced core): a prefix
    # longer than max_features is legal, folds whole, and adds nothing —
    # warm repair from a core-overflowed result must stay expressible
    r = plar_reduce(x, d, warm_start=[0, 1, 2], max_features=2)
    assert r.reduct == [0, 1, 2]
    # boundary: prefix length == max_features is allowed (pure re-eval)
    r = plar_reduce(x, d, warm_start=[0, 1], max_features=2)
    assert r.reduct == [0, 1]


def test_engine_factory_cache_key():
    """One lru entry per logical config: positional, keyword, defaulted, and
    numpy-scalar-typed calls to the engine factories all key identically
    (redundant entries would mean redundant XLA compiles)."""
    from repro.core.engine import (
        _make_engine_run,
        _make_engine_step,
        make_engine_step,
    )

    for make, cached in ((make_engine_run, _make_engine_run),
                         (make_engine_step, _make_engine_step)):
        # a config no other test uses, so the first call is a genuine miss
        args = ("SCE", "incremental", "segment", 5, 32, 2, 3, 1e-6, 2e-5,
                False, 5)
        before = cached.cache_info().currsize
        f0 = make(*args)                                    # defaulted tail
        f1 = make(*args, 64, False)                         # positional tail
        f2 = make(*args, mp_chunk=64, ladder=False)         # keyword tail
        f3 = make("SCE", mode="incremental", backend="segment",
                  n_attrs=np.int32(5), cap=np.int64(32), m=np.int32(2),
                  v_max=np.int32(3), tol=np.float64(1e-6),
                  tie_tol=np.float64(2e-5), shrink=np.bool_(False),
                  max_sel=np.int32(5))                      # numpy scalars
        assert f0 is f1 is f2 is f3
        assert cached.cache_info().currsize == before + 1


def test_engine_distributed_fused_collective_requires_host():
    import jax

    from repro.core.distributed import plar_reduce_distributed
    from repro.distributed.api import make_mesh

    rng = np.random.default_rng(31)
    x, d = _table(rng, 100, 5)
    mesh = make_mesh((1, 1), ("data", "model"),
                     devices=np.array(jax.devices()[:1]))
    with pytest.raises(ValueError, match="fused"):
        plar_reduce_distributed(x, d, mesh, collective="fused",
                                engine="device")
    # auto resolves fused → host and still works
    r = plar_reduce_distributed(x, d, mesh, collective="fused")
    assert r.reduct == reduct_oracle("PR", x, d)
