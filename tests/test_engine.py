"""Device-resident selection engine (core/engine.py) vs the legacy host loop.

The engine's contract is *bit-identical* results: same reduct, same core,
same theta_history floats as ``engine="host"`` — across all four measures,
with shrink, with max_features, without core computation, and in spark mode.
Plus the perf contract: the whole greedy loop is ONE jitted while_loop (a
single trace/compile, no per-iteration recompiles or host transfers).

The distributed twin (1×1 mesh == single process; multi-device parity lives
in test_distributed.py's subprocess tests).
"""
import numpy as np
import pytest

from repro.core import fspa_reduce, har_reduce, plar_reduce
from repro.core.engine import make_engine_run
from repro.core.oracle import reduct_oracle

DELTAS = ["PR", "SCE", "LCE", "CCE"]


def _table(rng, n, a, vmax=3, m=2, redundancy=0.5):
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    for j in range(a):
        if rng.random() < redundancy and j > 0:
            x[:, j] = x[:, rng.integers(0, j)]
    d = rng.integers(0, m, size=(n,)).astype(np.int32)
    return x, d


def _assert_same(rh, rd):
    assert rh.reduct == rd.reduct
    assert rh.core == rd.core
    assert rh.theta_history == rd.theta_history  # bit-identical floats
    assert rh.iterations == rd.iterations
    # the device engine evaluates all A candidates per iteration (masked
    # argmin), the host loop only the shrinking remaining set
    assert rd.n_evaluations >= rh.n_evaluations


@pytest.mark.parametrize("delta", DELTAS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_engine_parity_all_measures(delta, seed):
    rng = np.random.default_rng(seed)
    x, d = _table(rng, 180, 8)
    rh = plar_reduce(x, d, delta=delta, engine="host")
    rd = plar_reduce(x, d, delta=delta, engine="device")
    _assert_same(rh, rd)
    assert rd.reduct == reduct_oracle(delta, x, d)


@pytest.mark.parametrize("delta", DELTAS)
def test_engine_parity_shrink(delta):
    """FSPA shrinking folds into SelectionState (active mask + PR scalar)."""
    rng = np.random.default_rng(7)
    x, d = _table(rng, 200, 8)
    rh = plar_reduce(x, d, delta=delta, shrink=True, engine="host")
    rd = plar_reduce(x, d, delta=delta, shrink=True, engine="device")
    _assert_same(rh, rd)


def test_engine_parity_max_features_and_no_core():
    rng = np.random.default_rng(11)
    x, d = _table(rng, 200, 10, redundancy=0.0)
    for kw in [dict(max_features=3, compute_core=False),
               dict(compute_core=False)]:
        rh = plar_reduce(x, d, delta="SCE", engine="host", **kw)
        rd = plar_reduce(x, d, delta="SCE", engine="device", **kw)
        _assert_same(rh, rd)
        if "max_features" in kw:
            assert len(rd.reduct) <= 3


def test_engine_parity_spark_mode_and_baselines():
    """HAR/FSPA (mode='spark', no GrC) run on the same engine step."""
    rng = np.random.default_rng(13)
    x, d = _table(rng, 150, 7)
    for reduce_fn in (har_reduce, fspa_reduce):
        rh = reduce_fn(x, d, delta="PR", engine="host")
        rd = reduce_fn(x, d, delta="PR", engine="device")
        _assert_same(rh, rd)


def test_engine_auto_resolution_and_validation():
    rng = np.random.default_rng(17)
    x, d = _table(rng, 80, 5)
    # auto == device for device-capable backends: identical results
    r_auto = plar_reduce(x, d, delta="SCE")
    r_dev = plar_reduce(x, d, delta="SCE", engine="device")
    _assert_same(r_auto, r_dev)
    with pytest.raises(ValueError, match="unknown engine"):
        plar_reduce(x, d, engine="gpu")
    with pytest.raises(ValueError, match="engine='device'"):
        plar_reduce(x, d, backend="pallas", engine="device")


def test_unknown_mode_and_backend_raise():
    """An unknown mode used to fall silently into the incremental branch."""
    rng = np.random.default_rng(19)
    x, d = _table(rng, 50, 4)
    with pytest.raises(ValueError, match="unknown mode.*incremental.*spark"):
        plar_reduce(x, d, mode="sprak")
    with pytest.raises(ValueError, match="unknown Θ backend.*segment"):
        plar_reduce(x, d, backend="sgement")


def test_engine_single_compile():
    """The whole greedy loop is ONE jit trace (the while_loop), and a second
    run on different same-shape data adds zero traces — the acceptance
    criterion "at most 2 XLA compilations (step + while_loop)"; the run
    needs just the one because the step body is inlined into the loop."""
    rng = np.random.default_rng(23)
    n, a, vmax, m = 160, 8, 3, 2
    x1, d1 = _table(rng, n, a, vmax=vmax, m=m)
    x2, d2 = _table(rng, n, a, vmax=vmax, m=m)
    # pin v_max/n_dec so both tables resolve to the same static config
    for x, d in ((x1, d1), (x2, d2)):
        x[0, :] = vmax - 1
        d[0] = m - 1
    # grc_init=False ⇒ capacity == n exactly, so the engine-cache key is known
    r1 = plar_reduce(x1, d1, delta="SCE", engine="device", grc_init=False)
    runner = make_engine_run(
        "SCE", "incremental", "segment", a, n, m, vmax, 1e-6, 1e-5, False, a,
        64)
    assert runner._cache_size() == 1          # one trace for the whole loop
    r2 = plar_reduce(x2, d2, delta="SCE", engine="device", grc_init=False)
    assert runner._cache_size() == 1          # warm rerun: zero new traces
    assert r1.reduct == reduct_oracle("SCE", x1, d1)
    assert r2.reduct == reduct_oracle("SCE", x2, d2)


def test_engine_step_matches_run_prefix():
    """make_engine_step (the exposed single-iteration entry point) drives the
    same body engine_run inlines: stepping it N times from a fresh state
    reproduces the full while_loop reduction exactly."""
    import jax.numpy as jnp

    from repro.core.engine import init_state, make_engine_step

    rng = np.random.default_rng(41)
    n, a, vmax, m = 120, 6, 3, 2
    x, d = _table(rng, n, a, vmax=vmax, m=m)
    x[0, :] = vmax - 1
    d[0] = m - 1
    r = plar_reduce(x, d, delta="SCE", engine="device", grc_init=False,
                    compute_core=False)
    step = make_engine_step(
        "SCE", "incremental", "segment", a, n, m, vmax, 1e-6, 1e-5, False, a,
        64)
    st = init_state(n, a, np.ones((n,), bool))
    xs, ds_ = jnp.asarray(x), jnp.asarray(d)
    ws = jnp.ones((n,), jnp.int32)
    no_core = jnp.zeros((a,), jnp.int32)
    for _ in range(r.iterations):
        st = step(st, xs, ds_, ws, jnp.int32(n), jnp.float32(r.theta_full),
                  no_core, jnp.int32(0))
    nsel = int(st.n_selected)
    assert [int(v) for v in np.asarray(st.order)[:nsel]] == r.reduct
    hist = [float(t) for t in np.asarray(st.theta_history)[:nsel]]
    assert hist == r.theta_history


@pytest.mark.parametrize("delta", ["PR", "LCE"])
def test_engine_distributed_1x1_mesh_matches_single_process(delta):
    """A 1×1 ('data','model') mesh engine == the single-process engine."""
    import jax

    from repro.core.distributed import plar_reduce_distributed
    from repro.distributed.api import make_mesh

    rng = np.random.default_rng(29)
    x, d = _table(rng, 250, 8)
    mesh = make_mesh((1, 1), ("data", "model"),
                     devices=np.array(jax.devices()[:1]))
    r_mesh = plar_reduce_distributed(x, d, mesh, delta=delta, engine="device")
    r_sp = plar_reduce(x, d, delta=delta, engine="device")
    assert r_mesh.reduct == r_sp.reduct
    assert r_mesh.core == r_sp.core
    # mesh capacity padding differs from the single-process pow2 shrink, so
    # float32 summation grouping may differ in the last ulp — values agree
    np.testing.assert_allclose(
        r_mesh.theta_history, r_sp.theta_history, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(
        r_mesh.theta_full, r_sp.theta_full, rtol=1e-6, atol=1e-7)


def test_engine_distributed_fused_collective_requires_host():
    import jax

    from repro.core.distributed import plar_reduce_distributed
    from repro.distributed.api import make_mesh

    rng = np.random.default_rng(31)
    x, d = _table(rng, 100, 5)
    mesh = make_mesh((1, 1), ("data", "model"),
                     devices=np.array(jax.devices()[:1]))
    with pytest.raises(ValueError, match="fused"):
        plar_reduce_distributed(x, d, mesh, collective="fused",
                                engine="device")
    # auto resolves fused → host and still works
    r = plar_reduce_distributed(x, d, mesh, collective="fused")
    assert r.reduct == reduct_oracle("PR", x, d)
