"""Per-architecture smoke tests (reduced same-family configs, CPU).

Required by the brief: every assigned arch instantiates a REDUCED config of
its family and runs one forward/train step asserting output shapes + no NaNs.
Full configs are exercised only by the dry-run (launch/dryrun.py).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import SHAPES, build_model

DECODER_ARCHS = [a for a in ARCH_IDS if not get_config(a).is_encdec]


def _batch(cfg, rng, b=2, s=16):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["frontend_feats"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32
        )
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.frontend_dim)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    """One loss + grad step on the reduced config: shapes, finiteness."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    batch = _batch(cfg, rng)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True
    )(params)
    assert jnp.isfinite(loss), arch
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g).all()), (arch, path)
        assert float(jnp.abs(g.astype(jnp.float32)).max()) > 0.0, (arch, path, "dead grad")


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if not get_config(a).is_encdec])
def test_smoke_logit_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    logits = model.forward(params, batch)
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_decode_matches_forward(arch):
    """Incremental decode (serve_step) reproduces teacher-forced logits."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    b, s = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 2)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        batch["frontend_feats"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32
        )
    full = model.forward(params, batch)
    lg, cache, lengths = model.prefill(params, {**batch, "tokens": toks[:, :s]}, cache_len=s + 4)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32), np.asarray(full[:, s - 1], np.float32),
        rtol=1e-4, atol=1e-4,
    )
    for t in range(2):
        lg, cache, lengths = model.decode(params, cache, toks[:, s + t : s + t + 1], lengths)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32), np.asarray(full[:, s + t], np.float32),
            rtol=1e-4, atol=1e-4,
        )


def test_encdec_decode_matches_teacher_forcing():
    cfg = get_config("seamless-m4t-medium").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    b, se, sd = 2, 10, 5
    frames = jnp.asarray(rng.standard_normal((b, se, cfg.frontend_dim)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, sd)), jnp.int32)
    mem = model.encode(params, frames)
    full = model._logits(params, model._decode_stack_full(params, toks, mem))
    cache, lengths = model.prefill(params, {"frames": frames}, cache_len=sd + 2)
    for t in range(sd):
        lg, cache, lengths = model.decode(params, cache, toks[:, t : t + 1], lengths)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32), np.asarray(full[:, t], np.float32),
            rtol=1e-4, atol=1e-4,
        )


# ---------------------------------------------------------------------------
# Full-config structural assertions (the brief's exact numbers)
# ---------------------------------------------------------------------------

BRIEF = {
    "qwen3-moe-235b-a22b": dict(n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
                                vocab=151936, n_experts=128, top_k=8, moe_d_ff=1536),
    "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
                            vocab=163840, n_experts=384, top_k=8, moe_d_ff=2048),
    "minitron-4b": dict(n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
                        d_ff=9216, vocab=256000),
    "gemma-2b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
                     d_ff=16384, vocab=256000, head_dim=256, activation="geglu"),
    "mistral-nemo-12b": dict(n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
                             d_ff=14336, vocab=131072),
    "tinyllama-1.1b": dict(n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
                           d_ff=5632, vocab=32000),
    "llava-next-34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
                           d_ff=20480, vocab=64000),
    "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
                                 d_ff=24576, vocab=65536, n_experts=16, top_k=2),
    "rwkv6-3b": dict(n_layers=32, d_model=2560, d_ff=8960, vocab=65536),
    "seamless-m4t-medium": dict(d_model=1024, n_heads=16, n_kv_heads=16,
                                d_ff=4096, vocab=256206, enc_layers=12, dec_layers=12),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_brief(arch):
    cfg = get_config(arch)
    for field, want in BRIEF[arch].items():
        assert getattr(cfg, field) == want, (arch, field, getattr(cfg, field), want)


def test_param_counts_close_to_advertised():
    """Analytic param counts land near each architecture's nameplate size."""
    expect = {
        "qwen3-moe-235b-a22b": (235e9, 0.05),
        "kimi-k2-1t-a32b": (1.0e12, 0.10),
        "tinyllama-1.1b": (1.1e9, 0.05),
        "mistral-nemo-12b": (12.2e9, 0.05),
        "gemma-2b": (2.5e9, 0.05),
        "llava-next-34b": (34e9, 0.05),
        "jamba-1.5-large-398b": (398e9, 0.05),
        "rwkv6-3b": (3.1e9, 0.05),
    }
    for arch, (want, tol) in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < tol, (arch, got, want)


def test_active_params_match_a_suffix():
    assert abs(get_config("qwen3-moe-235b-a22b").active_param_count() - 22e9) / 22e9 < 0.05
    assert abs(get_config("kimi-k2-1t-a32b").active_param_count() - 32e9) / 32e9 < 0.15


def test_long_500k_applicability():
    """Sub-quadratic archs (and only those) run long_500k (DESIGN.md §4)."""
    from repro.configs import shape_applies
    runs = {a for a in ARCH_IDS if shape_applies(get_config(a), SHAPES["long_500k"])}
    assert runs == {"rwkv6-3b", "jamba-1.5-large-398b"}
