"""Batched multi-config engine (DESIGN.md §3.8): parity, compile count, API.

The acceptance contract: a stacked N-config run returns byte-identical
per-config reducts and Θ histories to N independent ``plar_reduce`` runs —
across measures, shrink, feature caps, tolerances, bagged seeds, spark mode,
and every ensemble backend — while the whole grid is exactly ONE XLA
compile (one ``lax.while_loop`` trace).
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    ENSEMBLE_BACKENDS,
    bagged_weights,
    expand_ensemble_grid,
    make_ensemble_run,
    normalize_ensemble_configs,
    plar_reduce,
    plar_reduce_ensemble,
    resolve_granularity,
)

DELTAS = ["PR", "SCE", "LCE", "CCE"]


@pytest.fixture(scope="module", autouse=True)
def _free_compile_state():
    """Drop this module's compiled executables when it finishes.

    The parity matrix compiles dozens of large stacked-engine programs
    (vmapped multi-config while_loops) on top of the sequential twins;
    keeping them resident for the rest of the session pushes XLA:CPU's
    JIT over the edge on long full-suite runs (observed as a segfault in
    a *later* module's backend_compile).  The lru-cached runner factories
    are cleared too so no handle to a freed executable survives.
    """
    yield
    import jax

    from repro.core import engine

    engine._make_engine_run.cache_clear()
    engine._make_engine_step.cache_clear()
    engine._make_ensemble_run.cache_clear()
    jax.clear_caches()


def _table(rng, n, a, vmax=4, m=2, redundancy=0.5):
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    for j in range(1, a):
        if rng.random() < redundancy:
            x[:, j] = x[:, rng.integers(0, j)]
    d = rng.integers(0, m, size=(n,)).astype(np.int32)
    return x, d


def _assert_member(r_e, r_s):
    assert r_e.reduct == r_s.reduct
    assert r_e.theta_history == r_s.theta_history  # bit-identical floats
    assert r_e.core == r_s.core
    assert r_e.theta_full == r_s.theta_full
    assert r_e.iterations == r_s.iterations
    assert r_e.n_evaluations == r_s.n_evaluations


# ---------------------------------------------------------------------------
# parity matrix (the §3.8 contract)
# ---------------------------------------------------------------------------


def test_ensemble_mixed_grid_matches_sequential():
    """One stacked dispatch over a grid mixing every per-config knob ==
    the same configs run sequentially, member for member."""
    rng = np.random.default_rng(7)
    x, d = _table(rng, 300, 8, m=3)
    grid = [
        {"delta": "PR"},
        {"delta": "SCE", "shrink": True},
        {"delta": "LCE", "max_features": 3, "compute_core": False},
        {"delta": "CCE", "tol": 1e-5},
        {"delta": "PR", "shrink": True, "tie_tol": 1e-4},
    ]
    ens = plar_reduce_ensemble(x, d, configs=grid)
    assert len(ens) == len(grid)
    for c, r_e in zip(grid, ens):
        r_s = plar_reduce(x, d, engine="device", **c)
        _assert_member(r_e, r_s)


@pytest.mark.parametrize("mode,backend,ladder", [
    ("incremental", "segment", False),
    ("incremental", "onehot", False),
    ("incremental", "sweep_xla", False),
    ("incremental", "sweep_xla", True),
    ("spark", "segment", False),
])
def test_ensemble_backend_parity(mode, backend, ladder):
    """Every ensemble backend (and the stacked ladder) matches its
    sequential twin on the all-measures grid."""
    rng = np.random.default_rng(13)
    x, d = _table(rng, 250, 7, m=3)
    ens = plar_reduce_ensemble(x, d, configs=DELTAS, mode=mode,
                               backend=backend, ladder=ladder)
    for dd, r_e in zip(DELTAS, ens):
        r_s = plar_reduce(x, d, delta=dd, engine="device", mode=mode,
                          backend=backend, ladder=ladder)
        _assert_member(r_e, r_s)


def test_ensemble_bagged_matches_reweighted_sequential():
    """A ``seed`` config is a bootstrap reweighting of the shared
    granularity: its sequential twin is ``plar_reduce`` on the same
    granules with ``w`` replaced by :func:`bagged_weights`."""
    rng = np.random.default_rng(29)
    x, d = _table(rng, 280, 7, m=3)
    gran = resolve_granularity(x, d)
    seeds = [0, 1, 2]
    ens = plar_reduce_ensemble(source=gran, configs=["SCE"], seeds=seeds)
    for s, r_e in zip(seeds, ens):
        w_s = bagged_weights(gran, s)
        assert int(w_s.sum()) == int(gran.n_total)  # total mass preserved
        twin = dataclasses.replace(gran, w=jnp.asarray(w_s),
                                   n_total=jnp.int32(int(w_s.sum())))
        r_s = plar_reduce(source=twin, delta="SCE", engine="device")
        _assert_member(r_e, r_s)


def test_ensemble_single_compile():
    """The whole grid is ONE jit trace, and a second grid on different
    same-shape data adds zero traces — the §3.8 acceptance criterion."""
    rng = np.random.default_rng(23)
    n, a, vmax, m = 160, 8, 3, 2
    grid = [{"delta": dd, "shrink": s} for dd in DELTAS for s in (False, True)]
    x1, d1 = _table(rng, n, a, vmax=vmax, m=m)
    x2, d2 = _table(rng, n, a, vmax=vmax, m=m)
    # pin v_max/n_dec so both tables resolve to the same static config
    for x, d in ((x1, d1), (x2, d2)):
        x[0, :] = vmax - 1
        d[0] = m - 1
    # grc_init=False ⇒ capacity == n exactly, so the engine-cache key is known
    rs1 = plar_reduce_ensemble(x1, d1, configs=grid, grc_init=False)
    runner = make_ensemble_run("incremental", "segment", len(grid), a, n, m,
                               vmax, 64, False)
    assert runner._cache_size() == 1          # one trace for the whole grid
    rs2 = plar_reduce_ensemble(x2, d2, configs=grid, grc_init=False)
    assert runner._cache_size() == 1          # warm rerun: zero new traces
    for (x, d), rs in (((x1, d1), rs1), ((x2, d2), rs2)):
        for c, r_e in zip(grid, rs):
            _assert_member(r_e, plar_reduce(x, d, engine="device",
                                            grc_init=False, **c))


# ---------------------------------------------------------------------------
# grid semantics + validation
# ---------------------------------------------------------------------------


def test_expand_ensemble_grid_order_and_seeds():
    grid = expand_ensemble_grid(["PR", {"delta": "SCE", "shrink": True}],
                                seeds=[4, 9])
    # configs outer, seeds inner; bare measure name → {"delta": name}
    assert grid == [
        {"delta": "PR", "seed": 4}, {"delta": "PR", "seed": 9},
        {"delta": "SCE", "shrink": True, "seed": 4},
        {"delta": "SCE", "shrink": True, "seed": 9},
    ]
    assert expand_ensemble_grid(["LCE"]) == [{"delta": "LCE"}]


def test_ensemble_validation_errors():
    rng = np.random.default_rng(5)
    x, d = _table(rng, 60, 4)
    with pytest.raises(ValueError, match="non-empty"):
        plar_reduce_ensemble(x, d, configs=[])
    with pytest.raises(ValueError, match="unknown measure"):
        plar_reduce_ensemble(x, d, configs=["XXX"])
    with pytest.raises(ValueError, match="unknown ensemble config keys"):
        plar_reduce_ensemble(x, d, configs=[{"delta": "PR", "bogus": 1}])
    with pytest.raises(ValueError, match="seed"):
        # per-config seed and a seeds= grid are mutually exclusive
        normalize_ensemble_configs([{"delta": "PR", "seed": 3}], seeds=[1])
    with pytest.raises(ValueError, match="backend"):
        plar_reduce_ensemble(x, d, configs=["PR"], backend="fused_xla")
    with pytest.raises(ValueError, match="sweep_xla"):
        # stacked ladder shares one rung across configs — sweep_xla only
        plar_reduce_ensemble(x, d, configs=["PR"], backend="segment",
                             ladder=True)
    assert "fused_xla" not in ENSEMBLE_BACKENDS


# ---------------------------------------------------------------------------
# service layer
# ---------------------------------------------------------------------------


def test_handle_reduce_ensemble_members_match_direct():
    """DatasetHandle.reduce_ensemble == the driver on the handle's
    granularity, and members land in the handle's result cache."""
    from repro.service import DatasetHandle

    rng = np.random.default_rng(17)
    x, d = _table(rng, 300, 8, m=3)
    h = DatasetHandle.create(x, d, n_dec=3, v_max=4)
    configs = [{"delta": dd} for dd in DELTAS]
    rs = h.reduce_ensemble(configs)
    direct = plar_reduce_ensemble(source=h.gran, configs=configs)
    for r_h, r_d in zip(rs, direct):
        _assert_member(r_h, r_d)


def test_server_query_ensemble_cache_and_stats():
    """query_ensemble: cold grid → C cold configs; repeat → pure cache hit;
    overlapping grid → only the new configs re-run (as a smaller grid)."""
    import asyncio

    from repro.service import ReductServer

    rng = np.random.default_rng(31)
    x, d = _table(rng, 240, 7, m=3)

    async def drive():
        async with ReductServer() as srv:
            await srv.submit("t", x, d, n_dec=3, v_max=4)
            r1 = await srv.query_ensemble("t", ["PR", "SCE"])
            r2 = await srv.query_ensemble("t", ["PR", "SCE"])
            r3 = await srv.query_ensemble("t", ["PR", "SCE", "LCE"])
            return r1, r2, r3, dict(srv.stats), list(srv.requests)

    r1, r2, r3, stats, reqs = asyncio.run(drive())
    assert [r.reduct for r in r1] == [r.reduct for r in r2]
    assert [r.reduct for r in r3[:2]] == [r.reduct for r in r1]
    assert stats["ensemble_queries"] == 3
    assert stats["ensemble_configs"] == 7
    assert stats["cold"] == 3            # PR, SCE once + LCE once
    assert stats["cache_hits"] == 4      # r2's two + r3's two
    assert not reqs[0].cached and reqs[1].cached and not reqs[2].cached
