"""Fused contingency→Θ kernel vs the unfused reference path (DESIGN.md §5.2).

The fused kernel must reproduce ``measures.evaluate(delta,
candidate_contingency(...), n)`` to ≤1e-5 for all four measures — including
the edge cases the epilogues guard: all-padding tiles, pure classes (the θ_PR
edge), and empty contingency cells (0·log 0 in θ_SCE).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim: property tests skip on bare envs

from repro.core import measures
from repro.core.plan import candidate_contingency, candidate_theta
from repro.kernels.contingency import (
    autotune_block_sizes,
    fused_theta,
    fused_theta_ref,
    select_block_sizes,
    theta_scale,
)

DELTAS = ["PR", "SCE", "LCE", "CCE"]


def _case(rng, nc, g, n_bins, m, zero_tail=0):
    packed = rng.integers(0, n_bins, size=(nc, g)).astype(np.int32)
    d = rng.integers(0, m, size=(g,)).astype(np.int32)
    w = rng.integers(1, 5, size=(g,)).astype(np.float32)
    if zero_tail:
        w[-zero_tail:] = 0.0
    return jnp.asarray(packed), jnp.asarray(d), jnp.asarray(w)


def _unfused(delta, packed, d, w, n, *, n_bins, m):
    valid = w > 0
    cont = candidate_contingency(packed, d, w, valid, n_bins=n_bins, m=m)
    return np.asarray(measures.evaluate(delta, cont, n))


@pytest.mark.parametrize("delta", DELTAS)
@pytest.mark.parametrize(
    "nc,g,n_bins,m",
    [
        (1, 64, 8, 2),
        (3, 700, 37, 5),
        (8, 1024, 128, 2),       # tile-aligned
        (2, 1000, 130, 26),      # bins just over one tile
        (5, 513, 300, 3),        # G just over one tile
        (1, 33, 1, 2),           # single bin
    ],
)
def test_fused_matches_unfused(delta, nc, g, n_bins, m):
    rng = np.random.default_rng(nc * 1000 + g)
    packed, d, w = _case(rng, nc, g, n_bins, m, zero_tail=g // 10)
    n = float(np.asarray(w).sum())
    got = np.asarray(fused_theta(packed, d, w, n, delta=delta, n_bins=n_bins, n_dec=m))
    want = _unfused(delta, packed, d, w, n, n_bins=n_bins, m=m)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("delta", DELTAS)
def test_fused_matches_ref_oracle(delta):
    """Raw (unnormalized) kernel output vs the ref.py oracle definition."""
    rng = np.random.default_rng(3)
    packed, d, w = _case(rng, 4, 600, 50, 3)
    n = float(np.asarray(w).sum())
    got = np.asarray(fused_theta(packed, d, w, n, delta=delta, n_bins=50, n_dec=3))
    want = np.asarray(fused_theta_ref(packed, d, w, n, delta=delta, n_bins=50, n_dec=3))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("delta", DELTAS)
@pytest.mark.parametrize("bk,bg", [(8, 64), (128, 128), (64, 512)])
def test_fused_block_shape_invariance(delta, bk, bg):
    """Θ must not depend on the BlockSpec tiling (epilogue runs per bin-tile)."""
    rng = np.random.default_rng(7)
    packed, d, w = _case(rng, 3, 500, 77, 4)
    n = float(np.asarray(w).sum())
    got = np.asarray(
        fused_theta(packed, d, w, n, delta=delta, n_bins=77, n_dec=4, bk=bk, bg=bg))
    want = _unfused(delta, packed, d, w, n, n_bins=77, m=4)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("delta", DELTAS)
def test_all_padding_tiles(delta):
    """Θ of an empty universe (w ≡ 0, sentinel keys) is exactly 0."""
    packed = jnp.full((2, 100), -1, jnp.int32)
    d = jnp.zeros((100,), jnp.int32)
    w = jnp.zeros((100,), jnp.float32)
    got = np.asarray(fused_theta(packed, d, w, 10.0, delta=delta, n_bins=40, n_dec=3))
    np.testing.assert_array_equal(got, np.zeros(2, np.float32))


def test_pure_classes_pr_edge():
    """All-pure classes: γ = 1, so Θ_PR = -1 exactly; SCE/LCE/CCE = 0."""
    rng = np.random.default_rng(11)
    packed = jnp.asarray(rng.integers(0, 6, size=(3, 200)), jnp.int32)
    d = np.asarray(packed[0]) % 2  # decision determined by candidate 0's key
    w = jnp.ones((200,), jnp.float32)
    got = np.asarray(
        fused_theta(packed[:1], jnp.asarray(d), w, 200.0, delta="PR", n_bins=6, n_dec=2))
    np.testing.assert_allclose(got, [-1.0], atol=1e-6)
    for delta in ("SCE", "LCE", "CCE"):
        got = np.asarray(
            fused_theta(packed[:1], jnp.asarray(d), w, 200.0, delta=delta, n_bins=6, n_dec=2))
        np.testing.assert_allclose(got, [0.0], atol=1e-6)


def test_zero_log_zero_cells():
    """Classes hitting only a subset of decisions: 0·log 0 ≝ 0 in θ_SCE."""
    # bin 0 → decision 0 only; bin 1 → decisions 1,2; bin 2 never occurs.
    packed = jnp.asarray([[0, 0, 1, 1, 1, 1]], jnp.int32)
    d = jnp.asarray([0, 0, 1, 1, 2, 2], jnp.int32)
    w = jnp.ones((6,), jnp.float32)
    n = 6.0
    for delta in DELTAS:
        got = np.asarray(fused_theta(packed, d, w, n, delta=delta, n_bins=3, n_dec=3))
        want = _unfused(delta, packed, d, w, n, n_bins=3, m=3)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
        assert np.isfinite(got).all()


@pytest.mark.parametrize("backend", ["fused", "fused_xla"])
@pytest.mark.parametrize("delta", DELTAS)
def test_candidate_theta_backends_agree(backend, delta):
    """plan.candidate_theta: fused backends == materialize-then-evaluate."""
    rng = np.random.default_rng(13)
    packed, d, w = _case(rng, 4, 600, 50, 3)
    valid = w > 0
    n = float(np.asarray(w).sum())
    got = np.asarray(candidate_theta(
        delta, packed, d, w, valid, n, n_bins=50, m=3, backend=backend))
    want = np.asarray(candidate_theta(
        delta, packed, d, w, valid, n, n_bins=50, m=3, backend="segment"))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


def test_theta_scale_linearity():
    """theta_scale commutes with summation — the fused-collective invariant."""
    rng = np.random.default_rng(17)
    parts = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    n = 123.0
    for delta in DELTAS:
        merged = np.asarray(theta_scale(delta, parts.sum(0), n))
        scaled = np.asarray(theta_scale(delta, parts, n).sum(0))
        np.testing.assert_allclose(merged, scaled, rtol=1e-5, atol=1e-6)


def test_select_block_sizes_sane():
    bk, bg = select_block_sizes(300, 5000, 128)
    assert bk % 8 == 0 and bg % 128 == 0
    from repro.kernels.contingency.autotune import working_set_bytes, VMEM_BUDGET_BYTES
    assert working_set_bytes(bk, bg, 128) <= VMEM_BUDGET_BYTES


def test_autotune_hook_returns_valid_config():
    """The timing hook must return a config that computes correct Θ."""
    bk, bg = autotune_block_sizes(2, 300, 40, 3, delta="SCE", reps=1,
                                  candidates=((8, 128), (16, 256)))
    assert (bk, bg) in ((8, 128), (16, 256))
    rng = np.random.default_rng(19)
    packed, d, w = _case(rng, 2, 300, 40, 3)
    n = float(np.asarray(w).sum())
    got = np.asarray(
        fused_theta(packed, d, w, n, delta="SCE", n_bins=40, n_dec=3, bk=bk, bg=bg))
    want = _unfused("SCE", packed, d, w, n, n_bins=40, m=3)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    nc=st.integers(1, 4),
    g=st.integers(1, 300),
    n_bins=st.integers(1, 64),
    m=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_fused_theta_property(nc, g, n_bins, m, seed):
    rng = np.random.default_rng(seed)
    packed, d, w = _case(rng, nc, g, n_bins, m)
    n = float(np.asarray(w).sum()) or 1.0
    for delta in DELTAS:
        got = np.asarray(fused_theta(packed, d, w, n, delta=delta, n_bins=n_bins, n_dec=m))
        want = _unfused(delta, packed, d, w, n, n_bins=n_bins, m=m)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)
