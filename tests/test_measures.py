"""The four significance measures vs the paper-literal numpy oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim: property tests skip on bare envs

from repro.core import measures
from repro.core.oracle import theta_oracle
from repro.core.plan import contingency_from_ids, ids_by_sort

DELTAS = ["PR", "SCE", "LCE", "CCE"]


def _theta_via_decomposition(delta, x, d, cols):
    """Θ(D|B) through the granule/contingency path (paper §3.2)."""
    n = x.shape[0]
    if cols:
        keys = [jnp.asarray(x[:, c]) for c in cols][::-1]
    else:
        keys = [jnp.zeros(n, jnp.int32)]
    valid = jnp.ones(n, bool)
    ids, k = ids_by_sort(keys, valid)
    m = int(d.max()) + 1
    cont = contingency_from_ids(ids, jnp.asarray(d), jnp.ones(n, jnp.int32), valid, n_bins=n, m=m)
    return float(measures.evaluate(delta, cont, jnp.float32(n)))


@pytest.mark.parametrize("delta", DELTAS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_theta_matches_oracle(delta, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 3, size=(120, 6)).astype(np.int32)
    d = rng.integers(0, 3, size=(120,)).astype(np.int32)
    for cols in [[0], [1, 3], [0, 2, 4], list(range(6))]:
        got = _theta_via_decomposition(delta, x, d, cols)
        want = theta_oracle(delta, x, d, cols)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("delta", DELTAS)
def test_theta_monotone_under_refinement(delta):
    """Adding attributes never increases Θ (all four are anti-monotone).

    This is the rough-set property that makes greedy forward selection sound:
    Θ(D|B∪{a}) ≤ Θ(D|B), i.e. outer significance is non-negative.
    """
    rng = np.random.default_rng(42)
    x = rng.integers(0, 4, size=(200, 7)).astype(np.int32)
    d = rng.integers(0, 2, size=(200,)).astype(np.int32)
    cols: list = []
    prev = _theta_via_decomposition(delta, x, d, cols)
    for a in range(7):
        cols.append(a)
        cur = _theta_via_decomposition(delta, x, d, cols)
        assert cur <= prev + 1e-6, (delta, cols, cur, prev)
        prev = cur


@pytest.mark.parametrize("delta", DELTAS)
def test_theta_consistent_table_reaches_floor(delta):
    """If D is a function of B, Θ(D|B) hits its minimum (PR: -1; entropies: 0)."""
    rng = np.random.default_rng(3)
    x = rng.integers(0, 5, size=(100, 3)).astype(np.int32)
    d = ((x[:, 0] + 2 * x[:, 1]) % 3).astype(np.int32)  # D determined by B
    got = _theta_via_decomposition(delta, x, d, [0, 1])
    if delta == "PR":
        np.testing.assert_allclose(got, -1.0, atol=1e-6)
    else:
        np.testing.assert_allclose(got, 0.0, atol=1e-6)


def test_pr_theta_is_negative_dependency():
    """Θ_PR = -γ_B(D) per the paper's unified sign convention."""
    x = np.array([[0], [0], [1], [1]], np.int32)
    d = np.array([0, 0, 0, 1], np.int32)
    # class {0,0}: pure (2 objects). class {1,1}: impure. γ = 2/4.
    got = _theta_via_decomposition("PR", x, d, [0])
    np.testing.assert_allclose(got, -0.5, atol=1e-7)


def test_paper_example_table3():
    """The paper's running Example 1/3 (Table 3): B={a2}, Δ=PR → γ = 1/8·|..|.

    From Fig. 6: with B={a2} the classes are a2=0 → {Y:3,N:2} (impure) and
    a2=1 → {Y:4} pure wait — recompute from Table 3: a2=0 rows {x1,x2,x3,x7},
    decisions {Y,Y,N,N} impure; a2=1 rows {x4,x5,x6,x8} all Y → pure, 4 objs.
    γ = 4/8, Θ_PR = -0.5.
    """
    x = np.array([[0, 0], [0, 0], [0, 0], [0, 1], [0, 1], [0, 1], [1, 0], [1, 1]], np.int32)
    d = np.array([0, 0, 1, 0, 0, 0, 1, 0], np.int32)
    got = _theta_via_decomposition("PR", x, d, [1])
    np.testing.assert_allclose(got, -0.5, atol=1e-7)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(5, 80),
    a=st.integers(1, 5),
    vmax=st.integers(2, 4),
    m=st.integers(2, 4),
    delta=st.sampled_from(DELTAS),
    seed=st.integers(0, 2**16),
)
def test_theta_property(n, a, vmax, m, delta, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    d = rng.integers(0, m, size=(n,)).astype(np.int32)
    cols = list(rng.choice(a, size=rng.integers(1, a + 1), replace=False))
    got = _theta_via_decomposition(delta, x, d, [int(c) for c in cols])
    want = theta_oracle(delta, x, d, [int(c) for c in cols])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
