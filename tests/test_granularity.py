"""GrC initialization: granularity build, coarsening, id packing/compaction."""
import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim: property tests skip on bare envs

from repro.core import (
    build_granularity,
    compact_ids,
    pack_ids,
    regranulate,
    row_fingerprints,
)
from repro.core.granularity import column_terms


def _np_granules(x, d):
    rows = np.concatenate([x, d[:, None]], axis=1)
    uniq, counts = np.unique(rows, axis=0, return_counts=True)
    return uniq, counts


@pytest.mark.parametrize("exact", [True, False])
@pytest.mark.parametrize("seed", [0, 1])
def test_build_matches_numpy_unique(exact, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 3, size=(300, 5)).astype(np.int32)
    d = rng.integers(0, 2, size=(300,)).astype(np.int32)
    g = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3, exact=exact)
    uniq, counts = _np_granules(x, d)
    assert int(g.num) == len(uniq)
    assert int(g.n_total) == 300
    got = np.concatenate(
        [np.asarray(g.x)[: int(g.num)], np.asarray(g.d)[: int(g.num), None]], axis=1
    )
    got_w = np.asarray(g.w)[: int(g.num)]
    # order-insensitive comparison
    order_got = np.lexsort(got.T[::-1])
    order_want = np.lexsort(uniq.T[::-1])
    np.testing.assert_array_equal(got[order_got], uniq[order_want])
    np.testing.assert_array_equal(got_w[order_got], counts[order_want])
    # padding slots carry zero weight
    assert np.all(np.asarray(g.w)[int(g.num):] == 0)


def test_paper_example1_table4():
    """Paper Example 1: Table 3 → Table 4 granularity representation."""
    x = np.array([[0, 0], [0, 0], [0, 0], [0, 1], [0, 1], [0, 1], [1, 0], [1, 1]], np.int32)
    d = np.array([0, 0, 1, 0, 0, 0, 1, 0], np.int32)  # Y=0, N=1
    g = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=2)
    assert int(g.num) == 5                       # Table 4 has 5 granules
    assert int(g.n_total) == 8
    rows = {
        tuple(np.asarray(g.x)[i].tolist()) + (int(np.asarray(g.d)[i]),): int(np.asarray(g.w)[i])
        for i in range(5)
    }
    assert rows == {(0, 0, 0): 2, (0, 0, 1): 1, (0, 1, 0): 3, (1, 0, 1): 1, (1, 1, 0): 1}


def test_coarsening_merges_counts():
    """Corollary 3.3: G^(P) from G^(Q), P ⊆ Q — counts merge additively."""
    rng = np.random.default_rng(2)
    x = rng.integers(0, 3, size=(200, 4)).astype(np.int32)
    d = rng.integers(0, 2, size=(200,)).astype(np.int32)
    g_full = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3)
    g_p = regranulate(g_full, jnp.asarray([0, 2], jnp.int32))
    uniq, counts = _np_granules(x[:, [0, 2]], d)
    assert int(g_p.num) == len(uniq)
    assert int(np.asarray(g_p.w).sum()) == 200


def test_fingerprint_linearity():
    """h(row) = Σ_j term_j — removing a column is subtraction (linear sketch)."""
    rng = np.random.default_rng(4)
    x = rng.integers(0, 100, size=(50, 6)).astype(np.int32)
    h = row_fingerprints(jnp.asarray(x), 0)
    acc = jnp.zeros((50,), jnp.uint32)
    for j in range(6):
        acc = acc + column_terms(jnp.asarray(x[:, j]), j, 6, 0)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(acc))
    # drop column 3 by subtraction == fingerprint of the subtable
    h_drop = h - column_terms(jnp.asarray(x[:, 3]), 3, 6, 0)
    cols = [0, 1, 2, 4, 5]
    # note: column seeds depend on (index, n_cols); rebuild with same seeds
    manual = jnp.zeros((50,), jnp.uint32)
    for j in cols:
        manual = manual + column_terms(jnp.asarray(x[:, j]), j, 6, 0)
    np.testing.assert_array_equal(np.asarray(h_drop), np.asarray(manual))


def test_pack_compact_roundtrip():
    """pack_ids refines exactly; compact_ids renumbers densely and stably."""
    rng = np.random.default_rng(5)
    n, v = 100, 4
    r = rng.integers(0, 7, size=(n,)).astype(np.int32)
    col = rng.integers(0, v, size=(n,)).astype(np.int32)
    valid = jnp.asarray(rng.random(n) > 0.1)
    packed = pack_ids(jnp.asarray(r), jnp.asarray(col), v)
    new_ids, k_new, presence = compact_ids(packed, valid, 7 * v)
    pairs = {(int(a), int(b)) for a, b, ok in zip(r, col, np.asarray(valid)) if ok}
    assert int(k_new) == len(pairs)
    # same (r, col) pair ⇒ same new id; different ⇒ different
    seen = {}
    for i in range(n):
        if not bool(np.asarray(valid)[i]):
            continue
        key = (int(r[i]), int(col[i]))
        nid = int(np.asarray(new_ids)[i])
        assert seen.setdefault(key, nid) == nid
    assert len(set(seen.values())) == len(pairs)


def test_compact_ids_commute_with_merge():
    """Presence bitmaps OR/psum across shards ⇒ identical global numbering.

    Simulates two data shards: merging bitmaps then ranking equals ranking
    the concatenated data — the property that lets distributed PLAR renumber
    without a gather (DESIGN.md §3.1).
    """
    rng = np.random.default_rng(6)
    from repro.core.granularity import ids_from_presence, presence_bitmap

    n_bins = 40
    p1 = jnp.asarray(rng.integers(0, n_bins, size=(60,)).astype(np.int32))
    p2 = jnp.asarray(rng.integers(0, n_bins, size=(60,)).astype(np.int32))
    v1 = jnp.ones((60,), bool)
    v2 = jnp.ones((60,), bool)
    bm = presence_bitmap(p1, v1, n_bins) + presence_bitmap(p2, v2, n_bins)  # "psum"
    ids1, k1 = ids_from_presence(bm, p1, v1)
    ids2, k2 = ids_from_presence(bm, p2, v2)
    both = jnp.concatenate([p1, p2])
    idsb, kb = ids_from_presence(presence_bitmap(both, jnp.ones((120,), bool), n_bins), both, jnp.ones((120,), bool))
    assert int(k1) == int(kb) == int(k2)
    np.testing.assert_array_equal(np.asarray(idsb[:60]), np.asarray(ids1))
    np.testing.assert_array_equal(np.asarray(idsb[60:]), np.asarray(ids2))


def test_distributed_merge_equals_global_build():
    """Per-shard granulation + weighted re-granulation == global granulation."""
    rng = np.random.default_rng(7)
    x = rng.integers(0, 3, size=(400, 4)).astype(np.int32)
    d = rng.integers(0, 2, size=(400,)).astype(np.int32)
    g_all = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=3)

    shards = [build_granularity(jnp.asarray(x[i::2]), jnp.asarray(d[i::2]), n_dec=2, v_max=3) for i in range(2)]
    xs = jnp.concatenate([s.x for s in shards])
    ds = jnp.concatenate([s.d for s in shards])
    ws = jnp.concatenate([s.w for s in shards])
    vs = jnp.concatenate([s.valid for s in shards])
    merged = build_granularity(xs, ds, n_dec=2, v_max=3, w=ws, valid=vs)
    assert int(merged.num) == int(g_all.num)
    assert int(merged.n_total) == int(g_all.n_total) == 400


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 200),
    a=st.integers(1, 6),
    vmax=st.integers(1, 5),
    seed=st.integers(0, 2**16),
)
def test_granularity_property(n, a, vmax, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    d = rng.integers(0, 2, size=(n,)).astype(np.int32)
    g = build_granularity(jnp.asarray(x), jnp.asarray(d), n_dec=2, v_max=vmax)
    uniq, counts = _np_granules(x, d)
    assert int(g.num) == len(uniq)
    assert int(np.asarray(g.w).sum()) == n
