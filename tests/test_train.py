"""Training loop, optimizer, checkpointing, preemption, stragglers."""
import os
import signal
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data import TokenStream
from repro.train import (
    AdamW, CheckpointManager, TrainConfig, Trainer, constant_schedule,
    cosine_schedule,
)


@pytest.fixture()
def small_setup(tmp_path):
    cfg = get_config("tinyllama-1.1b").reduced()
    tc = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=50,
                     ckpt_every=5, ckpt_dir=str(tmp_path / "ckpt"), log_every=5)
    stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=0)
    data_fn = lambda step: {k: jnp.asarray(v) for k, v in stream.batch(step).items()}
    return cfg, tc, data_fn


def test_loss_decreases(small_setup):
    cfg, tc, data_fn = small_setup
    trainer = Trainer(cfg, tc)
    state, hist = trainer.fit(data_fn, steps=25)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_resume_bit_exact(small_setup, tmp_path):
    """Stop at step 10, resume to 15 == straight run to 15 (same data)."""
    cfg, tc, data_fn = small_setup
    t1 = Trainer(cfg, tc)
    state_a, _ = t1.fit(data_fn, steps=10)
    t2 = Trainer(cfg, tc)       # restores step-10 checkpoint
    state_b, _ = t2.fit(data_fn, steps=15)

    import dataclasses
    tc_straight = dataclasses.replace(tc, ckpt_dir=str(tmp_path / "ckpt2"))
    t3 = Trainer(cfg, tc_straight)
    state_c, _ = t3.fit(data_fn, steps=15)
    for a, c in zip(jax.tree.leaves(state_b["params"]),
                    jax.tree.leaves(state_c["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), rtol=1e-5, atol=1e-6)


def test_checkpoint_atomicity_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": np.arange(10.0), "b": {"c": np.ones((3, 3))}}
    for step in (1, 2, 3, 4):
        mgr.save(step, tree)
    assert mgr.all_steps() == [3, 4]          # retention
    step, restored, _ = mgr.restore()
    assert step == 4
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])
    # uncommitted directories are ignored
    os.makedirs(str(tmp_path / "step_000000099"))
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": np.random.default_rng(0).standard_normal((256, 256))}
    path = mgr.save(7, tree, blocking=False)
    mgr.wait()
    assert os.path.exists(os.path.join(path, "COMMITTED"))
    _, restored, _ = mgr.restore(7)
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_preemption_checkpoints_and_exits(small_setup):
    cfg, tc, data_fn = small_setup
    trainer = Trainer(cfg, tc)
    trainer.install_preemption_handler()

    def preempt():
        time.sleep(3.0)
        signal.raise_signal(signal.SIGTERM)

    threading.Thread(target=preempt, daemon=True).start()
    state, hist = trainer.fit(data_fn, steps=10_000)
    # must have stopped early and left a committed checkpoint
    assert trainer.ckpt.latest_step() is not None
    assert trainer.ckpt.latest_step() < 10_000


def test_straggler_detector(small_setup):
    cfg, tc, data_fn = small_setup
    trainer = Trainer(cfg, tc)

    slow = {"at": 7}

    def slow_data(step):
        if step == slow["at"]:
            time.sleep(1.0)  # not counted: sleep happens before the timer
        return data_fn(step)

    # inject slowness into the step itself via a wrapper
    orig = Trainer.step_fn.func(trainer)

    def spiky(state, batch):
        out = orig(state, batch)
        if int(np.asarray(out[0]["opt_step"])) == slow["at"]:
            time.sleep(1.5)
        return out

    trainer.__dict__["step_fn"] = spiky
    trainer.fit(slow_data, steps=12)
    assert trainer.straggler_steps, "straggler step not flagged"


def test_adamw_converges_quadratic():
    opt = AdamW(lr=constant_schedule(0.1), weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}      # d/dw ||w||²
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110, floor=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr(jnp.asarray(60))) < 1.0
    assert abs(float(lr(jnp.asarray(110))) - 0.1) < 1e-6


def test_bf16_moments_track_f32():
    opt32 = AdamW(lr=constant_schedule(0.05), weight_decay=0.0, moment_dtype="float32")
    opt16 = AdamW(lr=constant_schedule(0.05), weight_decay=0.0, moment_dtype="bfloat16")
    p32 = {"w": jnp.ones((64,)) * 2.0}
    p16 = {"w": jnp.ones((64,)) * 2.0}
    s32, s16 = opt32.init(p32), opt16.init(p16)
    rng = np.random.default_rng(0)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(64), jnp.float32) * 0.1 + p32["w"] * 0.2
        p32, s32, _ = opt32.update({"w": g}, s32, p32)
        p16, s16, _ = opt16.update({"w": g}, s16, p16)
    # bf16 moments drift but stay close (the HBM-halving trade-off)
    diff = float(jnp.abs(p32["w"] - p16["w"]).max())
    assert diff < 0.05, diff


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("tinyllama-1.1b").reduced()
    from repro.models import build_model
    from repro.train import make_train_step

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=constant_schedule(1e-3), weight_decay=0.0, grad_clip=0.0)
    st = opt.init(params)
    state = {"params": params, "opt_m": st.m, "opt_v": st.v, "opt_step": st.step}
    stream = TokenStream(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}

    s1, m1 = jax.jit(make_train_step(model, opt, microbatches=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, opt, microbatches=4))(state, batch)
    # identical data in a different reduction order: params must match closely
    # (absolute tolerance — Adam's m/√v normalization amplifies float-order
    # noise on near-zero second moments at step 1)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-2, atol=1e-3)


def test_data_stream_deterministic_and_shardable():
    s = TokenStream(vocab=100, seq_len=8, global_batch=8, seed=3)
    a = s.batch(5)
    b = s.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    shards = [s.shard(5, i, 4) for i in range(4)]
    stacked = np.concatenate([sh["tokens"] for sh in shards])
    np.testing.assert_array_equal(stacked, a["tokens"])
