"""K-adaptive bin ladder + multi-candidate sweep backend (DESIGN.md §5.3).

The contract of PR 4: the eval sweep may shrink its bin work to the live
K·V range (ladder) and switch to the read-once slab backend (sweep /
sweep_xla) with *byte-exact* results — same reduct, same core, same
theta_history floats — against the PR-2 device engine, across all four
measures, with shrink, in spark mode, and under max_features.  Plus the perf
contract: the ladder adds zero traces to the single while_loop compile (all
rungs live inside one lax.switch), and the 1×1 mesh engine still equals the
single-process engine.

Kernel-level: the sweep Pallas kernel (interpret mode) against its pure-jnp
oracle, and the bitwise rung-invariance lemma the ladder's parity argument
rests on (trailing tiles beyond K·V contribute exact f32 zeros in tile
order).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import plar_reduce
from repro.core.plan import LADDER_TILE, candidate_theta, ladder_rungs

DELTAS = ["PR", "SCE", "LCE", "CCE"]


def _table(rng, n, a, vmax=4, m=2, redundancy=0.5):
    x = rng.integers(0, vmax, size=(n, a)).astype(np.int32)
    for j in range(a):
        if rng.random() < redundancy and j > 0:
            x[:, j] = x[:, rng.integers(0, j)]
    d = rng.integers(0, m, size=(n,)).astype(np.int32)
    return x, d


def _assert_same(ra, rb):
    assert ra.reduct == rb.reduct
    assert ra.core == rb.core
    assert ra.theta_history == rb.theta_history  # bit-identical floats
    assert ra.iterations == rb.iterations


# ---------------------------------------------------------------------------
# ladder bucket math
# ---------------------------------------------------------------------------


def test_ladder_rungs_properties():
    for n_bins in [100, 256, 300, 768, 1024, 4096, 6144]:
        rungs = ladder_rungs(n_bins)
        assert rungs[-1] == n_bins                  # top rung = exact bound
        assert list(rungs) == sorted(set(rungs))    # ascending, distinct
        for r in rungs[:-1]:
            # below the top: pow2 multiples of the 256-bin θ tile — divisible
            # by any pow2 data-shard count ≤ 256 (reduce_scatter tiling)
            assert r % LADDER_TILE == 0 and (r & (r - 1)) == 0
    assert ladder_rungs(100) == (100,)              # tiny tables: one rung
    assert ladder_rungs(4096) == (256, 512, 1024, 2048, 4096)


def test_ladder_rung_boundaries():
    """Boundary pins for the rung math: k·v_max exactly on a rung edge, the
    top rung, v_max == 1, and host/device selection agreement there."""
    from types import SimpleNamespace

    from repro.core.engine import _rung_index
    from repro.core.plan import rung_for

    rungs = ladder_rungs(1024)
    assert rungs == (256, 512, 1024)
    # exact tile boundary: k·v_max == 256 stays on the first rung, +1 spills
    assert rung_for(64, 4, rungs) == 256
    assert rung_for(65, 4, rungs) == 512
    # top boundary: k·v_max == the exact full bound still succeeds (k ≤ cap)
    assert rung_for(256, 4, rungs) == 1024
    assert rung_for(1024, 1, rungs) == 1024
    # v_max == 1: need degenerates to k itself
    assert rung_for(256, 1, rungs) == 256
    assert rung_for(257, 1, rungs) == 512
    # k == 0 (empty selection) clamps to one bin's worth, never underflows
    assert rung_for(0, 3, rungs) == 256
    # non-tile-multiple top rung: first-rung boundary still exact
    assert ladder_rungs(300) == (256, 300)
    assert rung_for(64, 4, (256, 300)) == 256
    assert rung_for(65, 4, (256, 300)) == 300
    # tiny tables: the single rung is the exact bound
    assert ladder_rungs(1) == (1,)
    assert rung_for(1, 1, (1,)) == 1
    # the device twin picks the same rung at every boundary k
    cfg = SimpleNamespace(v_max=4, rungs=rungs)
    for k in [1, 63, 64, 65, 128, 129, 255, 256]:
        want = rungs.index(rung_for(k, 4, rungs))
        assert int(_rung_index(cfg, jnp.int32(k))) == want


def test_sweep_xla_bitwise_invariant_across_rungs():
    """The ladder's parity lemma: sweep_xla thetas are bit-identical at every
    rung ≥ K·V — dropped trailing tiles are exact f32 zeros in tile order."""
    rng = np.random.default_rng(3)
    G, nc, vmax, m, K = 300, 7, 4, 3, 37
    x_t = jnp.asarray(rng.integers(0, vmax, (nc, G)), jnp.int32)
    r = jnp.asarray(rng.integers(0, K, (G,)), jnp.int32)
    d = jnp.asarray(rng.integers(0, m, (G,)), jnp.int32)
    w = jnp.asarray(rng.integers(1, 5, (G,)), jnp.int32)
    valid = jnp.asarray(rng.random(G) < 0.9)
    n = jnp.float32(float(np.where(np.asarray(valid), np.asarray(w), 0).sum()))
    for delta in DELTAS:
        outs = [
            np.asarray(candidate_theta(
                delta, None, d, w, valid, n, n_bins=nb, m=m,
                backend="sweep_xla", x_t=x_t, r_ids=r, v_max=vmax))
            for nb in (256, 512, 1024)
        ]
        for o in outs[1:]:
            np.testing.assert_array_equal(o, outs[0])


def test_sweep_kernel_matches_oracle():
    """Pallas sweep kernel (interpret) == pure-jnp oracle, incl. candidate
    and granule padding, pure classes, and a non-tile-multiple bin count."""
    from repro.kernels.contingency import sweep_theta_ref
    from repro.kernels.contingency.ops import sweep_theta

    rng = np.random.default_rng(11)
    for nc, G, vmax, m, K, n_bins in [(5, 130, 3, 2, 20, 60),
                                      (9, 300, 4, 3, 50, 512)]:
        x_t = jnp.asarray(rng.integers(0, vmax, (nc, G)), jnp.int32)
        r = jnp.asarray(rng.integers(0, K, (G,)), jnp.int32)
        d = jnp.asarray(rng.integers(0, m, (G,)), jnp.int32)
        w_ = jnp.asarray(rng.integers(0, 4, (G,)), jnp.float32)  # 0-weight slots
        n = jnp.float32(float(np.asarray(w_).sum()))
        for delta in DELTAS:
            got = np.asarray(sweep_theta(
                x_t, r, d, w_, n, delta=delta, v_max=vmax, n_bins=n_bins,
                n_dec=m))
            want = np.asarray(sweep_theta_ref(
                x_t, r, d, w_, n, delta=delta, v_max=vmax, n_bins=n_bins,
                n_dec=m))
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end parity matrix (the §5.3 contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("delta", DELTAS)
def test_ladder_and_sweep_parity_all_measures(delta):
    """(backend, ladder) grid == the PR-2 device engine, byte-exact."""
    rng = np.random.default_rng(42)
    x, d = _table(rng, 400, 8)
    base = plar_reduce(x, d, delta=delta, engine="device")  # segment, no ladder
    for backend, ladder in [("segment", True), ("sweep_xla", False),
                            ("sweep_xla", True)]:
        r = plar_reduce(x, d, delta=delta, engine="device", backend=backend,
                        ladder=ladder)
        _assert_same(base, r)
    # the Pallas sweep kernel joins the same matrix from the host loop
    r = plar_reduce(x, d, delta=delta, backend="sweep", ladder=True)
    _assert_same(base, r)


@pytest.mark.parametrize("delta", DELTAS)
def test_ladder_sweep_parity_shrink(delta):
    """FSPA shrinking (active mask + PR scalar) under ladder + sweep."""
    rng = np.random.default_rng(7)
    x, d = _table(rng, 300, 8)
    base = plar_reduce(x, d, delta=delta, shrink=True, engine="device")
    r = plar_reduce(x, d, delta=delta, shrink=True, engine="device",
                    backend="sweep_xla", ladder=True)
    _assert_same(base, r)


def test_ladder_parity_spark_and_max_features():
    rng = np.random.default_rng(13)
    x, d = _table(rng, 250, 8)
    # spark mode: the ladder is inert (sort-ranked ids, not K·V-packed) but
    # must pass through cleanly with identical results
    bs = plar_reduce(x, d, delta="PR", mode="spark", engine="device")
    rs = plar_reduce(x, d, delta="PR", mode="spark", engine="device",
                     ladder=True)
    _assert_same(bs, rs)
    # max_features caps the same iteration on every config
    bm = plar_reduce(x, d, delta="SCE", engine="device", max_features=3,
                     compute_core=False)
    rm = plar_reduce(x, d, delta="SCE", engine="device", max_features=3,
                     compute_core=False, backend="sweep_xla", ladder=True)
    _assert_same(bm, rm)
    assert len(rm.reduct) <= 3


def test_host_engine_ladder_matches_device_ladder():
    """The host loop's rung-snapped eval == the device switch, byte-exact
    (same rung set, same candidate_theta function at each K)."""
    rng = np.random.default_rng(17)
    x, d = _table(rng, 350, 8)
    for backend in ["segment", "sweep_xla"]:
        rh = plar_reduce(x, d, delta="SCE", engine="host", backend=backend,
                         ladder=True)
        rd = plar_reduce(x, d, delta="SCE", engine="device", backend=backend,
                         ladder=True)
        _assert_same(rh, rd)


def test_ladder_single_compile():
    """All ladder rungs live inside the ONE while_loop trace (lax.switch):
    a full run adds exactly one trace, a second same-shape run adds zero —
    the 'never recompiles mid-run' proof."""
    from repro.core.engine import make_engine_run

    rng = np.random.default_rng(23)
    n, a, vmax, m = 400, 8, 4, 2
    x1, d1 = _table(rng, n, a, vmax=vmax, m=m)
    x2, d2 = _table(rng, n, a, vmax=vmax, m=m)
    for x, d in ((x1, d1), (x2, d2)):
        x[0, :] = vmax - 1
        d[0] = m - 1
    # grc_init=False ⇒ capacity == n exactly: n_bins = 1600, a 4-rung ladder
    assert len(ladder_rungs(n * vmax)) == 4
    plar_reduce(x1, d1, delta="SCE", engine="device", grc_init=False,
                backend="sweep_xla", ladder=True)
    runner = make_engine_run(
        "SCE", "incremental", "sweep_xla", a, n, m, vmax, 1e-6, 1e-5, False,
        a, 64, True)
    assert runner._cache_size() == 1          # one trace, every rung inside
    plar_reduce(x2, d2, delta="SCE", engine="device", grc_init=False,
                backend="sweep_xla", ladder=True)
    assert runner._cache_size() == 1          # warm rerun: zero new traces


@pytest.mark.parametrize("delta", ["PR", "LCE"])
def test_ladder_sweep_1x1_mesh_matches_single_process(delta):
    import jax

    from repro.core.distributed import plar_reduce_distributed
    from repro.distributed.api import make_mesh

    rng = np.random.default_rng(29)
    x, d = _table(rng, 300, 8)
    mesh = make_mesh((1, 1), ("data", "model"),
                     devices=np.array(jax.devices()[:1]))
    r_mesh = plar_reduce_distributed(x, d, mesh, delta=delta, engine="device",
                                     backend="sweep_xla", ladder=True)
    r_sp = plar_reduce(x, d, delta=delta, engine="device",
                       backend="sweep_xla", ladder=True)
    assert r_mesh.reduct == r_sp.reduct
    assert r_mesh.core == r_sp.core
    # mesh capacity padding differs from the single-process pow2 shrink, so
    # f32 grouping may differ in the last ulp — values agree
    np.testing.assert_allclose(
        r_mesh.theta_history, r_sp.theta_history, rtol=1e-6, atol=1e-7)


def test_sweep_validation_errors():
    import jax

    from repro.core.distributed import plar_reduce_distributed
    from repro.distributed.api import make_mesh

    rng = np.random.default_rng(31)
    x, d = _table(rng, 80, 5)
    # Pallas sweep kernel cannot run inside the while_loop body
    with pytest.raises(ValueError, match="engine='device'"):
        plar_reduce(x, d, backend="sweep", engine="device")
    # slab operand form is mandatory for the sweep backends
    with pytest.raises(ValueError, match="slab"):
        candidate_theta("PR", jnp.zeros((2, 8), jnp.int32),
                        jnp.zeros((8,), jnp.int32), jnp.ones((8,), jnp.int32),
                        jnp.ones((8,), bool), jnp.float32(8), n_bins=16, m=2,
                        backend="sweep_xla")
    mesh = make_mesh((1, 1), ("data", "model"),
                     devices=np.array(jax.devices()[:1]))
    with pytest.raises(ValueError, match="mesh Θ backend"):
        plar_reduce_distributed(x, d, mesh, backend="onehot")
    with pytest.raises(ValueError, match="fused"):
        plar_reduce_distributed(x, d, mesh, collective="fused",
                                backend="sweep_xla")
